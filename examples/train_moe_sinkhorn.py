"""End-to-end training driver: MoE LM with the Sinkhorn-Knopp router.

    PYTHONPATH=src python examples/train_moe_sinkhorn.py [--steps 300]

Trains a ~100M-param qwen2-moe-family model for a few hundred steps on the
synthetic pipeline, with the paper's Sinkhorn-Knopp solver doing the
token->expert balanced assignment, and compares router health (drop rate,
load imbalance) against the top-k baseline at the end.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, batch_at_step
from repro.models import model as M
from repro.models import transformer as T
from repro.models.moe import moe_dropped_fraction
from repro.optim import adamw


def hundred_m_config(router: str):
    base = get_config("qwen2_moe_a2_7b")
    return dataclasses.replace(
        base, num_layers=4, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, vocab_size=8192,
        moe=dataclasses.replace(base.moe, n_experts=16, n_shared=1,
                                top_k=2, d_ff=512, router=router))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--router", default="sinkhorn",
                    choices=["sinkhorn", "topk"])
    args = ap.parse_args()

    cfg = hundred_m_config(args.router)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, router={args.router}")

    hp = M.TrainHParams(peak_lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(M.make_train_step(cfg, hp=hp))
    opt = adamw.init(params)
    dc = DataConfig(cfg.vocab_size, args.batch, args.seq_len, seed=0)

    t0 = time.time()
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, batch_at_step(dc, step))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"ce {float(m['ce']):.4f}  aux {float(m['aux']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    # router health on fresh data, both routers, same trained weights
    batch = batch_at_step(dc, args.steps + 1)
    h = T.forward(cfg, params, batch["tokens"], remat=False)[0]
    lp = jax.tree.map(lambda x: x[0], params["layers"])   # first layer
    for kind in ("topk", "sinkhorn"):
        drop = float(moe_dropped_fraction(lp["moe"], h, cfg.moe.top_k, kind))
        print(f"router={kind:8s} token-drop fraction at capacity: {drop:.4f}")


if __name__ == "__main__":
    main()
