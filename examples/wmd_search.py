"""End-to-end serving driver: batched WMD document retrieval.

    PYTHONPATH=src python examples/wmd_search.py [--n-docs 2048] [--queries 8]

The paper's practical use case ("find whether a tweet is similar to any
other tweets of a given day"): a stream of query documents, each scored
against the WHOLE corpus in one fused solve; returns top-k per query with
latency stats. Uses the distributed solver when >1 device is available.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import one_to_many, select_support
from repro.data.corpus import make_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=2048)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--impl", default="sparse")
    args = ap.parse_args()

    corpus = make_corpus(vocab_size=args.vocab, embed_dim=64,
                         n_docs=args.n_docs, n_queries=args.queries, seed=7)
    print(f"corpus: {args.n_docs} docs, vocab {args.vocab}, "
          f"{len(jax.devices())} device(s)")

    lat = []
    for qi in range(args.queries):
        q = corpus.queries[qi]
        t0 = time.perf_counter()
        d = np.asarray(one_to_many(q, corpus.docs, corpus.vecs, lam=8.0,
                                   n_iter=15, impl=args.impl))
        lat.append(time.perf_counter() - t0)
        top = np.argsort(d)[:args.topk]
        v_r = int((q > 0).sum())
        print(f"query {qi} (v_r={v_r}): top-{args.topk} = {top.tolist()} "
              f" d={np.round(d[top], 3).tolist()}  "
              f"{lat[-1]*1e3:.1f} ms")

    lat = np.asarray(lat[1:]) * 1e3        # drop compile
    print(f"\nlatency p50={np.percentile(lat, 50):.1f}ms "
          f"p95={np.percentile(lat, 95):.1f}ms  "
          f"throughput={args.n_docs/ (lat.mean()/1e3):,.0f} docs/s/query")


if __name__ == "__main__":
    main()
