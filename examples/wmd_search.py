"""End-to-end serving driver: staged top-k WMD document retrieval.

    PYTHONPATH=src python examples/wmd_search.py [--n-docs 2048] [--queries 8]

The paper's practical use case ("find whether a tweet is similar to any
other tweets of a given day"): a stream of query documents retrieved
against the WHOLE corpus through the staged pipeline — the corpus index is
frozen once, queries are bucketed by support size, and each batch runs
*prune -> solve -> rank*: an admissible lower bound (``--prune``) excludes
most documents, the fused Sinkhorn solve runs only on the surviving
candidates, and the exact top-k comes back with latency stats and the
solved-fraction per query. ``--prune none`` scores every document
(exhaustive oracle); ``--mode refine`` bounds the solve budget to
``refine-factor * topk`` bound-ranked candidates per query (distances
stay exact, membership is approximate — fig13 measures the recall);
``--looped`` falls back to the seed per-query loop.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import WmdEngine, build_index, one_to_many
from repro.data.corpus import make_corpus

LAM = 4.0   # distance scale here is ~sqrt(2*64) ~ 11; keep lam*dist << 87


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=2048)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--prune", default="rwmd",
                    choices=["none", "wcd", "rwmd", "wcd+rwmd", "ivf+wcd",
                             "ivf+rwmd", "ivf+wcd+rwmd",
                             "ivf+pivot+wcd+rwmd", "ivf+pivot+rwmd"],
                    help="prune-stage lower bound or IVF cascade; "
                         "'none' = exhaustive; 'pivot' rungs use the "
                         "index's precomputed pivot triangle bounds")
    ap.add_argument("--nprobe", type=int, default=0,
                    help="ivf cascades: clusters probed per query "
                         "(0 = all = exact top-k)")
    ap.add_argument("--mode", default="exact", choices=["exact", "refine"],
                    help="'refine': rank candidates by the cascade's "
                         "bound, Sinkhorn-solve only the top "
                         "refine-factor*topk per query (needs --prune)")
    ap.add_argument("--refine-factor", type=int, default=4,
                    help="--mode refine: solve budget multiple")
    ap.add_argument("--impl", default="sparse",
                    help="engine: sparse|kernel; --looped accepts any "
                         "repro.core.IMPLS entry")
    ap.add_argument("--n-clusters", default=None,
                    help="IVF cluster count at index build (int, or 'auto' "
                         "to sweep cluster-radius statistics; default "
                         "sqrt(n_docs))")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "log", "bf16+log"],
                    help="solve precision policy ('log' is underflow-free "
                         "at any lam)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="> 0: convergence-adaptive solve (exit at this "
                         "relative doc-marginal residual; 15 iters becomes "
                         "a cap)")
    ap.add_argument("--check-every", type=int, default=4,
                    help="adaptive solve: iterations between residual "
                         "checks")
    ap.add_argument("--scope", default="query", choices=["chunk", "query"],
                    help="adaptive-exit granularity (with --tol): 'query' "
                         "freezes each query at its own convergence, "
                         "'chunk' keeps the global scalar exit")
    ap.add_argument("--warm-start", action="store_true",
                    help="warm-start survivor solves from the seed "
                         "solve's converged profile (with --tol)")
    ap.add_argument("--shards", type=int, default=0,
                    help="> 1: cluster-aligned doc shards over a device "
                         "mesh (host-platform CPU devices are forced when "
                         "no accelerators exist); per-shard cascades, one "
                         "top-k merge collective")
    ap.add_argument("--batches", type=int, default=4,
                    help="timed engine passes over the query set")
    ap.add_argument("--looped", action="store_true",
                    help="seed per-query loop instead of the staged engine")
    args = ap.parse_args()

    if args.shards > 1:
        # must precede the first jax array op / device query below
        from repro.runtime.sharding import ensure_host_devices
        ensure_host_devices(args.shards)

    corpus = make_corpus(vocab_size=args.vocab, embed_dim=64,
                         n_docs=args.n_docs, n_queries=args.queries, seed=7)
    queries = list(corpus.queries)
    print(f"corpus: {args.n_docs} docs, vocab {args.vocab}, "
          f"{len(jax.devices())} device(s)")

    if args.looped:
        for q in queries:                                 # compile pass
            jax.block_until_ready(one_to_many(q, corpus.docs, corpus.vecs,
                                              lam=LAM, n_iter=15,
                                              impl=args.impl))
        lat = []
        rows = []
        for q in queries:
            t0 = time.perf_counter()
            rows.append(np.asarray(one_to_many(q, corpus.docs, corpus.vecs,
                                               lam=LAM, n_iter=15,
                                               impl=args.impl)))
            lat.append(time.perf_counter() - t0)
        d = np.stack(rows)
        batch_ms = [sum(lat) * 1e3]
        for qi, q in enumerate(queries):
            top = np.argsort(d[qi])[:args.topk]
            print(f"query {qi} (v_r={int((q > 0).sum())}): "
                  f"top-{args.topk} = {top.tolist()} "
                  f"d={np.round(d[qi][top], 3).tolist()}")
    else:
        prune = None if args.prune == "none" else args.prune
        nprobe = args.nprobe if args.nprobe > 0 else None
        kw = dict(lam=LAM, n_iter=15, impl=args.impl,
                  tol=args.tol if args.tol > 0 else None,
                  check_every=args.check_every, precision=args.precision,
                  scope=args.scope, warm_start=args.warm_start)
        if args.shards > 1:
            from repro.core import ShardedWmdEngine, shard_corpus
            sindex = shard_corpus(corpus.docs, corpus.vecs, args.shards,
                                  n_clusters=args.n_clusters)
            engine = ShardedWmdEngine(sindex, **kw)
            print(f"sharded: {engine.n_shards} cluster-aligned shards, "
                  f"docs/shard {list(engine.docs_per_shard)}, "
                  f"clusters/shard {list(engine.cluster_counts)}")
        else:
            index = build_index(corpus.docs, corpus.vecs,
                                n_clusters=args.n_clusters)  # frozen once;
            # 'auto'/numeric strings parsed by build_index itself
            engine = WmdEngine(index, **kw)
        res = engine.search(queries, args.topk, prune=prune,
                            nprobe=nprobe, mode=args.mode,
                            refine_factor=args.refine_factor)  # compile
        batch_ms = []
        for _ in range(args.batches):
            t0 = time.perf_counter()
            res = engine.search(queries, args.topk, prune=prune,
                                nprobe=nprobe, mode=args.mode,
                                refine_factor=args.refine_factor)
            batch_ms.append((time.perf_counter() - t0) * 1e3)
        for qi, q in enumerate(queries):
            print(f"query {qi} (v_r={int((q > 0).sum())}): "
                  f"top-{args.topk} = {res.indices[qi].tolist()} "
                  f"d={np.round(res.distances[qi], 3).tolist()} "
                  f"solved={int(res.solved[qi])}/{args.n_docs}")

    batch_ms = np.asarray(batch_ms)
    per_query = batch_ms.mean() / args.queries
    print(f"\nbatch latency p50={np.percentile(batch_ms, 50):.1f}ms "
          f"({args.queries} queries)  per-query={per_query:.2f}ms  "
          f"throughput={args.n_docs / (per_query / 1e3):,.0f} docs/s/query")
    if not args.looped and args.tol > 0:
        iters = engine.iter_stats()
        if iters.size:
            stages = ", ".join(
                f"{st}={arr.mean():.1f}" for st, arr in
                engine.iter_stats_by_stage().items() if arr.size)
            print(f"adaptive solve: realized iters/query "
                  f"mean={iters.mean():.1f} max={int(iters.max())} "
                  f"(cap 15, tol={args.tol:g}, scope={args.scope}; "
                  f"per-stage means: {stages})")


if __name__ == "__main__":
    main()
