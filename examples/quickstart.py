"""Quickstart: Word Mover's Distance between documents in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a toy vocabulary + embeddings, computes one-to-many WMD with the
paper's sparse fused solver, and shows the nearest documents. Mirrors the
paper's motivating example: documents with disjoint words can still be
close in embedding space.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import one_to_many
from repro.data.corpus import make_corpus

corpus = make_corpus(vocab_size=4096, embed_dim=64, n_docs=256, n_queries=1,
                     seed=42)
query = corpus.queries[0]
# NOTE: lam is scaled to the embedding norm — at w=64 distances are ~11, and
# lam*M must stay well under ~87 or exp(-lam*M) underflows fp32 (use
# impl="dense_stabilized" for large lam; see EXPERIMENTS.md).

# all implementations agree; 'sparse' is the production path
for impl in ("dense", "sparse", "kernel"):
    d = np.asarray(one_to_many(query, corpus.docs, corpus.vecs,
                               lam=3.0, n_iter=25, impl=impl))
    top = np.argsort(d)[:5]
    print(f"{impl:8s} nearest docs: {top.tolist()}  "
          f"distances: {np.round(d[top], 3).tolist()}")

d = np.asarray(one_to_many(query, corpus.docs, corpus.vecs, lam=3.0,
                           n_iter=25, impl="sparse"))
print(f"\ncorpus of {len(d)} docs  ->  WMD range "
      f"[{d.min():.2f}, {d.max():.2f}]  (lower = more similar)")
