"""Architecture config system.

Every assigned architecture is an ``ArchConfig`` in its own module
(``repro.configs.<id>``) selectable via ``--arch <id>`` in the launchers.
``reduced()`` yields the CPU-smoke-test variant of the same family.

TP head adjustment (DESIGN.md §6): the production mesh fixes the tensor-
parallel degree at 16, so head counts are adapted at build time:
  - query heads padded up to a multiple of tp (zero-capacity heads;
    function-preserving for checkpoint import via a head permutation);
  - kv heads: kept if divisible by tp; replicated tp/kv per kv head if tp %
    kv == 0 (exact GQA pairing preserved); else converted to MHA (the
    vLLM/Megatron fallback). The padded-FLOPs overhead is visible in the
    roofline "useful ratio" — honesty by construction.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    n_shared: int          # fused into one shared expert of n_shared*d_ff
    top_k: int
    d_ff: int              # per-expert hidden dim
    router: str = "sinkhorn"   # paper integration default; "topk" baseline
    capacity_factor: float = 1.25
    router_iters: int = 6


@dataclass(frozen=True)
class SSMSpec:
    kind: str              # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    decay_lora: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp: str = "swiglu"
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float | None = 10000.0
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    attn_every: int = 0    # hybrid: shared attn+mlp block every k ssm layers
    tie_embeddings: bool = False
    notes: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def tp_heads(self, tp: int) -> tuple[int, int]:
        """(n_q_eff, n_kv_eff) after TP padding/replication (see module doc)."""
        if self.num_heads == 0:
            return 0, 0
        n_q = -(-self.num_heads // tp) * tp
        kv = self.num_kv_heads
        if kv % tp == 0:
            n_kv = kv
        elif tp % kv == 0:
            n_kv = tp
        else:
            n_kv = n_q                       # MHA fallback (e.g. phi3 kv=10)
        if n_q % n_kv != 0:
            n_kv = n_q
        return n_q, n_kv

    def n_params(self) -> int:
        """Approximate true (unpadded) parameter count."""
        d, nl, v = self.d_model, self.num_layers, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per = 0
        if self.ssm and self.ssm.kind == "mamba2":
            di = self.ssm.expand * d
            per += d * (2 * di + 2 * self.ssm.d_state + di // self.ssm.head_dim)
            per += di * d
        elif self.ssm and self.ssm.kind == "rwkv6":
            per += 5 * d * d + 2 * d * self.ssm.decay_lora
            per += 2 * d * self.d_ff        # channel mix
        if self.num_heads:
            hd = self.head_dim
            attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            if self.attn_every:             # hybrid: ONE shared block
                per_shared = attn + 3 * d * self.d_ff
                return emb + nl * per + per_shared
            per += attn
        if self.moe:
            per += d * self.moe.n_experts
            per += 3 * d * self.moe.d_ff * self.moe.n_experts
            per += 3 * d * self.moe.d_ff * self.moe.n_shared
        elif self.d_ff and not self.ssm:
            mult = 3 if self.mlp == "swiglu" else 2
            per += mult * d * self.d_ff
        return emb + nl * per

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if not self.moe:
            return self.n_params()
        d, nl = self.d_model, self.num_layers
        total = self.n_params()
        all_experts = 3 * d * self.moe.d_ff * self.moe.n_experts * nl
        active = 3 * d * self.moe.d_ff * self.moe.top_k * nl
        return total - all_experts + active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes: dict = dict(
            num_layers=2, d_model=64, vocab_size=512,
        )
        if self.num_heads:
            changes.update(num_heads=4, num_kv_heads=max(1, min(
                self.num_kv_heads, 2)), head_dim=16)
        if self.d_ff:
            changes.update(d_ff=128)
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2,
                n_shared=min(self.moe.n_shared, 1), d_ff=64)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=8, head_dim=8, decay_lora=8, chunk=16)
        if self.attn_every:
            changes.update(num_layers=5, attn_every=2)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


ARCH_IDS = [
    "chameleon_34b", "zamba2_7b", "qwen2_5_14b", "phi3_medium_14b",
    "nemotron_4_340b", "granite_3_2b", "qwen2_moe_a2_7b",
    "qwen3_moe_235b_a22b", "musicgen_large", "rwkv6_3b",
]


def load_all() -> None:
    import importlib
    for mod in ARCH_IDS:
        importlib.import_module(f"repro.configs.{mod}")
