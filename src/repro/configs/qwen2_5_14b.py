"""qwen2.5-14b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2_5_14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, mlp="swiglu", norm="rmsnorm",
    qkv_bias=True, rope_theta=1000000.0,
))
