"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

Sinkhorn router (the paper's technique) is the default; --router topk for
the baseline. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from .base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="qwen2_moe_a2_7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    vocab_size=151936, mlp="swiglu", norm="rmsnorm",
    moe=MoESpec(n_experts=60, n_shared=4, top_k=4, d_ff=1408),
))
