"""The paper's own workload config: V=100k vocabulary, w=300 embeddings,
N=5000 target documents (crawl-300d-2M subset + dbpedia statistics)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class WmdConfig:
    vocab_size: int = 100_000
    embed_dim: int = 300
    n_docs: int = 5000
    max_words: int = 64          # ELL pad (dbpedia docs ~ 35 nnz)
    lam: float = 10.0
    n_iter: int = 15
    query_words: tuple = (19, 43)   # the paper's two profiled queries


CONFIG = WmdConfig()
