"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

81 Mamba2 layers; ONE weight-shared (attn + MLP) block applied every 6th
layer (13 applications + 3 trailing mamba layers). Sub-quadratic: runs the
long_500k cell. [arXiv:2411.15242; unverified]
"""
from .base import ArchConfig, SSMSpec, register

CONFIG = register(ArchConfig(
    name="zamba2_7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000, mlp="swiglu", norm="rmsnorm",
    ssm=SSMSpec(kind="mamba2", d_state=64, head_dim=64, expand=2),
    attn_every=6,
    notes="shared attn block weights reused at every application; "
          "each application has its own KV cache",
))
