"""qwen3-moe-235b-a22b [moe] — 128 routed experts, top-8, no shared.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="qwen3_moe_235b_a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    vocab_size=151936, mlp="swiglu", norm="rmsnorm",
    moe=MoESpec(n_experts=128, n_shared=0, top_k=8, d_ff=1536),
))
