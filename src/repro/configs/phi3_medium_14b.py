"""phi3-medium-14b [dense] — RoPE SwiGLU GQA (kv=10 -> MHA fallback at
TP=16, see ArchConfig.tp_heads). [arXiv:2404.14219; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi3_medium_14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352, mlp="swiglu", norm="rmsnorm",
))
