"""chameleon-34b [vlm] — early-fusion multimodal decoder over VQ image tokens.

Backbone only (assignment): the modality frontend is the VQ token stream
itself, so input_specs() supplies token ids. [arXiv:2405.09818; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon_34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, mlp="swiglu", norm="rmsnorm",
    notes="early-fusion VLM; VQ image tokens share the text vocab",
))
