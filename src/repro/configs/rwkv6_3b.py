"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

Sub-quadratic: runs the long_500k cell. [arXiv:2404.05892; hf]
"""
from .base import ArchConfig, SSMSpec, register

CONFIG = register(ArchConfig(
    name="rwkv6_3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=8960, vocab_size=65536, mlp="squared_relu", norm="rmsnorm",
    rope_theta=None,
    ssm=SSMSpec(kind="rwkv6", head_dim=64, decay_lora=64),
))
