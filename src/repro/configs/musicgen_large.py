"""musicgen-large [audio] — decoder-only over EnCodec tokens.

Backbone only: input_specs() supplies precomputed EnCodec frame token ids
(the audio frontend stub per the assignment). [arXiv:2306.05284; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen_large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, mlp="gelu", norm="layernorm",
))
