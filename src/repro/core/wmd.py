"""End-to-end Word Mover's Distance pipeline (public API).

    wmd = one_to_many(query_counts, corpus_docs, vecs, lam=..., n_iter=...,
                      impl="sparse")
    res = search(queries, corpus_docs, vecs, k=10, prune="rwmd")

Implementations (all produce identical distances, tested against each other
and against the exact-LP oracle):

  dense             paper Fig. 2 transliteration (the "python" baseline)
  dense_stabilized  log-domain dense (beyond-paper; large-lam safe in fp32)
  sparse            fused SDDMM_SpMM formulation, gather-once (paper §4 + TPU
                    adaptation) — the production path
  sparse_unfused    separate SDDMM / SpMM with per-iteration gathers (paper
                    Fig. 3 before fusion; for the fusion ablation)
  kernel            Pallas SDDMM_SpMM kernel path (TPU target; interpret-mode
                    on CPU)

Top-k retrieval goes through the staged pipeline (prune -> solve -> rank,
:meth:`repro.core.index.WmdEngine.search`); :func:`search` is the one-shot
convenience wrapper (index built per call — hold a ``WmdEngine`` to amortize
the corpus freeze across query batches).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .sinkhorn import (LamUnderflowError, select_support, sinkhorn_wmd_dense,
                       sinkhorn_wmd_dense_stabilized, underflow_report)
from .sinkhorn_sparse import sinkhorn_wmd_sparse, sinkhorn_wmd_sparse_unfused
from .sparse import PaddedDocs, padded_docs_to_dense

IMPLS = ("dense", "dense_stabilized", "sparse", "sparse_unfused", "kernel")


def one_to_many(r_full, docs: PaddedDocs, vecs, lam: float = 10.0,
                n_iter: int = 15, impl: str = "sparse",
                dtype=jnp.float32, check_underflow: bool = True):
    """WMD from one query (full-vocab count/frequency vector ``r_full``) to
    every document in ``docs``. Returns (N,) distances.

    ``check_underflow`` (all impls except the log-domain one): raise
    :class:`LamUnderflowError` with a diagnosis when ``K = exp(-lam*M)``
    underflowed and the distances came out NaN, instead of returning them.
    The check syncs the result — pass ``False`` to keep dispatch async.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    vecs = jnp.asarray(vecs, dtype)
    r, vecs_sel, _ = select_support(r_full, vecs, dtype)

    if impl == "sparse":
        # the unified check below covers this impl — skip the solver's own
        out = sinkhorn_wmd_sparse(r, vecs_sel, vecs, docs, lam, n_iter,
                                  check_underflow=False)
    elif impl == "sparse_unfused":
        out = sinkhorn_wmd_sparse_unfused(r, vecs_sel, vecs, docs, lam,
                                          n_iter)
    elif impl == "kernel":
        from repro.kernels.ops import sinkhorn_wmd_kernel
        out = sinkhorn_wmd_kernel(r, vecs_sel, vecs, docs, lam, n_iter)
    else:
        c = jnp.asarray(padded_docs_to_dense(docs, vecs.shape[0]), dtype)
        if impl == "dense":
            out = sinkhorn_wmd_dense(r, vecs_sel, vecs, c, lam, n_iter)
        else:
            return sinkhorn_wmd_dense_stabilized(r, vecs_sel, vecs, c, lam,
                                                 n_iter)
    if (check_underflow and r.shape[0] > 0
            and bool(jnp.isnan(out).any())):
        raise LamUnderflowError(underflow_report(lam, vecs_sel, vecs, docs))
    return out


def many_to_many(queries: list[np.ndarray], docs: PaddedDocs, vecs,
                 lam: float = 10.0, n_iter: int = 15, impl: str = "sparse",
                 batched: bool = True):
    """Paper Fig. 6 workload: multiple source documents at once.

    Default path: the batched multi-query engine (:mod:`repro.core.index`) —
    one persistent corpus index, one solve per power-of-two ``v_r`` bucket.
    ``batched=False`` keeps the original per-query Python loop (the naive
    baseline the engine is benchmarked against); dense impls always loop.
    """
    if batched and impl in ("sparse", "kernel"):
        from .index import WmdEngine, build_index
        engine = WmdEngine(build_index(docs, vecs), lam=lam, n_iter=n_iter,
                           impl=impl)
        out = engine.query_batch(queries)
        return [out[i] for i in range(out.shape[0])]
    return [one_to_many(q, docs, vecs, lam, n_iter, impl) for q in queries]


def search(queries, docs: PaddedDocs, vecs, k: int = 10, lam: float = 10.0,
           n_iter: int = 15, impl: str = "sparse", prune: object = "rwmd"):
    """One-shot top-k retrieval through the staged pipeline: freeze an index,
    prune with an admissible lower bound, Sinkhorn-solve the survivors, rank.
    Returns a :class:`repro.core.index.SearchResult`. ``prune=None`` scores
    every document (exhaustive oracle path)."""
    from .index import WmdEngine, build_index
    engine = WmdEngine(build_index(docs, vecs), lam=lam, n_iter=n_iter,
                       impl=impl)
    return engine.search(queries, k, prune=prune)
