"""End-to-end Word Mover's Distance pipeline (public API).

    wmd = one_to_many(query_counts, corpus_docs, vecs, lam=..., n_iter=...,
                      impl="sparse")

Implementations (all produce identical distances, tested against each other
and against the exact-LP oracle):

  dense             paper Fig. 2 transliteration (the "python" baseline)
  dense_stabilized  log-domain dense (beyond-paper; large-lam safe in fp32)
  sparse            fused SDDMM_SpMM formulation, gather-once (paper §4 + TPU
                    adaptation) — the production path
  sparse_unfused    separate SDDMM / SpMM with per-iteration gathers (paper
                    Fig. 3 before fusion; for the fusion ablation)
  kernel            Pallas SDDMM_SpMM kernel path (TPU target; interpret-mode
                    on CPU)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .sinkhorn import (select_support, sinkhorn_wmd_dense,
                       sinkhorn_wmd_dense_stabilized)
from .sinkhorn_sparse import sinkhorn_wmd_sparse, sinkhorn_wmd_sparse_unfused
from .sparse import PaddedDocs, padded_docs_to_dense

IMPLS = ("dense", "dense_stabilized", "sparse", "sparse_unfused", "kernel")


def one_to_many(r_full, docs: PaddedDocs, vecs, lam: float = 10.0,
                n_iter: int = 15, impl: str = "sparse",
                dtype=jnp.float32):
    """WMD from one query (full-vocab count/frequency vector ``r_full``) to
    every document in ``docs``. Returns (N,) distances."""
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    vecs = jnp.asarray(vecs, dtype)
    r, vecs_sel, _ = select_support(r_full, vecs, dtype)

    if impl == "sparse":
        return sinkhorn_wmd_sparse(r, vecs_sel, vecs, docs, lam, n_iter)
    if impl == "sparse_unfused":
        return sinkhorn_wmd_sparse_unfused(r, vecs_sel, vecs, docs, lam, n_iter)
    if impl == "kernel":
        from repro.kernels.ops import sinkhorn_wmd_kernel
        return sinkhorn_wmd_kernel(r, vecs_sel, vecs, docs, lam, n_iter)

    c = jnp.asarray(padded_docs_to_dense(docs, vecs.shape[0]), dtype)
    if impl == "dense":
        return sinkhorn_wmd_dense(r, vecs_sel, vecs, c, lam, n_iter)
    return sinkhorn_wmd_dense_stabilized(r, vecs_sel, vecs, c, lam, n_iter)


def many_to_many(queries: list[np.ndarray], docs: PaddedDocs, vecs,
                 lam: float = 10.0, n_iter: int = 15, impl: str = "sparse",
                 batched: bool = True):
    """Paper Fig. 6 workload: multiple source documents at once.

    Default path: the batched multi-query engine (:mod:`repro.core.index`) —
    one persistent corpus index, one solve per power-of-two ``v_r`` bucket.
    ``batched=False`` keeps the original per-query Python loop (the naive
    baseline the engine is benchmarked against); dense impls always loop.
    """
    if batched and impl in ("sparse", "kernel"):
        from .index import WmdEngine, build_index
        engine = WmdEngine(build_index(docs, vecs), lam=lam, n_iter=n_iter,
                           impl=impl)
        out = engine.query_batch(queries)
        return [out[i] for i in range(out.shape[0])]
    return [one_to_many(q, docs, vecs, lam, n_iter, impl) for q in queries]
