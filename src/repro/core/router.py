"""Sinkhorn-Knopp balanced MoE router — the paper's solver inside the LM stack.

Token->expert assignment with load balance IS a small optimal-transport
problem: row marginal = one unit of routing mass per token, column marginal =
equal capacity per expert. We reuse the identical Sinkhorn-Knopp
matrix-scaling iteration the WMD solver runs (log-domain for bf16 safety) to
produce a balanced soft assignment, then take top-k. This is the
first-class integration of the paper's technique into the MoE architectures
(qwen2-moe-a2.7b, qwen3-moe-235b-a22b); select with ``router="sinkhorn"``.

The iteration count is small (paper uses tens for WMD; routing needs ~4-8
because the problem is tiny and well-conditioned) and runs fully on-device
per data shard — no collectives, exactly like the paper's per-thread
independence over documents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def sinkhorn_route(logits: jax.Array, n_iter: int = 6,
                   n_real: int | None = None) -> jax.Array:
    """Balanced assignment probabilities from router logits.

    ``logits`` (..., T, E) -> doubly-"stochastic-like" plan (..., T, E) whose
    rows sum to 1 and whose columns sum to T/n_real (perfect balance at the
    fixed point). Log-domain Sinkhorn-Knopp.

    ``n_real``: when experts are TP-padded (E > true expert count), padded
    columns get ZERO column marginal — exactly the WMD solver's treatment of
    empty ``c`` columns — so no mass is ever forced onto dead experts.
    """
    t = logits.shape[-2]
    e = logits.shape[-1]
    n_real = e if n_real is None else n_real
    log_k = logits  # K = exp(logits); cost = -logits, lam = 1
    log_r = -jnp.log(jnp.asarray(t, logits.dtype))        # each token: 1/T mass
    col = jnp.where(jnp.arange(e) < n_real,
                    -jnp.log(jnp.asarray(n_real, logits.dtype)), -jnp.inf)
    log_c = jnp.broadcast_to(col, logits.shape[:-2] + (e,))

    # derive zero inits FROM logits so shard_map vma typing matches the
    # scan carry (fresh constants would be unvarying -> carry type error)
    f = (logits * 0).sum(-1)                               # (..., T)
    g = (logits * 0).sum(-2)                               # (..., E)

    def body(carry, _):
        f, g = carry
        f = log_r - jax.nn.logsumexp(log_k + g[..., None, :], axis=-1)
        g = log_c - jax.nn.logsumexp(log_k + f[..., :, None], axis=-2)
        g = jnp.where(jnp.isneginf(log_c), -jnp.inf, g)
        return (f, g), None

    (f, g), _ = lax.scan(body, (f, g), None, length=n_iter)
    plan = jnp.exp(f[..., :, None] + log_k + g[..., None, :])
    # renormalize rows to probabilities (T * plan rows sum ~= 1 already)
    return plan / jnp.maximum(plan.sum(-1, keepdims=True), 1e-9)


def topk_route(logits: jax.Array) -> jax.Array:
    """Standard softmax router (baseline the paper's technique is compared
    against in the MoE integration benchmarks)."""
    return jax.nn.softmax(logits, axis=-1)


def route(logits: jax.Array, kind: str, n_iter: int = 6,
          n_real: int | None = None) -> jax.Array:
    if n_real is not None and n_real < logits.shape[-1]:
        # mask padded experts so top-k never selects them
        dead = jnp.arange(logits.shape[-1]) >= n_real
        logits = jnp.where(dead, -1e30, logits)
    if kind == "sinkhorn":
        return sinkhorn_route(logits, n_iter=n_iter, n_real=n_real)
    if kind == "topk":
        return topk_route(logits)
    raise ValueError(f"unknown router kind: {kind!r}")
