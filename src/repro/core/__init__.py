"""Core library: the paper's contribution as composable JAX modules."""
from .index import (CorpusIndex, DocGroup, IvfClusters, SearchResult,
                    WmdEngine, append_docs, auto_n_clusters, bucket_size,
                    build_index, default_n_clusters, load_index, save_index)
from .prune import (PRUNERS, CascadePruner, MaxPruner, Pruner, RwmdPruner,
                    WcdPruner, resolve_pruner)
from .sinkhorn import (LamUnderflowError, cdist, precompute, select_support,
                       sinkhorn_wmd_dense, sinkhorn_wmd_dense_stabilized,
                       underflow_report)
from .sinkhorn_sparse import (SolvePrecision, precompute_sparse,
                              precompute_sparse_log, reconstruct_gm,
                              sinkhorn_wmd_sparse,
                              sinkhorn_wmd_sparse_unfused)
from .sparse import (BlockSparse, PaddedDocs, block_density,
                     block_sparse_from_dense, padded_docs_from_dense,
                     padded_docs_from_lists, padded_docs_to_dense)
from .shard_index import (ShardCoverage, ShardSearchError,
                          ShardedCorpusIndex, ShardedWmdEngine,
                          append_docs_sharded, bin_pack_clusters,
                          count_collectives, restore_shard, shard_corpus,
                          snapshot_shards)
from .wmd import IMPLS, many_to_many, one_to_many, search
from .router import route, sinkhorn_route, topk_route

__all__ = [
    "CorpusIndex", "DocGroup", "IvfClusters", "SearchResult", "WmdEngine",
    "append_docs", "auto_n_clusters", "bucket_size", "build_index",
    "default_n_clusters", "load_index", "save_index",
    "PRUNERS", "CascadePruner", "MaxPruner", "Pruner", "RwmdPruner",
    "WcdPruner", "resolve_pruner", "LamUnderflowError",
    "cdist", "precompute", "select_support", "sinkhorn_wmd_dense",
    "sinkhorn_wmd_dense_stabilized", "underflow_report", "SolvePrecision",
    "precompute_sparse", "precompute_sparse_log",
    "reconstruct_gm", "sinkhorn_wmd_sparse", "sinkhorn_wmd_sparse_unfused",
    "BlockSparse", "PaddedDocs", "block_density", "block_sparse_from_dense",
    "padded_docs_from_dense", "padded_docs_from_lists",
    "padded_docs_to_dense", "IMPLS", "many_to_many", "one_to_many", "search",
    "ShardCoverage", "ShardSearchError",
    "ShardedCorpusIndex", "ShardedWmdEngine", "append_docs_sharded",
    "bin_pack_clusters", "count_collectives", "restore_shard",
    "shard_corpus", "snapshot_shards",
    "route", "sinkhorn_route", "topk_route",
]
