"""Batched multi-query WMD engine: persistent corpus index + bucketed solves.

The paper's motivating scenario ("finding whether a given tweet is similar to
any other tweets happened in a day") is *many* queries against one shared
corpus, but a per-query loop over :func:`repro.core.wmd.one_to_many` re-ships
the vocabulary embeddings to the device, re-reduces their norms, and re-jits
for every distinct query support size ``v_r`` — the naive-baseline shape the
paper gets its 700x over. This module keeps the corpus side *resident* and
batches the query side:

``CorpusIndex``
    Freezes everything query-independent exactly once: the ELL document
    collection (``docs.idx/val``), the vocabulary embeddings, and the
    per-word squared norms that form the corpus half of the ``cdist`` GEMM.
    Documents are also nnz-sorted and split into width-trimmed
    :class:`DocGroup` slices (ELL row grouping), so the per-query solve
    never touches padding slots shorter docs don't have — a one-time cost
    at build that every subsequent query amortizes. Every query after the
    first touches none of this again.

``WmdEngine``
    Shape-buckets incoming queries to a small set of power-of-two ``v_r``
    sizes (padded query rows carry ``r = 1, G = 0`` — the established
    padding contract of :mod:`repro.kernels.sddmm_spmm`, proven inert by the
    kernel tests), stacks each bucket into one ``(Q, v_r, ...)`` problem and
    runs the solver ONCE per bucket: the per-query ``(v_r, V)`` cdist
    becomes a single ``(Q*v_r, V)`` GEMM, the Sinkhorn loop runs as one
    batched einsum or one Pallas launch with a query grid dimension
    (:func:`repro.kernels.sddmm_spmm.sinkhorn_fused_all_batched`), and jit
    caching collapses to one executable per bucket shape instead of one per
    distinct ``v_r``. GM is reconstructed from G everywhere (never
    materialized), so the per-bucket footprint is two nnz-sized arrays.

Typical use::

    index = build_index(corpus.docs, corpus.vecs)
    engine = WmdEngine(index, lam=9.0, n_iter=15, impl="sparse")
    dists = engine.query_batch(queries)        # (Q, N)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .sinkhorn_sparse import reconstruct_gm
from .sparse import PaddedDocs

ENGINE_IMPLS = ("sparse", "kernel")


class DocGroup(NamedTuple):
    """One length-homogeneous slice of the corpus, ELL-trimmed to its own
    max word count (classic ELL row-grouping: the solver never multiplies
    padding slots a shorter doc group doesn't have)."""

    docs: PaddedDocs    # idx/val (N_g, L_g), L_g = group max words
    cols: jax.Array     # (N_g,) original doc positions (for reassembly)


class CorpusIndex(NamedTuple):
    """Query-independent corpus state, frozen once and reused forever."""

    docs: PaddedDocs    # full ELL corpus: idx (N, L) int32, val (N, L)
    groups: tuple       # tuple[DocGroup, ...] — nnz-sorted, width-trimmed
    vecs: jax.Array     # (V, w) vocabulary embeddings, device-resident
    vecs_sq: jax.Array  # (V,) per-word |b|^2 — corpus half of the cdist GEMM

    @property
    def n_docs(self) -> int:
        return self.docs.idx.shape[0]

    @property
    def vocab_size(self) -> int:
        return self.vecs.shape[0]

    @property
    def embed_dim(self) -> int:
        return self.vecs.shape[1]


def build_index(docs: PaddedDocs, vecs, dtype=jnp.float32,
                doc_groups: int = 4) -> CorpusIndex:
    """Freeze the corpus side: device-resident docs + embeddings + norms.

    Documents are additionally sorted by nnz and split into ``doc_groups``
    equal-count groups, each trimmed to its own max word count — the
    per-query solve work drops by the corpus' ELL padding fraction, paid
    once here instead of on every query.
    """
    vecs = jnp.asarray(vecs, dtype)
    idx_np = np.asarray(docs.idx, np.int32)
    val_np = np.asarray(docs.val, dtype)
    # compact live slots to the front (front-filled is the builders'
    # contract, but cheap to enforce for arbitrary PaddedDocs inputs)
    slot_order = np.argsort(~(val_np > 0), axis=1, kind="stable")
    idx_np = np.take_along_axis(idx_np, slot_order, 1)
    val_np = np.take_along_axis(val_np, slot_order, 1)
    nnz = (val_np > 0).sum(1)
    order = np.argsort(nnz, kind="stable")
    n = max(1, len(order))
    gsz = -(-n // max(1, doc_groups))
    groups = []
    for lo in range(0, len(order), gsz):
        sel = order[lo:lo + gsz]
        lg = max(1, int(nnz[sel].max(initial=0)))
        groups.append(DocGroup(
            docs=PaddedDocs(idx=jnp.asarray(idx_np[sel][:, :lg]),
                            val=jnp.asarray(val_np[sel][:, :lg])),
            cols=jnp.asarray(sel.astype(np.int32))))
    return CorpusIndex(docs=PaddedDocs(idx=jnp.asarray(idx_np),
                                       val=jnp.asarray(val_np)),
                       groups=tuple(groups), vecs=vecs,
                       vecs_sq=jnp.sum(vecs * vecs, axis=1))


def bucket_size(v_r: int, min_bucket: int = 8) -> int:
    """Smallest power-of-two bucket (>= min_bucket) holding v_r query rows."""
    b = max(1, int(min_bucket))
    while b < v_r:
        b *= 2
    return b


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def _solve_batched_einsum(g, val, r, mask, lam, n_iter):
    """Batched ELL Sinkhorn + distance line in the CPU/XLA-friendly layout.

    g (Q, N, L, B): query rows on the MINOR axis, so both contractions are
    contiguous per-(doc, query) tiles — measured ~4x faster per live row
    than the (Q, B, N, L) order whose k-reduction strides by N*L. Only ONE
    G tensor is kept: diag(1/r) is folded into the x-update (r is constant
    per row) instead of materializing G_over_r, halving resident bytes.
    val (N, L); r, mask (Q, B); padded rows (G == 0, r == 1) are inert.
    Returns wmd (Q, N).
    """
    q, n, length, b = g.shape
    live = val > 0                                      # (N, L)
    rinv = _safe_inv(r)[:, None, :]                     # (Q, 1, B)
    denom = jnp.sum(mask, axis=1, keepdims=True)
    x0 = jnp.where(mask > 0, 1.0 / jnp.maximum(denom, 1.0), 0.0)
    x = jnp.broadcast_to(x0[:, None, :], (q, n, b))

    # pad rows keep x == 0 exactly (their G is 0), so a single x > 0 guard
    # on u suffices — the untaken 1/0 branch yields inf which the select
    # discards; live-entry arithmetic matches the per-query oracle's.
    def body(x, _):
        u = jnp.where(x > 0, 1.0 / x, 0.0)
        t = jnp.einsum("qnlb,qnb->qnl", g, u)           # SDDMM
        w = jnp.where(live[None], val[None] / t, 0.0)
        x = jnp.einsum("qnlb,qnl->qnb", g, w) * rinv    # SpMM (fused)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = jnp.where(x > 0, 1.0 / x, 0.0)
    t = jnp.einsum("qnlb,qnb->qnl", g, u)
    w = jnp.where(live[None], val[None] / t, 0.0)
    return jnp.einsum("qnb,qnlb,qnl->qn", u, reconstruct_gm(g, lam), w)


@functools.partial(jax.jit, static_argnames=("lam",))
def _compute_kq(sup: jax.Array, mask: jax.Array, vecs: jax.Array,
                vecs_sq: jax.Array, lam: float) -> jax.Array:
    """Stacked cdist GEMM -> K for one query chunk: (Q, B) ids -> (Q, V, B).

    One (V, Q*B) GEMM replaces Q separate (v_r, V) cdists. The TRANSPOSED
    orientation makes the subsequent doc-word gathers copy contiguous rows
    instead of striding over the vocab axis; the reorder to (Q, V, B)
    happens on this SMALL matrix, never on the Q*N*L*B gather output.
    Padded rows (mask == 0) come out as all-zero K columns (G == 0).
    """
    q, b = sup.shape
    a = jnp.take(vecs, sup, axis=0)                     # (Q, B, w)
    a2 = jnp.sum(a * a, axis=-1)                        # (Q, B)
    ab = vecs @ a.reshape(q * b, -1).T                  # (V, Q*B)
    d2 = jnp.maximum(vecs_sq[:, None] + a2.reshape(1, -1) - 2.0 * ab, 0.0)
    kt = jnp.exp(-lam * jnp.sqrt(d2)) * mask.reshape(1, -1)
    return jnp.transpose(kt.reshape(-1, q, b), (1, 0, 2))    # (Q, V, B)


@functools.partial(jax.jit, static_argnames=("layout",))
def _gather_g(kq: jax.Array, idx: jax.Array, layout: str = "qnlb"):
    """Gather doc-word columns of K: (Q, V, B) x (N, L) -> G.

    Kept as its own jit (with :func:`_compute_kq` separate too): XLA CPU
    otherwise fuses the exp/sqrt producer INTO the gather and recomputes it
    per gathered element (~2.4x slower end to end); on TPU the boundary is
    where the engine hands off to the Mosaic kernel anyway.
    """
    if layout == "qbnl":
        # TPU tile layout: (v_r, block_n, L) per query, sublane = query rows
        return jnp.take(jnp.transpose(kq, (0, 2, 1)), idx, axis=2)
    return jnp.take(kq, idx, axis=1)                         # (Q, N, L, B)


_solve_gathered = jax.jit(_solve_batched_einsum,
                          static_argnames=("lam", "n_iter"))


def _prepare_query(q, bucket: int, dtype):
    """Host-side support selection + bucket padding for one query row."""
    q = np.asarray(q, dtype=np.float64).reshape(-1)
    idx = np.nonzero(q > 0)[0]
    v_r = idx.size
    if v_r > bucket:
        raise ValueError(f"query v_r={v_r} exceeds bucket {bucket}")
    sup = np.zeros(bucket, np.int32)
    sup[:v_r] = idx
    r = np.ones(bucket, dtype)                # pad rows carry r == 1
    r[:v_r] = (q[idx] / q[idx].sum()).astype(dtype)
    mask = np.zeros(bucket, dtype)
    mask[:v_r] = 1.0
    return sup, r, mask


class WmdEngine:
    """Persistent multi-query WMD engine over a frozen :class:`CorpusIndex`.

    Parameters
    ----------
    index:       corpus state from :func:`build_index` (reused across calls)
    lam, n_iter: Sinkhorn strength / iteration count (static per engine)
    impl:        "sparse" (batched einsum) or "kernel" (batched Pallas)
    min_bucket:  smallest v_r bucket; queries are padded up to powers of two
    max_batch:   per-solve query cap — larger buckets are chunked so the
                 (Q, B, N, L) gathered tile stays memory-bounded
    pad_q:       round each chunk's Q up to a power of two with inert all-pad
                 queries, bounding the set of compiled shapes under serving
                 traffic (Q buckets x v_r buckets executables total)
    """

    def __init__(self, index: CorpusIndex, lam: float = 10.0,
                 n_iter: int = 15, impl: str = "sparse",
                 min_bucket: int = 8, max_batch: int = 4,
                 pad_q: bool = True, block_n: int = 128,
                 interpret: bool | None = None, dtype=jnp.float32):
        if impl not in ENGINE_IMPLS:
            raise ValueError(f"impl must be one of {ENGINE_IMPLS}, "
                             f"got {impl!r}")
        self.index = index
        self.lam = float(lam)
        self.n_iter = int(n_iter)
        self.impl = impl
        self.min_bucket = int(min_bucket)
        self.max_batch = int(max_batch)
        self.pad_q = bool(pad_q)
        self.block_n = int(block_n)
        self.interpret = interpret
        self.dtype = np.dtype(jnp.dtype(dtype).name)

    def query(self, r_full) -> jax.Array:
        """WMD from one full-vocab query histogram to every doc: (N,)."""
        return self.query_batch([r_full])[0]

    def query_batch(self, queries: Sequence) -> jax.Array:
        """WMD for Q queries (rows of full-vocab histograms) -> (Q, N).

        Queries are grouped into power-of-two v_r buckets and SORTED by v_r
        inside each bucket; each ``max_batch``-sized chunk is then trimmed to
        the smallest multiple-of-8 width (the TPU sublane) covering its
        members. The pow2 buckets bound the executable count, the sort + trim
        bounds padding waste to < 8 rows per query. Row order of the result
        matches the input order. A query with no support (all-zero
        histogram) yields a NaN row — WMD is undefined for an empty
        marginal — without affecting the other rows.
        """
        queries = [np.asarray(q) for q in queries]
        if not queries:
            return jnp.zeros((0, self.index.n_docs), self.dtype)
        vr = [int((q > 0).sum()) for q in queries]
        buckets: dict[int, list[int]] = {}
        for qi, q in enumerate(queries):
            if vr[qi] == 0:
                continue        # empty marginal: NaN row, never solved
            buckets.setdefault(bucket_size(vr[qi], self.min_bucket),
                               []).append(qi)

        # dispatch every chunk before collecting any result: device compute
        # of chunk i overlaps host prep of chunk i+1
        pending = []
        for b in sorted(buckets):
            members = sorted(buckets[b], key=lambda qi: vr[qi])
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                width = max(8, min(b, -(-max(vr[qi] for qi in chunk) // 8) * 8))
                parts = self._solve_chunk([queries[qi] for qi in chunk], width)
                pending.append((chunk, parts))
        out = np.zeros((len(queries), self.index.n_docs), self.dtype)
        for qi in range(len(queries)):
            if vr[qi] == 0:
                out[qi] = np.nan
        for chunk, parts in pending:
            for grp, wmd_g in parts:
                cols = np.asarray(grp.cols)
                out[np.ix_(chunk, cols)] = np.asarray(wmd_g)[:len(chunk)]
        return jnp.asarray(out)

    def _solve_chunk(self, chunk_queries: list, width: int):
        """Solve one padded chunk against every doc group; returns
        [(DocGroup, wmd (Qpad, N_g)), ...] (device arrays, not yet synced)."""
        prepared = [_prepare_query(q, width, self.dtype)
                    for q in chunk_queries]
        n_live = len(prepared)
        q_pad = n_live
        if self.pad_q:
            q_pad = 1
            while q_pad < n_live:
                q_pad *= 2
        # inert filler queries: no support (mask 0 -> G rows all 0), r == 1
        filler = (np.zeros(width, np.int32), np.ones(width, self.dtype),
                  np.zeros(width, self.dtype))
        prepared += [filler] * (q_pad - n_live)
        sup = jnp.asarray(np.stack([p[0] for p in prepared]))
        r = jnp.asarray(np.stack([p[1] for p in prepared]))
        mask = jnp.asarray(np.stack([p[2] for p in prepared]))
        layout = "qbnl" if self.impl == "kernel" else "qnlb"
        kq = _compute_kq(sup, mask, self.index.vecs, self.index.vecs_sq,
                         self.lam)
        parts = []
        for grp in self.index.groups:
            g = _gather_g(kq, grp.docs.idx, layout=layout)
            if self.impl == "kernel":
                from repro.kernels.ops import sinkhorn_fused_all_batched
                wmd_g = sinkhorn_fused_all_batched(
                    g, grp.docs.val, r, self.lam, self.n_iter,
                    block_n=self.block_n, interpret=self.interpret)
            else:
                wmd_g = _solve_gathered(g, grp.docs.val, r, mask, self.lam,
                                        self.n_iter)
            parts.append((grp, wmd_g))
        return parts
