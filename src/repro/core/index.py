"""Batched multi-query WMD engine: persistent corpus index + bucketed solves.

The paper's motivating scenario ("finding whether a given tweet is similar to
any other tweets happened in a day") is *many* queries against one shared
corpus, but a per-query loop over :func:`repro.core.wmd.one_to_many` re-ships
the vocabulary embeddings to the device, re-reduces their norms, and re-jits
for every distinct query support size ``v_r`` — the naive-baseline shape the
paper gets its 700x over. This module keeps the corpus side *resident* and
batches the query side:

``CorpusIndex``
    Freezes everything query-independent exactly once: the ELL document
    collection (``docs.idx/val``), the vocabulary embeddings, and the
    per-word squared norms that form the corpus half of the ``cdist`` GEMM.
    Documents are also nnz-sorted and split into width-trimmed
    :class:`DocGroup` slices (ELL row grouping), so the per-query solve
    never touches padding slots shorter docs don't have — a one-time cost
    at build that every subsequent query amortizes. Every query after the
    first touches none of this again.

``WmdEngine``
    Shape-buckets incoming queries to a small set of power-of-two ``v_r``
    sizes (padded query rows carry ``r = 1, G = 0`` — the established
    padding contract of :mod:`repro.kernels.sddmm_spmm`, proven inert by the
    kernel tests), stacks each bucket into one ``(Q, v_r, ...)`` problem and
    runs the solver ONCE per bucket: the per-query ``(v_r, V)`` cdist
    becomes a single ``(Q*v_r, V)`` GEMM, the Sinkhorn loop runs as one
    batched einsum or one Pallas launch with a query grid dimension
    (:func:`repro.kernels.sddmm_spmm.sinkhorn_fused_all_batched`), and jit
    caching collapses to one executable per bucket shape instead of one per
    distinct ``v_r``. GM is reconstructed from G everywhere (never
    materialized), so the per-bucket footprint is two nnz-sized arrays.

``WmdEngine.search`` (the staged retrieval pipeline, ISSUE 2)
    The paper's motivating workload is top-k retrieval, and exhaustive
    scoring does asymptotically too much work for it: ``search(queries, k)``
    runs *prune -> solve -> rank*. A cheap admissible lower bound from
    :mod:`repro.core.prune` (WCD / doc-side RWMD) scores every (query, doc)
    pair first; the Sinkhorn solve then runs only on (a) the k best-bounded
    seed docs and (b) the docs whose bound cannot be excluded by the kth
    seed distance — gathered out of the frozen index into a trimmed ELL
    subset slice. With an admissible bound the returned top-k equals the
    exhaustive one exactly; ``prune=None`` reproduces exhaustive
    ``query_batch`` + argsort bit-for-bit.

``WmdEngine`` solve policy (ISSUE 4)
    The solve stage is convergence-adaptive and precision-polymorphic:
    ``tol`` switches the fixed-length Sinkhorn scan to a
    ``lax.while_loop`` that exits once every live doc's marginal residual
    drops below it (``n_iter`` becomes a cap; realized counts are reported
    via :meth:`WmdEngine.iter_stats`), and ``precision`` selects bf16
    GEMMs and/or the log-domain kernel
    (:class:`~repro.core.sinkhorn_sparse.SolvePrecision`) — the log path
    makes :class:`LamUnderflowError` structurally impossible, so the
    paper's ``lam=9`` runs on corpora whose distance scale underflows
    fp32 ``exp(-lam*M)``.

Cluster-major layout (ISSUE 4)
    ``build_index`` stores the corpus sorted by IVF cluster id: cluster
    ``c``'s documents occupy the contiguous STORAGE rows
    ``starts[c]:starts[c+1]``, so ``subset()`` gathers of cascade
    survivors (which arrive as concatenated cluster slices) copy
    near-contiguous host rows instead of scattering across the corpus.
    Storage ids are internal; ``ext_ids``/``remap`` translate to/from the
    caller's original doc order at the output boundary only, so
    ``query_batch`` rows and ``search`` indices are unchanged.
    ``append_docs`` keeps the invariant within the grown group.

Typical use (runnable — the CI ``docs`` job executes it as a doctest)::

    >>> from repro.core import WmdEngine, build_index
    >>> from repro.data.corpus import make_corpus
    >>> c = make_corpus(vocab_size=64, embed_dim=8, n_docs=12,
    ...                 n_queries=2, words_per_doc=(3, 8), seed=0)
    >>> index = build_index(c.docs, c.vecs, n_clusters=3)  # frozen once
    >>> engine = WmdEngine(index, lam=2.0, n_iter=10)
    >>> res = engine.search(list(c.queries), k=3,
    ...                     prune="ivf+pivot+wcd+rwmd")    # exact top-3
    >>> res.indices.shape, res.distances.shape
    ((2, 3), (2, 3))
    >>> ref = engine.search(list(c.queries), k=3,
    ...                     prune="ivf+pivot+wcd+rwmd", mode="refine",
    ...                     refine_factor=4)  # bounded solve budget
    >>> bool((ref.solved <= 4 * 3).all())
    True

At larger ``lam`` (the paper's ``lam=9``) pass ``precision="log"`` —
fp32 ``exp(-lam*M)`` underflows first and the engine raises
:class:`LamUnderflowError` with a diagnosis rather than returning NaN.
``append_docs(index, more_docs)`` grows the corpus without a rebuild.
"""
from __future__ import annotations

import functools
import zlib
from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .sinkhorn import LamUnderflowError, underflow_report
from .sinkhorn_sparse import (SolvePrecision, adaptive_loop,
                              adaptive_loop_scoped, marginal_residual,
                              marginal_residual_per_query)
from .sparse import PaddedDocs

ENGINE_IMPLS = ("sparse", "kernel")


class DocGroup(NamedTuple):
    """One length-homogeneous slice of the corpus, ELL-trimmed to its own
    max word count (classic ELL row-grouping: the solver never multiplies
    padding slots a shorter doc group doesn't have)."""

    docs: PaddedDocs    # idx/val (N_g, L_g), L_g = group max words
    cols: jax.Array     # (N_g,) original doc positions (for reassembly)


class IvfClusters(NamedTuple):
    """Frozen IVF coarse quantizer over the per-doc WCD centroids.

    k-means runs ONCE at :func:`build_index` (mini-batch Lloyd, device-side);
    :func:`append_docs` assigns new docs to the nearest existing center
    without touching the clustering — centers are reused by identity, only
    the host-side membership arrays (and the grown clusters' radii) change.
    The cluster structure powers the :class:`~repro.core.prune.CascadePruner`
    cascade twice: the (Q, n_clusters) probe GEMM replaces the (Q, N) sweep
    for candidate generation, and ``radii`` gives a *cluster-level* lower
    bound ``||qcent - center_c|| - radius_c <= wcd(q, n)`` for every member
    n (triangle inequality; Werner & Laber-style), so whole clusters are
    excluded against the pruning threshold without touching their docs.
    """

    centers: jax.Array   # (C, w) cluster centers, device-resident
    assign: np.ndarray   # (N,) host: cluster id per doc
    order: np.ndarray    # (N,) host: doc ids sorted by cluster id
    starts: np.ndarray   # (C + 1,) host: cluster c owns order[starts[c]:
    #                      starts[c + 1]] — contiguous shortlist slices
    radii: np.ndarray    # (C,) host: max ||center_c - centroid_n|| over
    #                      members (cluster-level bound; grows on append)
    assign_dev: jax.Array  # (N,) device mirror of ``assign`` (the dense
    #                        prune pass looks up doc -> probed cluster)

    @property
    def n_clusters(self) -> int:
        return self.centers.shape[0]

    @property
    def sizes(self) -> np.ndarray:
        return np.diff(self.starts)


@jax.jit
def _assign_clusters(points: jax.Array, centers: jax.Array) -> jax.Array:
    """Nearest-center assignment for one mini-batch: (B, w) -> (B,)."""
    d2 = (jnp.sum(points * points, axis=1)[:, None]
          + jnp.sum(centers * centers, axis=1)[None, :]
          - 2.0 * (points @ centers.T))
    return jnp.argmin(d2, axis=1)


@jax.jit
def _kmeans_accum(points: jax.Array, centers: jax.Array):
    """One mini-batch's contribution to the Lloyd update: per-center
    coordinate sums + member counts (one-hot GEMM, stays on device)."""
    onehot = jax.nn.one_hot(_assign_clusters(points, centers),
                            centers.shape[0], dtype=points.dtype)
    return onehot.T @ points, jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("c",))
def _farthest_point_init(points: jax.Array, c: int, start) -> jax.Array:
    """Maxmin (farthest-point) seeding: each new center is the point
    farthest from all chosen so far. Deterministic, device-side, O(C*N*w)
    once at build — spreads centers across the corpus' actual modes (a
    random draw lands several centers in one dense mode and none in small
    ones, which inflates cluster radii and blunts the triangle bound)."""
    mind = jnp.sum((points - points[start]) ** 2, axis=1)
    centers = jnp.zeros((c, points.shape[1]), points.dtype)
    centers = centers.at[0].set(points[start])

    def body(i, carry):
        centers, mind = carry
        cen = points[jnp.argmax(mind)]
        centers = centers.at[i].set(cen)
        return centers, jnp.minimum(mind, jnp.sum((points - cen) ** 2,
                                                  axis=1))

    centers, _ = lax.fori_loop(1, c, body, (centers, mind))
    return centers


def _kmeans(centroids: jax.Array, n_clusters: int, n_iters: int = 10,
            batch: int = 4096, seed: int = 0, init_sample: int = 65536):
    """Mini-batch Lloyd k-means over the doc centroids, device-side.

    Farthest-point init (on an ``init_sample``-capped subset at corpus
    scale), then each Lloyd iteration streams the (N, w) centroid matrix
    through :func:`_kmeans_accum` in ``batch``-sized slices (the (B, C)
    one-hot and the assignment cdist never exceed a mini-batch) and applies
    one exact update; empty clusters keep their previous center.
    Deterministic in ``seed``. Returns (centers (C, w), assign host (N,)).
    """
    n = centroids.shape[0]
    rng = np.random.default_rng(seed)
    pool = centroids
    if n > init_sample:
        keep = np.sort(rng.choice(n, size=init_sample, replace=False))
        pool = jnp.take(centroids, jnp.asarray(keep, jnp.int32), axis=0)
    centers = _farthest_point_init(pool, n_clusters,
                                   int(rng.integers(pool.shape[0])))
    for _ in range(n_iters):
        sums = jnp.zeros_like(centers)
        counts = jnp.zeros((n_clusters,), centers.dtype)
        for lo in range(0, n, batch):
            s, c = _kmeans_accum(centroids[lo:lo + batch], centers)
            sums, counts = sums + s, counts + c
        centers = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts, 1.0)[:, None],
                            centers)
    assign = np.concatenate([
        np.asarray(_assign_clusters(centroids[lo:lo + batch], centers))
        for lo in range(0, n, batch)]).astype(np.int32)
    return centers, assign


@jax.jit
def _pivot_dists(points: jax.Array, pivots: jax.Array) -> jax.Array:
    """(M, w) points x (P, w) pivots -> (M, P) Euclidean distances — the
    precomputed corpus half (and the per-chunk query half) of the pivot
    triangle prestage ``|d(q, p) - d(n, p)| <= ||qcent - centroid_n||``."""
    a2 = jnp.sum(points * points, axis=1)[:, None]
    b2 = jnp.sum(pivots * pivots, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (points @ pivots.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def _select_pivots(vecs: jax.Array, n_pivots: int, seed: int = 0,
                   sample: int = 65536) -> jax.Array:
    """Pivot words for the triangle prestage: farthest-point selection over
    the vocabulary embeddings (``sample``-capped at vocabulary scale), so
    the reference set spans the embedding space's extremes — that is what
    makes ``max_p |d(q,p) - d(n,p)|`` a tight reverse-triangle bound.
    Returns (P, w) rows of ``vecs`` (actual word vectors, not centroids).
    """
    v = vecs.shape[0]
    n_pivots = max(1, min(int(n_pivots), v))
    rng = np.random.default_rng(seed)
    pool = vecs
    if v > sample:
        keep = np.sort(rng.choice(v, size=sample, replace=False))
        pool = jnp.take(vecs, jnp.asarray(keep, jnp.int32), axis=0)
    return _farthest_point_init(pool, n_pivots,
                                int(rng.integers(pool.shape[0])))


def _membership(assign: np.ndarray, n_clusters: int):
    """(order, starts) from an assignment: cluster c's docs are the
    contiguous slice order[starts[c]:starts[c + 1]]."""
    order = np.argsort(assign, kind="stable").astype(np.int32)
    starts = np.searchsorted(assign[order],
                             np.arange(n_clusters + 1)).astype(np.int64)
    return order, starts


def _member_dists(centroids, centers, assign: np.ndarray,
                  chunk: int = 4096) -> np.ndarray:
    """(N,) host distances from each doc centroid to its assigned center."""
    n = assign.shape[0]
    out = np.empty(n, np.float64)
    assign_dev = jnp.asarray(assign.astype(np.int32))
    for lo in range(0, n, chunk):
        own = jnp.take(centers, assign_dev[lo:lo + chunk], axis=0)
        d = jnp.linalg.norm(centroids[lo:lo + chunk] - own, axis=1)
        out[lo:lo + chunk] = np.asarray(d, np.float64)
    return out


def _cluster_radii(centroids, centers, assign: np.ndarray,
                   n_clusters: int) -> np.ndarray:
    """(C,) max member distance per cluster (0 for empty clusters)."""
    radii = np.zeros(n_clusters, np.float64)
    if assign.size:
        np.maximum.at(radii, assign, _member_dists(centroids, centers,
                                                   assign))
    return radii


def default_n_clusters(n_docs: int) -> int:
    """sqrt(N) coarse-quantizer heuristic (classic IVF sizing)."""
    return max(1, min(n_docs, int(round(float(np.sqrt(max(n_docs, 1)))))))


def auto_n_clusters(centroids: np.ndarray, seed: int = 0,
                    sample: int = 2048, sweep_iters: int = 4,
                    drop: float = 0.7) -> int:
    """Data-tuned cluster count from cluster-radius statistics.

    The sqrt(N) default is wrong for dedup-style corpora (fig9's wants
    ~N/16): once the cluster count reaches the near-duplicate group
    count, the mass-weighted mean cluster radius COLLAPSES (each cluster
    becomes one tight group; measured per-doubling ratio ~0.5 on the fig8
    corpus), which is exactly what makes the triangle-bound prune bite.
    A diffuse corpus has no such elbow — its radius declines gently
    (~0.85-0.95 per doubling) and extra clusters buy nothing.

    So: sweep cluster counts by doubling over a ``sample``-capped subset
    of the doc centroids (cheap mini-batch Lloyd each), and return the
    LARGEST candidate whose doubling shrank the weighted mean radius by
    more than ``1 - drop`` (the structure-driven collapse), scaled back
    to the full corpus size; with no collapse below ``m // 8``, fall
    back to the sqrt default. Spelled ``n_clusters="auto"`` in
    :func:`build_index`, serve, and ``examples/wmd_search.py``.
    """
    n = centroids.shape[0]
    if n <= 4:
        return max(1, n)
    rng = np.random.default_rng(seed)
    pts = centroids
    if n > sample:
        pick = np.sort(rng.choice(n, size=sample, replace=False))
        pts = centroids[pick]
    m = pts.shape[0]
    pts_dev = jnp.asarray(pts)
    best = None
    prev = None
    c = 2
    while c <= max(4, m // 8):
        centers, assign = _kmeans(pts_dev, c, n_iters=sweep_iters,
                                  seed=seed)
        radii = _cluster_radii(pts_dev, centers, assign, c)
        sizes = np.bincount(assign, minlength=c)
        wmean = float((sizes * radii).sum() / max(m, 1))
        if prev is not None and wmean < drop * prev:
            best = c
        prev = wmean
        c *= 2
    if best is None:
        # no collapse: the sqrt default, computed on the FULL corpus (a
        # sample-level sqrt scaled by n/m would be ~n/sqrt(sample))
        return default_n_clusters(n)
    # a collapse point is a density statement about the sample — scale it
    return max(1, min(n, int(round(best * n / m))))


class CorpusIndex(NamedTuple):
    """Query-independent corpus state, frozen once and reused forever.

    Documents live in CLUSTER-MAJOR storage order (sorted by IVF cluster
    id at build): all per-doc arrays — ``docs``, ``docs_host``,
    ``centroids``, group ``cols``, ``clusters.assign`` — are indexed by
    STORAGE id, and cluster ``c``'s members are the contiguous storage
    rows ``clusters.starts[c]:starts[c+1]`` at build time. ``ext_ids``
    maps storage -> the caller's original doc id (``remap`` is the
    inverse); the engine translates at its output boundary, so results
    are always in the caller's order."""

    docs: PaddedDocs     # full ELL corpus: idx (N, L) int32, val (N, L)
    groups: tuple        # tuple[DocGroup, ...] — nnz-sorted, width-trimmed
    vecs: jax.Array      # (V, w) vocabulary embeddings, device-resident
    vecs_sq: jax.Array   # (V,) per-word |b|^2 — corpus half of the cdist GEMM
    centroids: jax.Array  # (N, w) per-doc mass centroids (WCD prune stage)
    docs_host: PaddedDocs  # np mirror of ``docs`` — candidate staging reads
    #                        row slices host-side without a full D2H copy
    clusters: IvfClusters = None  # IVF coarse quantizer over the centroids
    #                               (the CascadePruner's shortlist stage)
    ext_ids: np.ndarray = None   # (N,) host: storage id -> original doc id
    remap: np.ndarray = None     # (N,) host: original doc id -> storage id
    pivots: jax.Array = None     # (P, w) pivot word embeddings (the
    #                              cascade's pivot triangle prestage)
    doc_pivot_d: jax.Array = None  # (N, P) device: ||centroid_n - pivot_p||
    #                                frozen at build; grows on append

    @property
    def n_docs(self) -> int:
        return self.docs.idx.shape[0]

    @property
    def vocab_size(self) -> int:
        return self.vecs.shape[0]

    @property
    def embed_dim(self) -> int:
        return self.vecs.shape[1]

    def save(self, path) -> None:
        """Persist this index to one integrity-checksummed ``.npz`` file
        (see :func:`save_index`). ``CorpusIndex.load(path)`` round-trips
        it bit-compatibly — the shard-recovery snapshot primitive."""
        save_index(self, path)

    @staticmethod
    def load(path) -> "CorpusIndex":
        """Rebuild an index from a :meth:`save` snapshot (see
        :func:`load_index`); raises ``ValueError`` if the checksum or
        format version does not match."""
        return load_index(path)

    def to_external(self, storage_ids: np.ndarray) -> np.ndarray:
        """Storage ids -> the caller's original doc ids."""
        storage_ids = np.asarray(storage_ids, np.int32)
        if self.ext_ids is None:
            return storage_ids
        return self.ext_ids[storage_ids]

    def subset(self, doc_ids, storage: bool = False) -> DocGroup:
        """Candidate-subset slice for the solve stage: gather ``doc_ids``
        out of the full ELL corpus into one width-trimmed :class:`DocGroup`
        (slots are front-compacted at build, so trimming to the subset's
        max nnz loses nothing). Gathers from the host mirror — candidate
        sets are small post-prune and change per query chunk, so they are
        staged like queries: O(|doc_ids| * L) work, one small H2D upload,
        no device round-trip.

        ``doc_ids`` are original (caller-order) ids by default;
        ``storage=True`` takes storage ids directly — the engine's internal
        path, where cascade survivors arrive as concatenated cluster
        slices and the cluster-major layout makes this gather a
        near-contiguous host copy. ``cols`` echoes ``doc_ids`` as passed
        (so it is in the same id space the caller used).

        Shapes are BUCKETED like the query side (doc count padded to a
        power of two with inert all-zero docs, ELL width to a multiple of
        8): candidate counts are data-dependent per search step and would
        otherwise compile a fresh solver executable per step under serving
        traffic. ``cols`` keeps only the real ids — consumers slice the
        solve output to ``cols.shape[0]`` columns."""
        doc_ids = np.asarray(doc_ids, np.int32)
        rows = doc_ids
        if not storage and self.remap is not None:
            rows = self.remap[doc_ids]
        idx = self.docs_host.idx[rows]
        val = self.docs_host.val[rows]
        lg = max(1, int((val > 0).sum(axis=1).max(initial=0)))
        lg = min(-(-lg // 8) * 8, idx.shape[1])
        n_pad = 8
        while n_pad < doc_ids.size:
            n_pad *= 2
        pad = ((0, n_pad - doc_ids.size), (0, 0))
        return DocGroup(docs=PaddedDocs(
            idx=jnp.asarray(np.pad(idx[:, :lg], pad)),
            val=jnp.asarray(np.pad(val[:, :lg], pad))),
            cols=jnp.asarray(doc_ids))


def _compact_slots(docs: PaddedDocs, dtype):
    """Host copies with live slots compacted to the front (front-filled is
    the builders' contract, but cheap to enforce for arbitrary inputs)."""
    idx_np = np.asarray(docs.idx, np.int32)
    val_np = np.asarray(docs.val, dtype)
    slot_order = np.argsort(~(val_np > 0), axis=1, kind="stable")
    return (np.take_along_axis(idx_np, slot_order, 1),
            np.take_along_axis(val_np, slot_order, 1))


def _doc_centroids(idx_np, val_np, vecs_np, chunk: int = 2048):
    """Per-doc mass centroids sum_l val[n,l] * vecs[idx[n,l]] — the frozen
    corpus half of the WCD prune stage. Chunked so the (n, L, w) gather
    intermediate stays small at corpus scale."""
    n = idx_np.shape[0]
    out = np.empty((n, vecs_np.shape[1]), vecs_np.dtype)
    for lo in range(0, max(n, 1), chunk):
        hi = min(lo + chunk, n)
        out[lo:hi] = np.einsum("nl,nlw->nw", val_np[lo:hi],
                               vecs_np[idx_np[lo:hi]])
    return out


def build_index(docs: PaddedDocs, vecs, dtype=jnp.float32,
                doc_groups: int = 4, n_clusters=None,
                ivf_iters: int = 10, ivf_seed: int = 0,
                clusters=None, n_pivots: int = 8,
                pivot_seed: int = 0) -> CorpusIndex:
    """Freeze the corpus side: device-resident docs + embeddings + norms +
    per-doc centroids (the WCD prune stage's corpus half) + the IVF coarse
    quantizer over those centroids (the cascade's shortlist stage).

    Storage is CLUSTER-MAJOR (ISSUE 4): after clustering, documents are
    permuted so cluster ids are non-decreasing — cascade survivor gathers
    in :meth:`CorpusIndex.subset` become near-contiguous host slices
    instead of corpus-wide scatters. ``ext_ids``/``remap`` record the
    permutation; every engine result stays in the caller's doc order.

    ``n_clusters`` accepts an int, ``None`` (sqrt(N) default), ``"auto"``
    (the radius sweep), or a numeric string (CLI passthrough).

    Documents are additionally sorted by nnz and split into ``doc_groups``
    equal-count groups, each trimmed to its own max word count (members
    kept in cluster-major order within the group) — the per-query solve
    work drops by the corpus' ELL padding fraction, paid once here instead
    of on every query. ``n_clusters`` defaults to the sqrt(N) IVF
    heuristic; ``"auto"`` sweeps :func:`auto_n_clusters`'s radius
    statistic instead (dedup-style corpora want far more than sqrt(N)).
    Clustering runs mini-batch Lloyd on device and is frozen afterwards
    (:func:`append_docs` only assigns).

    ``clusters=(centers, assign)`` skips the k-means entirely and freezes
    the given quantizer instead: ``centers`` is a (C, w) array, ``assign``
    a host (N,) cluster id per doc. This is the sharded-index hook
    (:func:`repro.core.shard_index.shard_corpus` runs ONE global k-means,
    then builds each shard's :class:`CorpusIndex` over its owned clusters
    with locally relabeled ids) — membership, radii, and the cluster-major
    permutation are still derived here, so every downstream invariant
    holds unchanged.

    ``n_pivots`` pivot words (farthest-point over the vocabulary
    embeddings, deterministic in ``pivot_seed``) are frozen with their
    per-doc centroid distances ``doc_pivot_d`` — the corpus half of the
    :class:`~repro.core.prune.CascadePruner`'s ``"pivot"`` triangle
    prestage (Werner & Laber style, arXiv:1912.00509): at query time
    ``max_p |d(q, p) - d(n, p)|`` lower-bounds the WCD at O(P) per pair
    instead of O(w). ``n_pivots=0`` skips the precompute (the ``"pivot"``
    stage then raises if requested).

    Exactness contract: the index itself is lossless — every document is
    stored exactly (permuted only), and ``WmdEngine`` results over it are
    independent of ``doc_groups``, ``n_clusters``, ``n_pivots``, and the
    storage permutation. Clustering and pivots only steer *pruning*; they
    change which docs get bounded/solved, never a returned distance.
    """
    vecs = jnp.asarray(vecs, dtype)
    vecs_np = np.asarray(vecs)
    idx_np, val_np = _compact_slots(docs, dtype)
    n_docs = idx_np.shape[0]
    centroids_np = _doc_centroids(idx_np, val_np, vecs_np)
    if clusters is not None:
        pre_centers, pre_assign = clusters
        centers = jnp.asarray(pre_centers, dtype)
        assign = np.asarray(pre_assign, np.int32)
        n_clusters = int(centers.shape[0])
        if assign.shape[0] != n_docs:
            raise ValueError(f"precomputed assign has {assign.shape[0]} "
                             f"entries for {n_docs} docs")
        if assign.size and (assign.min() < 0
                            or assign.max() >= n_clusters):
            raise ValueError("precomputed assign references cluster ids "
                             f"outside [0, {n_clusters})")
        return _assemble_index(idx_np, val_np, centroids_np, vecs,
                               centers, assign, n_clusters, doc_groups,
                               dtype, n_pivots, pivot_seed)
    if isinstance(n_clusters, str):
        if n_clusters == "auto":
            n_clusters = auto_n_clusters(centroids_np, seed=ivf_seed)
        elif n_clusters.isdigit():
            n_clusters = int(n_clusters)    # CLI passthrough
        else:
            raise ValueError(f"n_clusters must be an int, None, or 'auto', "
                             f"got {n_clusters!r}")
    elif n_clusters is None:
        n_clusters = default_n_clusters(n_docs)
    n_clusters = max(1, min(int(n_clusters), max(n_docs, 1)))
    if n_docs:
        centers, assign = _kmeans(jnp.asarray(centroids_np), n_clusters,
                                  n_iters=ivf_iters, seed=ivf_seed)
    else:
        centers = jnp.zeros((n_clusters, vecs.shape[1]), dtype)
        assign = np.zeros((0,), np.int32)
    return _assemble_index(idx_np, val_np, centroids_np, vecs, centers,
                           assign, n_clusters, doc_groups, dtype,
                           n_pivots, pivot_seed)


def _assemble_index(idx_np, val_np, centroids_np, vecs, centers, assign,
                    n_clusters: int, doc_groups: int, dtype,
                    n_pivots: int = 8, pivot_seed: int = 0) -> CorpusIndex:
    """Shared :func:`build_index` tail: cluster-major permutation, nnz
    grouping, membership/radii, device upload. Split out so the sharded
    builder can reuse it with a precomputed (frozen) quantizer."""
    # cluster-major storage: permute every per-doc array so assign is
    # non-decreasing; ext_ids/remap translate at the output boundary
    perm = np.argsort(assign, kind="stable").astype(np.int32)
    idx_np, val_np = idx_np[perm], val_np[perm]
    centroids_np, assign = centroids_np[perm], assign[perm]
    ext_ids = perm
    remap = np.empty_like(perm)
    remap[perm] = np.arange(perm.size, dtype=np.int32)

    groups = _nnz_groups(idx_np, val_np, doc_groups)
    centroids = jnp.asarray(centroids_np)
    c_order, c_starts = _membership(assign, n_clusters)
    radii = _cluster_radii(centroids, centers, assign, n_clusters)
    pivots = doc_pivot_d = None
    if n_pivots and int(n_pivots) > 0:
        pivots = _select_pivots(vecs, int(n_pivots), seed=pivot_seed)
        doc_pivot_d = _pivot_dists(centroids, pivots)
    return CorpusIndex(docs=PaddedDocs(idx=jnp.asarray(idx_np),
                                       val=jnp.asarray(val_np)),
                       groups=groups, vecs=vecs,
                       vecs_sq=jnp.sum(vecs * vecs, axis=1),
                       centroids=centroids,
                       docs_host=PaddedDocs(idx=idx_np, val=val_np),
                       clusters=IvfClusters(centers=centers, assign=assign,
                                            order=c_order, starts=c_starts,
                                            radii=radii,
                                            assign_dev=jnp.asarray(assign)),
                       ext_ids=ext_ids, remap=remap,
                       pivots=pivots, doc_pivot_d=doc_pivot_d)


def _nnz_groups(idx_np, val_np, doc_groups: int) -> tuple:
    """nnz-sorted, width-trimmed :class:`DocGroup` split of an ELL corpus.

    Shared by :func:`_assemble_index` and :func:`load_index`: the split is
    a pure function of (idx, val, doc_groups), so a snapshot only needs to
    persist the full ELL arrays plus the GROUP COUNT to reconstruct the
    groups bit-identically (``g = ceil(n/k)`` is an involution on its
    image: rebuilding with ``doc_groups = len(groups)`` reproduces the
    build-time group size exactly)."""
    nnz = (val_np > 0).sum(1)
    order = np.argsort(nnz, kind="stable")
    n = max(1, len(order))
    gsz = -(-n // max(1, doc_groups))
    groups = []
    for lo in range(0, len(order), gsz):
        # ascending storage ids within the group == cluster-major
        sel = np.sort(order[lo:lo + gsz])
        lg = max(1, int(nnz[sel].max(initial=0)))
        groups.append(DocGroup(
            docs=PaddedDocs(idx=jnp.asarray(idx_np[sel][:, :lg]),
                            val=jnp.asarray(val_np[sel][:, :lg])),
            cols=jnp.asarray(sel.astype(np.int32))))
    return tuple(groups)


INDEX_SNAPSHOT_VERSION = 1


def snapshot_checksum(arrays: dict) -> int:
    """CRC32 over every array's name, dtype, shape, and bytes (key-sorted)
    — the integrity tag :func:`load_index` verifies before trusting a
    snapshot. Not cryptographic; it catches truncated/garbled files, not
    adversarial tampering."""
    crc = 0
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        hdr = f"{name}:{a.dtype.str}:{a.shape}".encode()
        crc = zlib.crc32(a.tobytes(), zlib.crc32(hdr, crc))
    return crc


def save_index(index: CorpusIndex, path) -> None:
    """Persist a frozen :class:`CorpusIndex` to one ``.npz`` file.

    Saves only the HOST-canonical arrays (ELL docs, embeddings, cluster
    membership/radii, ext_ids/remap, pivots) plus the group count;
    everything else — device uploads, ``vecs_sq``, the nnz group split —
    is a deterministic pure function of those and is recomputed on
    :func:`load_index`, which is what makes restore-then-search
    bit-compatible with build-then-search. The payload is tagged with
    :func:`snapshot_checksum`; ``load_index`` refuses a mismatch."""
    idx_np = np.asarray(index.docs_host.idx)
    val_np = np.asarray(index.docs_host.val)
    arrays = {
        "idx": idx_np,
        "val": val_np,
        "vecs": np.asarray(index.vecs),
        "centroids": np.asarray(index.centroids),
        "n_groups": np.asarray(len(index.groups), np.int64),
        "version": np.asarray(INDEX_SNAPSHOT_VERSION, np.int64),
    }
    if index.clusters is not None:
        arrays["c_centers"] = np.asarray(index.clusters.centers)
        arrays["c_assign"] = np.asarray(index.clusters.assign)
        arrays["c_order"] = np.asarray(index.clusters.order)
        arrays["c_starts"] = np.asarray(index.clusters.starts)
        arrays["c_radii"] = np.asarray(index.clusters.radii)
    if index.ext_ids is not None:
        arrays["ext_ids"] = np.asarray(index.ext_ids)
        arrays["remap"] = np.asarray(index.remap)
    if index.pivots is not None:
        arrays["pivots"] = np.asarray(index.pivots)
        arrays["doc_pivot_d"] = np.asarray(index.doc_pivot_d)
    # checksum covers everything ABOVE (computed before its own insertion)
    arrays["checksum"] = np.asarray(snapshot_checksum(arrays), np.uint32)
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def load_index(path) -> CorpusIndex:
    """Rebuild a :class:`CorpusIndex` from a :func:`save_index` snapshot.

    Verifies the integrity checksum first (raises ``ValueError`` on
    mismatch — a half-written snapshot must not silently serve wrong
    results), then re-uploads the host arrays and re-derives the pure
    functions of them (``vecs_sq``, nnz groups, device mirrors). The
    result is bit-compatible with the index that was saved: identical
    host arrays in, identical derivations out."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    stored = int(data.pop("checksum"))
    actual = snapshot_checksum(data)
    if actual != stored:
        raise ValueError(
            f"index snapshot {path!r} failed its integrity check "
            f"(stored crc32 {stored:#010x}, recomputed {actual:#010x}) — "
            "refusing to serve from a corrupt/truncated snapshot")
    version = int(data["version"])
    if version != INDEX_SNAPSHOT_VERSION:
        raise ValueError(f"index snapshot {path!r} has version {version}; "
                         f"this build reads {INDEX_SNAPSHOT_VERSION}")
    idx_np = data["idx"]
    val_np = data["val"]
    vecs = jnp.asarray(data["vecs"])
    clusters = None
    if "c_centers" in data:
        clusters = IvfClusters(
            centers=jnp.asarray(data["c_centers"]),
            assign=data["c_assign"], order=data["c_order"],
            starts=data["c_starts"], radii=data["c_radii"],
            assign_dev=jnp.asarray(data["c_assign"]))
    pivots = doc_pivot_d = None
    if "pivots" in data:
        pivots = jnp.asarray(data["pivots"])
        doc_pivot_d = jnp.asarray(data["doc_pivot_d"])
    return CorpusIndex(
        docs=PaddedDocs(idx=jnp.asarray(idx_np), val=jnp.asarray(val_np)),
        groups=_nnz_groups(idx_np, val_np, int(data["n_groups"])),
        vecs=vecs, vecs_sq=jnp.sum(vecs * vecs, axis=1),
        centroids=jnp.asarray(data["centroids"]),
        docs_host=PaddedDocs(idx=idx_np, val=val_np),
        clusters=clusters,
        ext_ids=data.get("ext_ids"), remap=data.get("remap"),
        pivots=pivots, doc_pivot_d=doc_pivot_d)


def _pad_width(a, width: int):
    """Right-pad axis 1 with zeros; np in -> np out, jax in -> jax out."""
    if a.shape[1] >= width:
        return a
    pads = ((0, 0), (0, width - a.shape[1]))
    return (jnp.pad(a, pads) if isinstance(a, jax.Array)
            else np.pad(a, pads))


def append_docs(index: CorpusIndex, new_docs: PaddedDocs,
                dtype=jnp.float32) -> CorpusIndex:
    """Streaming index update: add documents WITHOUT a full rebuild.

    The new docs join the group with the fewest members (widened only if
    they are longer than its current ELL trim); every other group's arrays
    are reused as-is — no re-sort, no re-gather, no centroid recompute for
    existing docs. New docs get ids ``[n_docs, n_docs + n_new)``.
    ``search``/``query_batch`` after an append match a from-scratch
    ``build_index`` exactly: per-doc solves are independent and grouping /
    ELL padding are inert (proven by the engine tests).

    IVF clusters are FROZEN: the new docs are assigned to their nearest
    existing center (no re-clustering — ``centers`` is reused by identity)
    and only the host-side membership arrays are rebuilt. Exact search
    (``nprobe = n_clusters``) is unaffected; smaller-``nprobe`` recall
    degrades only as far as the frozen centers drift from the grown
    corpus — rebuild when that matters.

    Cluster-major invariant: appended docs take the NEXT storage ids (the
    global storage is no longer one contiguous run per cluster — member
    slices go through ``clusters.order`` and stay *near*-contiguous), but
    the grown group's rows are re-sorted by cluster id so its arrays keep
    the build-time layout; a rebuild restores full contiguity.
    """
    n_new = new_docs.idx.shape[0]
    if n_new == 0:
        return index
    new_idx, new_val = _compact_slots(new_docs, dtype)
    if int(new_idx.max(initial=0)) >= index.vocab_size:
        raise ValueError("new docs reference word ids outside the index "
                         f"vocabulary ({index.vocab_size})")
    nnz = (new_val > 0).sum(1)
    lg_new = max(1, int(nnz.max(initial=0)))
    new_idx, new_val = new_idx[:, :lg_new], new_val[:, :lg_new]
    n_old = index.n_docs

    # full ELL corpus: widen whichever side is narrower, then concat — the
    # device side on-device and the host mirror on-host, so only the NEW
    # docs ever cross the device boundary
    width = max(index.docs.idx.shape[1], lg_new)
    docs = PaddedDocs(
        idx=jnp.concatenate([_pad_width(index.docs.idx, width),
                             jnp.asarray(_pad_width(new_idx, width))]),
        val=jnp.concatenate([_pad_width(index.docs.val, width),
                             jnp.asarray(_pad_width(new_val, width))]))
    docs_host = PaddedDocs(
        idx=np.concatenate([_pad_width(index.docs_host.idx, width),
                            _pad_width(new_idx, width)]),
        val=np.concatenate([_pad_width(index.docs_host.val, width),
                            _pad_width(new_val, width)]))

    cent_new = _doc_centroids(new_idx, new_val, np.asarray(index.vecs))
    clusters = index.clusters
    assign = None
    if clusters is not None:
        cent_new_dev = jnp.asarray(cent_new)
        assign_new = np.asarray(
            _assign_clusters(cent_new_dev,
                             clusters.centers)).astype(np.int32)
        assign = np.concatenate([clusters.assign, assign_new])
        c_order, c_starts = _membership(assign, clusters.n_clusters)
        # frozen centers: only the grown clusters' radii can expand
        radii = clusters.radii.copy()
        np.maximum.at(radii, assign_new,
                      _member_dists(cent_new_dev, clusters.centers,
                                    assign_new))
        clusters = clusters._replace(assign=assign, order=c_order,
                                     starts=c_starts, radii=radii,
                                     assign_dev=jnp.asarray(assign))

    # grow only the smallest group; all others are reused untouched
    gi = int(np.argmin([g.cols.shape[0] for g in index.groups]))
    grp = index.groups[gi]
    gw = max(grp.docs.idx.shape[1], lg_new)
    g_idx = jnp.concatenate([_pad_width(grp.docs.idx, gw),
                             jnp.asarray(_pad_width(new_idx, gw))])
    g_val = jnp.concatenate([_pad_width(grp.docs.val, gw),
                             jnp.asarray(_pad_width(new_val, gw))])
    g_cols = np.concatenate([np.asarray(grp.cols),
                             np.arange(n_old, n_old + n_new, dtype=np.int32)])
    if assign is not None:
        # keep the grown group cluster-major (ISSUE 4 invariant): one
        # O(group) device gather per append, amortized over every
        # subsequent query
        gorder = np.argsort(assign[g_cols], kind="stable").astype(np.int32)
        if not np.array_equal(gorder, np.arange(gorder.size)):
            gd = jnp.asarray(gorder)
            g_idx = jnp.take(g_idx, gd, axis=0)
            g_val = jnp.take(g_val, gd, axis=0)
            g_cols = g_cols[gorder]
    grown = DocGroup(docs=PaddedDocs(idx=g_idx, val=g_val),
                     cols=jnp.asarray(g_cols))
    groups = tuple(grown if i == gi else g
                   for i, g in enumerate(index.groups))

    tail_ids = np.arange(n_old, n_old + n_new, dtype=np.int32)
    ext_ids = (np.concatenate([index.ext_ids, tail_ids])
               if index.ext_ids is not None else None)
    remap = (np.concatenate([index.remap, tail_ids])
             if index.remap is not None else None)
    doc_pivot_d = index.doc_pivot_d
    if index.pivots is not None:
        # frozen pivots (like the cluster centers): only the new rows of
        # the distance table are computed
        doc_pivot_d = jnp.concatenate(
            [index.doc_pivot_d,
             _pivot_dists(jnp.asarray(cent_new), index.pivots)])
    return index._replace(
        docs=docs, groups=groups, docs_host=docs_host,
        centroids=jnp.concatenate([index.centroids,
                                   jnp.asarray(cent_new)]),
        clusters=clusters, ext_ids=ext_ids, remap=remap,
        doc_pivot_d=doc_pivot_d)


def bucket_size(v_r: int, min_bucket: int = 8) -> int:
    """Smallest power-of-two bucket (>= min_bucket) holding v_r query rows."""
    b = max(1, int(min_bucket))
    while b < v_r:
        b *= 2
    return b


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def _stabilize_log_g(g):
    """Column-stabilize a gathered LOG-kernel tile (Q, N, L, B): subtract
    each (q, n, l) column's max over the query-word axis and exponentiate.
    Masked/padded rows carry -inf and exponentiate to exactly 0; a column
    with no live row (an all-pad filler query) gets shift 0 and stays
    all-zero. Returns (G', shift) with every live column's max entry == 1,
    so an all-zero K column — the LamUnderflowError mode — cannot occur."""
    shift = jnp.max(g, axis=-1)                         # (Q, N, L)
    shift = jnp.where(jnp.isfinite(shift), shift, 0.0)
    gp = jnp.where(jnp.isfinite(g), jnp.exp(g - shift[..., None]), 0.0)
    return gp, shift


def _solve_batched_einsum(g, mq, idx, val, r, mask, lam, n_iter, tol=None,
                          check_every: int = 4, gemm: str = "fp32",
                          log_domain: bool = False, scope: str = "chunk",
                          qdoc_mask=None, x0q=None,
                          with_profile: bool = False, prof_mask=None):
    """Batched ELL Sinkhorn + distance line in the CPU/XLA-friendly layout.

    g (Q, N, L, B): query rows on the MINOR axis, so both contractions are
    contiguous per-(doc, query) tiles — measured ~4x faster per live row
    than the (Q, B, N, L) order whose k-reduction strides by N*L. Only ONE
    G tensor is kept: diag(1/r) is folded into the x-update (r is constant
    per row) instead of materializing G_over_r, halving resident bytes.
    val (N, L); r, mask (Q, B); padded rows (G == 0, r == 1) are inert.

    ``tol`` switches the fixed-length scan to a ``lax.while_loop`` that
    checks the doc-marginal residual ``max|val/t - w_prev|`` every
    ``check_every`` iterations — measured RELATIVE to each doc's own
    marginal scale, and masked to live queries x live slots so padded
    docs/queries can neither stall the loop nor release it early.
    ``n_iter`` becomes a cap (realized counts land on
    ``1 + k*check_every``; the residual window is seeded with one real
    iteration so even the first check can exit). ``gemm="bf16"`` runs both contractions with bf16
    inputs and fp32 accumulation; ``log_domain=True`` takes ``g`` as
    UNexponentiated ``log K`` (masked rows -inf) and stabilizes it per
    column before the loop.

    Per-query residual scoping (ISSUE 5): ``scope="query"`` replaces the
    chunk-global scalar exit with the per-query machinery of
    :func:`~repro.core.sinkhorn_sparse.adaptive_loop_scoped` — each
    query's residual is a masked segment-max over its OWN doc slots
    (``qdoc_mask`` (Q, N) narrows that scope to the query's candidate
    docs, so far pairs the ranking never needs stop holding its exit
    open), queries FREEZE their x-columns once converged (their update
    rows are zeroed — semantically dropped; the dense einsum still
    executes at chunk width until the loop exits, so the wall-clock win
    is the EARLIER per-query exit, not fewer FLOPs per iteration), and
    the loop exits once every live query converged or the cap hits.
    ``iters`` is then a (Q,) vector of per-query realized counts instead
    of a scalar. ``x0q`` (Q, B) warm-starts every doc column from a
    per-query profile (the engine passes the seed solve's converged
    column mean for survivor solves); ``with_profile=True`` additionally
    returns that (Q, B) profile — the doc-mean of the final x over
    ``prof_mask`` docs (each query's own candidates; falls back to
    ``qdoc_mask``, then all live docs).

    Distance-line epilogue (ISSUE 4): instead of reconstructing
    ``GM = -G*log(G)/lam`` (a transcendental over the whole nnz tensor —
    measured ~6 iterations' worth on CPU, and wrong for the stabilized
    log-domain G anyway), the TRUE transport costs are gathered from the
    chunk's (Q, V, B) cdist output ``mq`` — one gather + multiply, exact
    in both domains, and the reason the log path needs NO shift
    correction here. The vocab-level M is held for the chunk (same size
    as ``kq``); the nnz-level (Q, N, L, B) product exists only inside
    this jit. The Pallas kernel path keeps the in-VMEM ``reconstruct_gm``
    (on TPU recompute beats the extra HBM gather).

    Returns (wmd (Q, N), realized iterations (int32 scalar)).
    """
    q, n, length, b = g.shape
    live = val > 0                                      # (N, L)
    if log_domain:
        g, _ = _stabilize_log_g(g)
    gd = jnp.bfloat16 if gemm == "bf16" else None
    gb = g if gd is None else g.astype(gd)

    def _sddmm(u):
        if gd is None:
            return jnp.einsum("qnlb,qnb->qnl", gb, u)
        return jnp.einsum("qnlb,qnb->qnl", gb, u.astype(gd),
                          preferred_element_type=jnp.float32)

    def _spmm(w):
        if gd is None:
            return jnp.einsum("qnlb,qnl->qnb", gb, w)
        return jnp.einsum("qnlb,qnl->qnb", gb, w.astype(gd),
                          preferred_element_type=jnp.float32)

    rinv = _safe_inv(r)[:, None, :]                     # (Q, 1, B)
    denom = jnp.sum(mask, axis=1, keepdims=True)
    if x0q is None:
        x0 = jnp.where(mask > 0, 1.0 / jnp.maximum(denom, 1.0), 0.0)
    else:
        # warm start: the caller's per-query profile, zeroed on pad slots
        # (a frozen profile can only carry mass on the query's live words)
        x0 = jnp.where(mask > 0, x0q, 0.0)
    x = jnp.broadcast_to(x0[:, None, :], (q, n, b)).astype(jnp.float32)

    def _select_w(t):
        # linear path: raw val/t so a K-column underflow surfaces as NaN
        # for the engine's LamUnderflowError guard. log path: t == 0 can
        # only mean a fully-underflowed query-word ROW at extreme lam —
        # guard it so the word drops out instead of poisoning the doc.
        if not log_domain:
            return jnp.where(live[None], val[None] / t, 0.0)
        ok = live[None] & (t > 0)
        return jnp.where(ok, val[None] / jnp.where(ok, t, 1.0), 0.0)

    # pad rows keep x == 0 exactly (their G is 0), so a single x > 0 guard
    # on u suffices — the untaken 1/0 branch yields inf which the select
    # discards; live-entry arithmetic matches the per-query oracle's.
    def step(carry, _):
        x, _ = carry
        u = jnp.where(x > 0, 1.0 / x, 0.0)
        t = _sddmm(u)                                   # SDDMM
        w = _select_w(t)
        x = _spmm(w) * rinv                             # SpMM (fused)
        return (x, w), None

    if tol is None:
        # x-only carry: bit-identical to the pre-adaptive dispatch (the
        # step's w is only needed by the residual check)
        x, _ = lax.scan(lambda x, _: (step((x, None), None)[0][0], None),
                        x, None, length=n_iter)
        iters = jnp.asarray(n_iter, jnp.int32)
    elif scope == "chunk":
        # residual mask: live queries (any support) x live doc slots —
        # filler queries' w is inf/NaN and padded docs' is 0; both are
        # excluded so they can neither hold the loop open nor close it
        resmask = ((jnp.sum(mask, axis=1) > 0)[:, None, None]
                   & live[None])                        # (Q, N, L)
        x, iters = adaptive_loop(
            lambda x: step((x, None), None)[0],
            lambda w, wp: marginal_residual(w, wp, resmask),
            x, n_iter, tol, check_every)
    else:
        # per-query scope (ISSUE 5): each query's residual covers only
        # its own live slots — narrowed to its candidate docs when the
        # caller provides qdoc_mask — and converged queries freeze
        live_q = jnp.sum(mask, axis=1) > 0              # (Q,)
        resmask = live_q[:, None, None] & live[None]    # (Q, N, L)
        if qdoc_mask is not None:
            resmask = resmask & qdoc_mask[:, :, None]

        def step_active(x, active):
            # frozen queries' rows drop out of the update: their u rows
            # are zeroed, so SDDMM/SpMM emit zeros the freeze discards
            u = jnp.where(x > 0, 1.0 / x, 0.0) * active[:, None, None]
            t = _sddmm(u)
            w = _select_w(t)
            return _spmm(w) * rinv, w

        x, iters = adaptive_loop_scoped(
            step_active,
            lambda w, wp: marginal_residual_per_query(w, wp, resmask),
            x, n_iter, tol, check_every, live_q)

    u = jnp.where(x > 0, 1.0 / x, 0.0)
    t = _sddmm(u)
    w = _select_w(t)
    mg = jnp.take(mq, idx, axis=1)                      # (Q, N, L, B)
    gm = jnp.where(g > 0, g * mg, 0.0)
    # wmd[q,n] = sum_b u sum_l GM w — with the TRUE gathered M, exact for
    # the stabilized log-domain G too (G' M w' == G M w identically)
    wmd = jnp.einsum("qnb,qnlb,qnl->qn", u, gm, w)
    if not with_profile:
        return wmd, iters
    # per-query doc-mean of the converged x: the warm-start profile
    # survivor solves reuse (survivors share the query's gathered columns,
    # so the converged per-word scaling transfers). Averaged over each
    # query's OWN candidate docs (prof_mask) — the chunk union includes
    # other queries' seeds, whose far-pair columns would pollute the
    # profile with a wildly different scale
    doc_live = jnp.sum(val, axis=1) > 0                       # (N,)
    sel = prof_mask if prof_mask is not None else qdoc_mask
    pmask = (doc_live[None] if sel is None
             else sel & doc_live[None])                       # (Q, N)
    pmask = pmask.astype(x.dtype)
    cnt = jnp.maximum(jnp.sum(pmask, axis=1), 1.0)            # (Q,)
    xprof = jnp.einsum("qnb,qn->qb", x, pmask) / cnt[:, None]
    return wmd, iters, xprof


@functools.partial(jax.jit, static_argnames=("lam", "gemm", "log_domain",
                                             "with_m"))
def _compute_kq(sup: jax.Array, mask: jax.Array, vecs: jax.Array,
                vecs_sq: jax.Array, lam: float, gemm: str = "fp32",
                log_domain: bool = False, with_m: bool = True):
    """Stacked cdist GEMM -> K for one query chunk: (Q, B) ids -> (Q, V, B).

    One (V, Q*B) GEMM replaces Q separate (v_r, V) cdists. The TRANSPOSED
    orientation makes the subsequent doc-word gathers copy contiguous rows
    instead of striding over the vocab axis; the reorder to (Q, V, B)
    happens on this SMALL matrix, never on the Q*N*L*B gather output.
    Padded rows (mask == 0) come out as all-zero K columns (G == 0).

    Returns (kq (Q, V, B), mq (Q, V, B)): the kernel AND the raw cdist —
    the solve's distance-line epilogue gathers its transport costs from
    ``mq`` instead of reconstructing them via ``log(G)`` (see
    :func:`_solve_batched_einsum`). ``mq`` is unmasked (the epilogue's
    ``g > 0`` guard excludes pad rows). ``with_m=False`` returns ``kq``
    alone — the Pallas path reconstructs GM in VMEM and must not pay an
    unused (Q, V, B) buffer per staged chunk.

    ``gemm="bf16"`` casts only the GEMM operands (fp32 accumulation via
    ``preferred_element_type``); ``log_domain=True`` returns
    UNexponentiated ``log K = -lam*M`` with masked rows at -inf — the
    solve stabilizes per gathered column (:func:`_stabilize_log_g`), so
    no K column can underflow at any lam.
    """
    q, b = sup.shape
    a = jnp.take(vecs, sup, axis=0)                     # (Q, B, w)
    a2 = jnp.sum(a * a, axis=-1)                        # (Q, B)
    if gemm == "bf16":
        ab = jnp.matmul(vecs.astype(jnp.bfloat16),
                        a.reshape(q * b, -1).T.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    else:
        ab = vecs @ a.reshape(q * b, -1).T              # (V, Q*B)
    d2 = jnp.maximum(vecs_sq[:, None] + a2.reshape(1, -1) - 2.0 * ab, 0.0)
    m = jnp.sqrt(d2)
    if log_domain:
        kt = jnp.where(mask.reshape(1, -1) > 0, -lam * m, -jnp.inf)
    else:
        kt = jnp.exp(-lam * m) * mask.reshape(1, -1)
    kq = jnp.transpose(kt.reshape(-1, q, b), (1, 0, 2))       # (Q, V, B)
    if not with_m:
        return kq
    return kq, jnp.transpose(m.reshape(-1, q, b), (1, 0, 2))


@functools.partial(jax.jit, static_argnames=("layout",))
def _gather_g(kq: jax.Array, idx: jax.Array, layout: str = "qnlb"):
    """Gather doc-word columns of K: (Q, V, B) x (N, L) -> G.

    Kept as its own jit (with :func:`_compute_kq` separate too): XLA CPU
    otherwise fuses the exp/sqrt producer INTO the gather and recomputes it
    per gathered element (~2.4x slower end to end); on TPU the boundary is
    where the engine hands off to the Mosaic kernel anyway.
    """
    if layout == "qbnl":
        # TPU tile layout: (v_r, block_n, L) per query, sublane = query rows
        return jnp.take(jnp.transpose(kq, (0, 2, 1)), idx, axis=2)
    return jnp.take(kq, idx, axis=1)                         # (Q, N, L, B)


_solve_gathered = jax.jit(_solve_batched_einsum,
                          static_argnames=("lam", "n_iter", "tol",
                                           "check_every", "gemm",
                                           "log_domain", "scope",
                                           "with_profile"))


def _prepare_query(q, bucket: int, dtype):
    """Host-side support selection + bucket padding for one query row."""
    q = np.asarray(q, dtype=np.float64).reshape(-1)
    idx = np.nonzero(q > 0)[0]
    v_r = idx.size
    if v_r > bucket:
        raise ValueError(f"query v_r={v_r} exceeds bucket {bucket}")
    sup = np.zeros(bucket, np.int32)
    sup[:v_r] = idx
    r = np.ones(bucket, dtype)                # pad rows carry r == 1
    r[:v_r] = (q[idx] / q[idx].sum()).astype(dtype)
    mask = np.zeros(bucket, dtype)
    mask[:v_r] = 1.0
    return sup, r, mask


class SearchResult(NamedTuple):
    """Top-k retrieval result from :meth:`WmdEngine.search`.

    Rows for empty queries (no support) hold ``indices == -1`` and NaN
    distances. ``solved`` counts the documents that went through the exact
    Sinkhorn solve for each query — ``n_docs`` when exhaustive, the
    surviving-candidate count when pruned, and the query's own
    rank-selected pick count (<= ``refine_factor * k``) in
    ``mode="refine"``.
    """

    indices: np.ndarray    # (Q, k) int32 doc ids, ascending distance
    distances: np.ndarray  # (Q, k)
    solved: np.ndarray     # (Q,) int64 exact solves per query


class WmdEngine:
    """Persistent multi-query WMD engine over a frozen :class:`CorpusIndex`.

    Parameters
    ----------
    index:       corpus state from :func:`build_index` (reused across calls)
    lam, n_iter: Sinkhorn strength / iteration count (static per engine)
    impl:        "sparse" (batched einsum) or "kernel" (batched Pallas)
    min_bucket:  smallest v_r bucket; queries are padded up to powers of two
    max_batch:   per-solve query cap — larger buckets are chunked so the
                 (Q, B, N, L) gathered tile stays memory-bounded
    pad_q:       round each chunk's Q up to a power of two with inert all-pad
                 queries, bounding the set of compiled shapes under serving
                 traffic (Q buckets x v_r buckets executables total)
    prune_slack: relative safety margin on the prune threshold in
                 :meth:`search` — admissible bounds and exact scores are
                 both fp32, so a candidate is kept unless its bound exceeds
                 the threshold by more than this fraction. Costs a few extra
                 survivors; guards the exact-top-k contract against rounding.
    tol:         convergence-adaptive solve (ISSUE 4): exit the Sinkhorn
                 loop once every live doc's marginal residual
                 ``max|val/t - w_prev|`` (relative to the doc's own
                 marginal scale) is below ``tol``, checked every
                 ``check_every`` iterations. ``None`` (default) keeps the
                 fixed-length loop bit-for-bit; with ``tol`` set,
                 ``n_iter`` becomes a cap (realized counts land on
                 ``1 + k*check_every``). Realized counts:
                 :meth:`iter_stats`.
    scope:       adaptive-exit granularity (ISSUE 5). ``"query"``
                 (default): each query's residual covers only its own
                 live slots, converged queries freeze their x-columns
                 (operand rows zeroed; the loop exits when every live
                 query converged) — one stubborn query no longer holds
                 its chunkmates' realized counts open. In :meth:`search`
                 the survivor solve's scope narrows further to the docs
                 whose bound passed that query's own threshold (the seed
                 solve keeps the union scope: any seed can contend for
                 any query once thresholds exist). ``"chunk"`` keeps
                 ISSUE 4's chunk-global scalar exit. Only consulted when
                 ``tol`` is set.
    warm_start:  survivor solves in :meth:`search` start from the seed
                 solve's converged per-query x profile instead of the
                 uniform init (survivors share the query's gathered
                 columns, so the scaling transfers; docs open at the
                 profile and re-converge in fewer iterations — measured
                 in :meth:`iter_stats_by_stage` as the ``"survivor"``
                 series). Opt-in, and only active with ``tol`` set on
                 the einsum path (``impl="sparse"``): warm starting is
                 sound when the adaptive exit actually CONVERGES (both
                 inits land within ``tol`` of the same fixed point); in
                 a cap-bound regime (``n_iter`` hit first) it changes
                 the truncated values, making survivor distances
                 incomparable with the cold seed stage.
    precision:   :class:`~repro.core.sinkhorn_sparse.SolvePrecision` or
                 its spelling (``"fp32"``, ``"bf16"``, ``"log"``,
                 ``"bf16+log"``) — bf16 GEMMs with fp32 accumulation
                 (tolerance-bounded) and/or the log-domain kernel (exact;
                 makes :class:`LamUnderflowError` impossible at any lam).
    iter_stats_maxlen: bound on the realized-iteration ring
                 (:meth:`iter_stats`); overflow discards the OLDEST record
                 and is counted by :attr:`iter_stats_dropped` so a
                 long-running serve can tell a window from a full history.
    kcache_slots: opt-in cross-request cdist-row cache (ISSUE 10;
                 ``impl="sparse"`` only): keep this many hot words'
                 ``(V,)`` corpus-distance rows device-resident with an
                 LRU clock, so Zipfian serving traffic assembles its
                 ``(Q, V, B)`` K block from cached rows + a misses-only
                 GEMM instead of recomputing the full stacked GEMM per
                 dispatch. Bit-exact against the uncached path (see
                 ``core/kcache.py``); the serving runtime enables it by
                 default. ``None``/``0`` disables.
    kcache_min_hits: dispatch-economy threshold: a chunk with fewer
                 resident rows than this falls back to the one-shot
                 stacked GEMM (cheaper on CPU than gather + miss GEMM +
                 scatter) and warms the cache from its ``mq`` block.
    """

    def __init__(self, index: CorpusIndex, lam: float = 10.0,
                 n_iter: int = 15, impl: str = "sparse",
                 min_bucket: int = 8, max_batch: int = 4,
                 pad_q: bool = True, block_n: int = 128,
                 interpret: bool | None = None, dtype=jnp.float32,
                 prune_slack: float = 1e-3, tol: float | None = None,
                 check_every: int = 4, precision=None,
                 scope: str = "query", warm_start: bool = False,
                 iter_stats_maxlen: int = 4096,
                 kcache_slots: int | None = None,
                 kcache_min_hits: int = 4):
        if impl not in ENGINE_IMPLS:
            raise ValueError(f"impl must be one of {ENGINE_IMPLS}, "
                             f"got {impl!r}")
        if scope not in ("chunk", "query"):
            raise ValueError(f"scope must be 'chunk' or 'query', "
                             f"got {scope!r}")
        if kcache_slots and impl == "kernel":
            raise ValueError(
                "kcache_slots needs impl='sparse': the kernel impl's "
                "staged pair carries no mq block to warm the cache from "
                "(and reconstructs GM in VMEM, bypassing the kq the "
                "cache would assemble)")
        self.index = index
        self.lam = float(lam)
        self.n_iter = int(n_iter)
        self.impl = impl
        self.min_bucket = int(min_bucket)
        self.max_batch = int(max_batch)
        self.pad_q = bool(pad_q)
        self.block_n = int(block_n)
        self.interpret = interpret
        self.dtype = np.dtype(jnp.dtype(dtype).name)
        self.prune_slack = float(prune_slack)
        self.tol = None if tol is None else float(tol)
        self.check_every = int(check_every)
        self.precision = SolvePrecision.parse(precision)
        self.scope = scope
        self.warm_start = bool(warm_start)
        # bounded ring: a long-running service must not leak one device
        # scalar per solve dispatch forever (reset_iter_stats() clears).
        # Saturation is OBSERVABLE (ISSUE 6): the ring silently discarding
        # the oldest record under long-running serve looked like "stats
        # cover everything" when they covered the last 4096 dispatches —
        # iter_stats_dropped counts the discards and the serve JSON
        # surfaces it.
        import collections
        self._iters_pending: collections.deque = collections.deque(
            maxlen=max(1, int(iter_stats_maxlen)))
        self._iters_dropped = 0
        # cross-request cdist-row cache (ISSUE 10): opt-in here, enabled
        # by default by the serving runtime where Zipfian reuse lives
        self._kcache = None
        self.kcache_min_hits = max(1, int(kcache_min_hits))
        if kcache_slots:
            self.enable_kcache(int(kcache_slots))

    # ------------------------------------------------- cross-request cache
    def enable_kcache(self, slots: int) -> bool:
        """Attach a :class:`~repro.core.kcache.KCache` of ``slots``
        resident cdist rows (replacing any existing cache). Returns
        ``False`` on the kernel impl — its staged pair has no ``mq`` to
        warm from — so serving's enable-by-default stays a no-op there.
        Search results are unchanged bit-for-bit (the cache module's
        exactness contract, pinned by the property suite)."""
        if self.impl == "kernel":
            return False
        from .kcache import KCache
        self._kcache = KCache(self.index.vecs, self.index.vecs_sq,
                              int(slots), gemm=self.precision.gemm)
        return True

    def kcache_stats(self) -> dict | None:
        """Hit/miss/eviction counters of the cross-request cache
        (``None`` when no cache is attached)."""
        return None if self._kcache is None else self._kcache.stats()

    def reset_kcache_stats(self) -> None:
        if self._kcache is not None:
            self._kcache.reset_counters()

    # -------------------------------------------------- realized iterations
    def reset_iter_stats(self) -> None:
        """Drop the accumulated realized-iteration log (and the
        dropped-record counter)."""
        self._iters_pending.clear()
        self._iters_dropped = 0

    @property
    def iter_stats_dropped(self) -> int:
        """Dispatch records discarded by the bounded ring since the last
        :meth:`reset_iter_stats` — nonzero means :meth:`iter_stats` is a
        WINDOW over the most recent ``iter_stats_maxlen`` dispatches, not
        the full history (long-running serve saturates it by design)."""
        return self._iters_dropped

    def _record_iters(self, stage: str, iters, n_live: int | None) -> None:
        """Log one dispatch's realized counts (device values, synced
        lazily in :meth:`iter_stats`): a scalar for chunk-scoped solves,
        a per-query vector for ``scope="query"`` — ``n_live`` trims the
        vector to the chunk's real queries (fillers freeze at the first
        check and would pollute the histogram)."""
        if len(self._iters_pending) == self._iters_pending.maxlen:
            self._iters_dropped += 1    # ring full: oldest record discarded
        self._iters_pending.append((stage, iters, n_live))

    def iter_stats(self, stage: str | None = None) -> np.ndarray:
        """Realized Sinkhorn iteration counts since the last
        :meth:`reset_iter_stats` (device values are synced here, not on
        the hot path; the log keeps the most recent 4096 dispatches).
        Chunk-scoped solves contribute one entry per dispatch; per-query
        solves one entry per LIVE query per dispatch. With ``tol=None``
        every entry equals ``n_iter``; with the adaptive loop this is the
        early-exit histogram the fig10 benchmark reports. ``stage``
        filters to one solve stage (``"batch"`` for exhaustive
        :meth:`query_batch` solves, ``"seed"``/``"survivor"`` for the two
        :meth:`search` solve stages — the warm-start win is the
        ``"survivor"`` series)."""
        out: list[np.ndarray] = []
        for st, dev, n_live in self._iters_pending:
            if stage is not None and st != stage:
                continue
            arr = np.atleast_1d(np.asarray(dev)).astype(np.int64)
            if n_live is not None and arr.size > 1:
                arr = arr[:n_live]
            elif n_live is not None and arr.size == 1:
                # chunk-scoped / fixed dispatch: every live query pays the
                # chunk's exit iteration — replicate so per-query and
                # chunk-scoped histograms measure the same unit (realized
                # iterations PER QUERY) and the fig10 A/B is fair
                arr = np.full(n_live, arr[0], np.int64)
            out.append(arr)
        if not out:
            return np.zeros((0,), np.int64)
        return np.concatenate(out)

    def iter_stats_by_stage(self) -> dict:
        """Realized-iteration log split by solve stage — the serve
        metadata / fig10 view of where iterations actually go (seed
        solves pay the cold init; warm-started survivor solves should
        report strictly fewer)."""
        stages = []
        for st, _, _ in self._iters_pending:
            if st not in stages:
                stages.append(st)
        return {st: self.iter_stats(stage=st) for st in stages}

    def _ext(self, storage_ids) -> np.ndarray:
        """Storage ids -> caller-order doc ids (the output boundary)."""
        return self.index.to_external(np.asarray(storage_ids))

    def query(self, r_full) -> jax.Array:
        """WMD from one full-vocab query histogram to every doc: (N,)."""
        return self.query_batch([r_full])[0]

    # ------------------------------------------------------------ staging
    def _plan(self, queries: list):
        """Bucket + chunk the query set: [(input positions, width), ...].

        Queries are grouped into power-of-two v_r buckets and SORTED by v_r
        inside each bucket; each ``max_batch``-sized chunk is then trimmed
        to the smallest multiple-of-8 width (the TPU sublane) covering its
        members. The pow2 buckets bound the executable count, the sort +
        trim bounds padding waste to < 8 rows per query. Empty queries
        (no support) are left out entirely.
        """
        vr = [int((q > 0).sum()) for q in queries]
        buckets: dict[int, list[int]] = {}
        for qi in range(len(queries)):
            if vr[qi] == 0:
                continue        # empty marginal: NaN row, never solved
            buckets.setdefault(bucket_size(vr[qi], self.min_bucket),
                               []).append(qi)
        chunks = []
        for b in sorted(buckets):
            members = sorted(buckets[b], key=lambda qi: vr[qi])
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                width = max(8, min(b, -(-max(vr[qi] for qi in chunk) // 8) * 8))
                chunks.append((chunk, width))
        return vr, chunks

    def _prep_chunk(self, chunk_queries: list, width: int):
        """Stage one chunk: (sup, r, mask) device arrays, q-padded to a
        power of two with inert fillers (no support -> G rows all 0, r == 1)
        when ``pad_q``."""
        prepared = [_prepare_query(q, width, self.dtype)
                    for q in chunk_queries]
        n_live = len(prepared)
        q_pad = n_live
        if self.pad_q:
            q_pad = 1
            while q_pad < n_live:
                q_pad *= 2
        filler = (np.zeros(width, np.int32), np.ones(width, self.dtype),
                  np.zeros(width, self.dtype))
        prepared += [filler] * (q_pad - n_live)
        return (jnp.asarray(np.stack([p[0] for p in prepared])),
                jnp.asarray(np.stack([p[1] for p in prepared])),
                jnp.asarray(np.stack([p[2] for p in prepared])))

    def _solve_group(self, kq, r, mask, grp: DocGroup, n_live=None,
                     stage: str = "batch", qdoc_mask=None, x0q=None,
                     want_profile: bool = False, prof_mask=None):
        """Solve one prepared chunk against one doc group (device array,
        not yet synced): gather the group's K columns, run the batched
        solver. Works for index groups and pruned candidate subsets alike —
        the solve stage of the pipeline. ``kq`` is the (kq, mq) pair from
        :meth:`_kq`. Realized iteration counts land in :meth:`iter_stats`
        under ``stage`` (device values, synced lazily).

        ``qdoc_mask`` (Q, N_grp) scopes each query's adaptive exit to its
        own candidate docs (``scope="query"``); ``x0q`` (Q, B) warm-starts
        the solve from a per-query profile; ``want_profile=True`` returns
        ``(wmd, profile)`` — the converged profile survivor solves reuse,
        averaged over ``prof_mask`` docs (``None`` on the kernel path,
        which reconstructs GM in VMEM and does not expose x)."""
        kqk, mq = kq
        layout = "qbnl" if self.impl == "kernel" else "qnlb"
        g = _gather_g(kqk, grp.docs.idx, layout=layout)
        scoped = self.tol is not None and self.scope == "query"
        if self.impl == "kernel":
            from repro.kernels.ops import sinkhorn_fused_all_batched
            wmd, iters = sinkhorn_fused_all_batched(
                g, grp.docs.val, r, self.lam, self.n_iter,
                block_n=self.block_n, interpret=self.interpret,
                tol=self.tol, check_every=self.check_every,
                gemm=self.precision.gemm,
                log_domain=self.precision.log_domain,
                resmask=qdoc_mask if scoped else None, with_iters=True)
            # per-block counts -> per-query realized iterations (a query's
            # slowest candidate block is when its columns actually froze)
            self._record_iters(stage,
                               jnp.max(iters, axis=1) if scoped
                               else jnp.max(iters), n_live)
            return (wmd, None) if want_profile else wmd
        out = _solve_gathered(g, mq, grp.docs.idx, grp.docs.val, r,
                              mask, self.lam, self.n_iter, self.tol,
                              self.check_every, self.precision.gemm,
                              self.precision.log_domain,
                              scope=self.scope,
                              qdoc_mask=qdoc_mask if scoped else None,
                              x0q=x0q, with_profile=want_profile,
                              prof_mask=prof_mask)
        wmd, iters = out[0], out[1]
        self._record_iters(stage, iters, n_live)
        if want_profile:
            return wmd, out[2]
        return wmd

    def _kq(self, sup, mask):
        """(kq, mq) for one staged chunk — treat as an opaque pair; the
        solve stage consumes both (kernel gather + distance epilogue).
        The kernel impl reconstructs GM in VMEM, so its pair carries
        ``mq=None`` instead of an unused (Q, V, B) buffer.

        With a :meth:`enable_kcache` cache attached, chunks whose words
        are mostly resident assemble the pair from cached cdist rows
        (gather + misses-only GEMM) instead of the full stacked GEMM;
        below ``kcache_min_hits`` resident rows the one-shot GEMM is
        cheaper on CPU (dispatch economy — see the ROADMAP refusion
        note) and its ``mq`` block warms the cache for the next request.
        Both paths produce BIT-IDENTICAL pairs (``core/kcache.py``)."""
        if self.impl == "kernel":
            kq = _compute_kq(sup, mask, self.index.vecs,
                             self.index.vecs_sq, self.lam,
                             gemm=self.precision.gemm,
                             log_domain=self.precision.log_domain,
                             with_m=False)
            return kq, None
        cache = self._kcache
        if cache is not None and cache.vecs is not self.index.vecs:
            # anything that swapped the embedding table (a new index, a
            # snapshot reload) invalidates every resident row; append_docs
            # reuses vecs by identity — the vocabulary is frozen — so
            # appends sail through here with the cache intact
            cache = self._kcache = cache.rebind(self.index.vecs,
                                                self.index.vecs_sq)
        if cache is None:
            return _compute_kq(sup, mask, self.index.vecs,
                               self.index.vecs_sq, self.lam,
                               gemm=self.precision.gemm,
                               log_domain=self.precision.log_domain)
        sup_np = np.asarray(sup)
        ids = np.unique(sup_np.reshape(-1))
        n_hit = cache.lookup(ids)
        oversize = len(ids) > cache.slots
        if oversize or n_hit < self.kcache_min_hits:
            cache.note_fallback(oversize=oversize)
            kq, mq = _compute_kq(sup, mask, self.index.vecs,
                                 self.index.vecs_sq, self.lam,
                                 gemm=self.precision.gemm,
                                 log_domain=self.precision.log_domain)
            cache.warm(sup_np, mq)
            return kq, mq
        from .kcache import assemble_kq
        rows = cache.rows(ids)
        inv = jnp.asarray(np.searchsorted(ids, sup_np).astype(np.int32))
        return assemble_kq(rows, inv, mask, self.lam,
                           log_domain=self.precision.log_domain)

    def _raise_if_nan(self, wmd_np: np.ndarray, chunk_queries: list) -> None:
        """Every chunk query has support, so NaN here means the lam-driven
        K underflow — diagnose (host-side, error path only) and raise
        instead of returning NaN distances."""
        bad = np.isnan(wmd_np).any(axis=1)
        if bad.any():
            from .sinkhorn import select_support
            q = chunk_queries[int(np.nonzero(bad)[0][0])]
            _, vecs_sel, _ = select_support(q, self.index.vecs)
            raise LamUnderflowError(underflow_report(
                self.lam, vecs_sel, self.index.vecs, self.index.docs))

    # ----------------------------------------------------------- scoring
    def query_batch(self, queries: Sequence) -> jax.Array:
        """Exhaustive WMD for Q queries (full-vocab histogram rows) ->
        (Q, N). Row order matches the input; a query with no support yields
        a NaN row (WMD is undefined for an empty marginal). Raises
        :class:`LamUnderflowError` if lam underflows K for a corpus word
        (the distances would be NaN).
        """
        queries = [np.asarray(q) for q in queries]
        if not queries:
            return jnp.zeros((0, self.index.n_docs), self.dtype)
        vr, chunks = self._plan(queries)
        # dispatch every chunk before collecting any result: device compute
        # of chunk i overlaps host prep of chunk i+1
        pending = []
        for chunk, width in chunks:
            sup, r, mask = self._prep_chunk([queries[qi] for qi in chunk],
                                            width)
            kq = self._kq(sup, mask)
            parts = [(grp, self._solve_group(kq, r, mask, grp,
                                             n_live=len(chunk)))
                     for grp in self.index.groups]
            pending.append((chunk, parts))
        out = np.zeros((len(queries), self.index.n_docs), self.dtype)
        for qi in range(len(queries)):
            if vr[qi] == 0:
                out[qi] = np.nan
        for chunk, parts in pending:
            for grp, wmd_g in parts:
                w = np.asarray(wmd_g)[:len(chunk)]
                self._raise_if_nan(w, [queries[qi] for qi in chunk])
                # group cols are STORAGE ids (cluster-major); scatter into
                # the caller's doc order at this output boundary
                out[np.ix_(chunk, self._ext(grp.cols))] = w
        return jnp.asarray(out)

    # ------------------------------------------------------------ search
    def search(self, queries: Sequence, k: int, prune: object = "rwmd",
               nprobe: int | None = None, mode: str = "exact",
               refine_factor: int = 4) -> SearchResult:
        """Staged top-k retrieval: prune -> solve -> rank.

        ``prune=None`` scores exhaustively (:meth:`query_batch` + argsort,
        bit-for-bit). Otherwise ``prune`` names a lower bound from
        :mod:`repro.core.prune` (``"wcd"``, ``"rwmd"``, ``"wcd+rwmd"``, a
        cascaded ``"ivf+pivot+wcd+rwmd"``) or is a
        :class:`~repro.core.prune.Pruner` /
        :class:`~repro.core.prune.CascadePruner` instance, and per chunk:

        1. *prune*: admissible lower bounds, one batched pass. Full-sweep
           pruners score every (query, doc) pair; a cascade first
           shortlists via the index's IVF clusters (``nprobe`` nearest per
           query; ``None`` = all = exact), bounds only the shortlist, and
           computes each later (costlier) bound only on the docs the
           previous stage could not exclude;
        2. *solve* (seed): exact Sinkhorn on the union of each query's k
           best-bounded docs, gathered into a trimmed ELL subset slice;
           the per-query kth-smallest exact distance becomes the pruning
           threshold t_q — any doc with lb > t_q cannot enter the top-k.
           Seed selection and thresholding run device-side (top_k / sort
           on the bound matrices); only compact id arrays reach the host;
        3. *solve* (survivors): exact Sinkhorn on the docs whose bound
           passes t_q (+ ``prune_slack`` fp margin);
        4. *rank*: merge and argsort the exact distances.

        With an admissible bound the result equals the exhaustive top-k
        (indices and distances, up to tie order) while Sinkhorn runs on a
        strict subset of documents — ``result.solved`` reports how strict.
        The guarantee holds for ``"rwmd"`` (and its compositions), which
        bounds the *computed* truncated-Sinkhorn score; ``"wcd"`` alone
        bounds exact EMD and is exact only up to the iteration's
        query-marginal residual vs ``prune_slack`` — near-exact at
        practical ``n_iter``, see :mod:`repro.core.prune`. A cascade at
        ``nprobe < n_clusters`` is *approximate*: un-probed clusters are
        never scored, recall is measured (monotone in ``nprobe``), and a
        query with fewer than k reachable candidates pads its result row
        with ``-1`` / NaN.

        ``mode="refine"`` (rank-then-refine, LC-RWMD style) trades the
        exact-top-k guarantee for a *bounded solve budget*: instead of
        seed-solve + threshold + survivor-solve, every candidate is RANKED
        by the pruner's tightest lower bound and only each query's best
        ``k' = refine_factor * k`` candidates are Sinkhorn-solved; the
        top-k of those exact distances is returned. Exactness contract:

        - every returned *distance* is still the exact (converged /
          truncated per the engine's solve policy) Sinkhorn score — the
          approximation is only in *which* docs get solved;
        - each query is ranked over its OWN k' picks, and pick sets are
          nested in ``refine_factor``, so recall@k against the exact path
          is monotone in ``refine_factor`` for a fixed query batch
          (measured in ``benchmarks/fig13_pareto.py``);
        - once ``k'`` covers the whole candidate universe (``nprobe``
          permitting), the result equals ``mode="exact"`` at the same
          ``nprobe`` — exactly equal to the exhaustive top-k when
          ``nprobe=None`` (up to tie order);
        - ``result.solved`` reports each query's own solved-candidate
          count (<= ``refine_factor * k``), not the chunk union.

        Failure modes: raises :class:`ValueError` for ``k <= 0``, an
        unknown ``mode``/``prune`` spec, ``refine_factor < 1``, or
        ``mode="refine"`` with ``prune=None`` (no bound to rank by);
        raises :class:`~repro.core.sinkhorn.LamUnderflowError` when
        ``exp(-lam * M)`` underflows for a solved pair (impossible under
        ``precision="log"``).
        """
        queries = [np.asarray(q) for q in queries]
        n = self.index.n_docs
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        if mode not in ("exact", "refine"):
            raise ValueError(f"mode must be 'exact' or 'refine', "
                             f"got {mode!r}")
        if mode == "refine":
            if prune is None:
                raise ValueError(
                    "mode='refine' ranks candidates by a pruner's lower "
                    "bound; prune=None has no bound to rank by — use "
                    "mode='exact' for the exhaustive path")
            if int(refine_factor) < 1:
                raise ValueError(f"refine_factor must be >= 1, "
                                 f"got {refine_factor}")
        k = min(int(k), n)
        nq = len(queries)
        out_i = np.full((nq, k), -1, np.int32)
        out_d = np.full((nq, k), np.nan, self.dtype)
        solved = np.zeros(nq, np.int64)
        if nq == 0 or n == 0:
            return SearchResult(out_i, out_d, solved)

        if prune is None:
            d = np.asarray(self.query_batch(queries))
            for qi in range(nq):
                if np.isnan(d[qi]).all():
                    continue                      # empty marginal
                order = np.argsort(d[qi], kind="stable")[:k]
                out_i[qi], out_d[qi] = order, d[qi, order]
                solved[qi] = n
            return SearchResult(out_i, out_d, solved)

        from .prune import CascadePruner, resolve_pruner
        pruner = resolve_pruner(prune, use_kernel=(self.impl == "kernel"),
                                interpret=self.interpret, nprobe=nprobe)
        _, chunks = self._plan(queries)
        if mode == "refine":
            if chunks:
                self._search_refine(queries, k, pruner, nprobe, chunks,
                                    int(refine_factor), out_i, out_d,
                                    solved)
            return SearchResult(out_i, out_d, solved)
        if isinstance(pruner, CascadePruner):
            if chunks:
                self._search_cascade(queries, k, pruner, nprobe, chunks,
                                     out_i, out_d, solved)
            return SearchResult(out_i, out_d, solved)
        for chunk, width in chunks:
            cq = [queries[qi] for qi in chunk]
            qc = len(chunk)
            sup, r, mask = self._prep_chunk(cq, width)
            kq = self._kq(sup, mask)              # shared by both solves

            def solve(doc_ids, qmask=None, stage="seed", warm=None,
                      prof=None):
                # -> ((qc, |ids|) np NaN-checked, warm-start profile)
                grp = self.index.subset(doc_ids, storage=True)
                n_pad = grp.docs.idx.shape[0]
                qm = (None if qmask is None else self._pad_qdoc(
                    qmask, r.shape[0], n_pad))
                pm = (None if prof is None else self._pad_qdoc(
                    prof, r.shape[0], n_pad))
                w, prof_out = self._solve_group(
                    kq, r, mask, grp, n_live=qc, stage=stage, qdoc_mask=qm,
                    x0q=warm, want_profile=True, prof_mask=pm)
                w = np.asarray(w)[:qc, :doc_ids.size]
                self._raise_if_nan(w, cq)
                return w, prof_out

            cand, d_cand = self._prune_full(pruner, sup, r, mask, qc, k,
                                            solve)
            cand_ext = self._ext(cand)       # storage -> caller doc ids
            for ci, qi in enumerate(chunk):
                order = np.argsort(d_cand[ci], kind="stable")[:k]
                out_i[qi, :order.size] = cand_ext[order]
                out_d[qi, :order.size] = d_cand[ci, order]
                solved[qi] = cand.size
        return SearchResult(out_i, out_d, solved)

    @staticmethod
    def _pad_qdoc(qmask: np.ndarray, qp: int, n_pad: int) -> jax.Array:
        """Pad a (qc, |ids|) per-query candidate mask to the solve's
        bucketed (Qp, N_pad) shape (fillers and pad docs are False — they
        are outside every query's residual scope by construction)."""
        out = np.zeros((qp, n_pad), bool)
        out[:qmask.shape[0], :qmask.shape[1]] = qmask
        return jnp.asarray(out)

    def _scoped(self) -> bool:
        """Per-query residual scoping active for this engine's solves?"""
        return self.tol is not None and self.scope == "query"

    def _threshold(self, d_seed_dev, k: int, n_seed: int):
        """Device-side pruning threshold: per-query kth-smallest exact
        distance among the solved seeds (+ fp slack margin). With fewer
        than k solved docs nothing may be excluded yet -> +inf."""
        if n_seed >= k:
            t = jnp.sort(d_seed_dev, axis=1)[:, k - 1]
        else:
            t = jnp.full((d_seed_dev.shape[0],), jnp.inf,
                         d_seed_dev.dtype)
        return t + self.prune_slack * (jnp.abs(t) + 1.0)

    def _prune_full(self, pruner, sup, r, mask, qc, k, solve):
        """PR 2's full-sweep prune stage, with seed selection and
        thresholding moved device-side: (Qc, N) argpartition/partition
        become top_k/sort on the device bound matrix, and only compact id
        arrays (seeds, the survivor bitmap) cross to the host.

        With per-query scoping (ISSUE 5): the SEED solve's residual
        covers the union of real seed docs — any chunkmate's seed can
        contend for any query's top-k once thresholds are known, so its
        distance must be converged for every query that might read it —
        while each query still FREEZES individually (the win). The
        query's OWN k picks drive only its warm-start profile; the
        threshold keeps PR 2's chunk-union tightening (every seed
        distance is now converged for every query, so it is sound). The
        SURVIVOR solve's residual narrows further, to the docs whose
        bound passed that query's threshold — a survivor outside that
        scope is admissibly excluded from its top-k at any truncation
        (RWMD lower-bounds the computed score, so its unconverged value
        stays above the threshold)."""
        from .prune import _keep_any
        scoped = self._scoped()
        lb = pruner.lower_bounds(self.index, sup, r, mask)   # (Qp, N) dev
        # seed: each query's k best-bounded docs (chunk union — extra
        # exact distances only tighten the other queries' thresholds)
        _, seed_pos = jax.lax.top_k(-lb[:qc], k)
        seed_pos = np.asarray(seed_pos)
        seed = np.unique(seed_pos).astype(np.int32)
        qmask_seed = None
        if scoped:
            qmask_seed = np.stack([np.isin(seed, seed_pos[qi])
                                   for qi in range(qc)])
        d_seed, xprof = solve(seed, None, "seed", prof=qmask_seed)
        thresh = self._threshold(jnp.asarray(d_seed), k, seed.size)
        surv = np.nonzero(np.asarray(_keep_any(lb, thresh)))[0] \
            .astype(np.int32)
        surv = surv[~np.isin(surv, seed)]
        cand = np.concatenate([seed, surv])
        if not surv.size:
            return cand, d_seed
        qmask_surv = None
        if scoped:
            qmask_surv = (np.asarray(lb[:qc, surv])
                          <= np.asarray(thresh)[:qc, None])
        warm = xprof if (self.warm_start and self.tol is not None) else None
        d_surv, _ = solve(surv, qmask_surv, "survivor", warm=warm)
        return cand, np.concatenate([d_seed, d_surv], axis=1)

    def _make_solver(self, queries, chunks, live_q):
        """Stage every v_r chunk once (sup/r/mask + the kq pair) and
        return ``solve_all(doc_ids, qmask, stage, warm, prof)`` — the
        chunk-looped exact solve over one candidate id array, shared by
        the cascade and refine drivers. Rows of the returned (qg, |ids|)
        matrix follow ``live_q`` order; NaN rows raise
        :class:`LamUnderflowError` before returning."""
        index = self.index
        qg = len(live_q)
        row_of = {qi: g for g, qi in enumerate(live_q)}
        prepped = []
        for chunk, width in chunks:
            cq = [queries[qi] for qi in chunk]
            sup, r, mask = self._prep_chunk(cq, width)
            prepped.append((chunk, cq, sup, r, mask, self._kq(sup, mask)))

        def solve_all(doc_ids, qmask=None, stage="seed", warm=None,
                      prof=None):
            # -> ((qg, |ids|) np NaN-checked, per-chunk warm profiles)
            out = np.empty((qg, doc_ids.size), self.dtype)
            profs = []
            # one gather, shared by chunks; survivor ids are cluster-sorted
            # storage ids, so this is a near-contiguous host slice
            grp = index.subset(doc_ids, storage=True)
            n_pad = grp.docs.idx.shape[0]
            for ci, (chunk, cq, sup, r, mask, kq) in enumerate(prepped):
                rows = [row_of[qi] for qi in chunk]
                qm = (None if qmask is None else self._pad_qdoc(
                    qmask[rows], r.shape[0], n_pad))
                pm = (None if prof is None else self._pad_qdoc(
                    prof[rows], r.shape[0], n_pad))
                w, xp = self._solve_group(
                    kq, r, mask, grp, n_live=len(chunk), stage=stage,
                    qdoc_mask=qm, x0q=None if warm is None else warm[ci],
                    want_profile=True, prof_mask=pm)
                profs.append(xp)
                w = np.asarray(w)[:len(chunk), :doc_ids.size]
                self._raise_if_nan(w, cq)
                out[rows] = w
            return out, profs

        return solve_all

    def _search_refine(self, queries, k, pruner, nprobe, chunks,
                       refine_factor, out_i, out_d, solved):
        """Rank-then-refine driver (``mode="refine"``): ONE bound pass
        ranks the whole candidate universe, then exactly one solve covers
        the union of each query's top ``k' = refine_factor * k`` picks.

        Ranking bound: a cascade's TIGHTEST stage (its last — RWMD in the
        default specs) over the probed clusters' members; a full-sweep
        pruner's own bound over every doc. Each query is ranked over its
        OWN picks only, so pick sets are nested in ``refine_factor`` and
        recall against the exact path is monotone; at a ``k'`` covering
        the candidate universe this IS the exact path's answer (every
        candidate solved, ranked by exact distance)."""
        from .prune import CascadePruner, _pad_pow2_ids
        index = self.index
        live_q = [qi for chunk, _ in chunks for qi in chunk]
        qg = len(live_q)
        width_g = max(width for _, width in chunks)
        sup_g, r_g, mask_g = self._prep_chunk(
            [queries[qi] for qi in live_q], width_g)
        if isinstance(pruner, CascadePruner):
            cdists, pm, qcent = pruner.probe(index, sup_g, r_g, mask_g,
                                             nprobe)
            # candidate universe = union of probed clusters' members
            # (every cluster when pm is None — the exhaustive probe)
            keep_c = (np.ones(index.clusters.n_clusters, bool)
                      if pm is None else np.asarray(pm)[:qg].any(axis=0))
            cand = pruner.cluster_members(index, keep_c)
            if cand.size == 0:
                return
            sp = _pad_pow2_ids(cand)
            lb = pruner.stage_bounds(
                pruner.stages[-1], index, sup_g, r_g, mask_g, sp,
                cand.size,
                pruner.id_qmask(index, pm, sp, cand.size,
                                qp=sup_g.shape[0]), qcent=qcent)
        else:
            cand = np.arange(index.n_docs, dtype=np.int32)
            sp = cand
            lb = pruner.lower_bounds(index, sup_g, r_g, mask_g)
        kp = min(refine_factor * k, cand.size)
        neg, pos = jax.lax.top_k(-lb[:qg], kp)
        neg, pos = np.asarray(neg), np.asarray(pos)
        # per-query own picks; -inf bounds are non-candidates (a query
        # whose probed universe holds fewer than k' docs)
        own = []
        for g in range(qg):
            p = pos[g][np.isfinite(neg[g])]
            p = p[p < cand.size]
            own.append(np.unique(sp[p]).astype(np.int32))
        ids = np.unique(np.concatenate(own))
        if ids.size == 0:
            return
        qmask_own = np.stack([np.isin(ids, o) for o in own])
        solve_all = self._make_solver(queries, chunks, live_q)
        d, _ = solve_all(ids, qmask_own if self._scoped() else None,
                         "refine")
        # rank each query over its OWN picks only — batch-mates' union
        # candidates are excluded so the pick-set nesting (and with it
        # the recall monotonicity) holds per query, not just per batch
        dm = np.where(qmask_own, d, np.inf)
        ids_ext = self._ext(ids)
        for g, qi in enumerate(live_q):
            n_own = int(qmask_own[g].sum())
            order = np.argsort(dm[g], kind="stable")[:min(k, n_own)]
            out_i[qi, :order.size] = ids_ext[order]
            out_d[qi, :order.size] = d[g, order]
            solved[qi] = n_own

    def _search_cascade(self, queries, k, pruner, nprobe, chunks,
                        out_i, out_d, solved):
        """CascadePruner driver — sub-O(N) per-doc prune work, ONE global
        prune pass for the whole query set:

        The bound stages don't need the solve's v_r bucketing (they read
        the (Q, B) support arrays directly), so all live queries are staged
        once at the widest chunk's bucket and every prune dispatch covers
        the full set — per-chunk pruning would pay the fixed dispatch
        chain per v_r bucket for no extra precision. Flow:

        1. cluster probe (one (Q, C) GEMM) + seed candidates from each
           query's nearest probed clusters (just enough to cover k docs);
        2. first-stage bounds on the seed candidates -> per-query best-k
           seeds -> exact seed solve (per solve chunk) -> threshold t_q;
        3. ``pruner.survivors``: cluster-radius triangle bound drops whole
           clusters, then the per-doc stages cheapest-first on what
           remains;
        4. exact solve on the final survivors, rank.
        """
        from .prune import _pad_pow2_ids
        index = self.index
        live_q = [qi for chunk, _ in chunks for qi in chunk]
        qg = len(live_q)
        width_g = max(width for _, width in chunks)
        sup_g, r_g, mask_g = self._prep_chunk(
            [queries[qi] for qi in live_q], width_g)
        cdists, pm, qcent = pruner.probe(index, sup_g, r_g, mask_g, nprobe)
        seed_cand = pruner.seed_candidates(index, cdists, mask_g, k, pm)
        if seed_cand.size == 0:
            return
        sp = _pad_pow2_ids(seed_cand)
        lb = pruner.stage_bounds(
            pruner.stages[0], index, sup_g, r_g, mask_g, sp,
            seed_cand.size,
            pruner.id_qmask(index, pm, sp, seed_cand.size,
                            qp=sup_g.shape[0]), qcent=qcent)
        k_eff = min(k, seed_cand.size)
        neg, seed_pos = jax.lax.top_k(-lb[:qg], k_eff)
        neg = np.asarray(neg)
        seed_pos = np.asarray(seed_pos)
        # -inf picks are non-candidates (a query with < k_eff candidates)
        pos_seed = np.unique(seed_pos[np.isfinite(neg)])
        pos_seed = pos_seed[pos_seed < seed_cand.size]
        if pos_seed.size == 0:
            return
        seed = sp[pos_seed]
        scoped = self._scoped()
        qmask_seed = None
        if scoped:
            # per-query seed membership: q's own finite top-k picks
            qmask_seed = np.zeros((qg, seed.size), bool)
            for g in range(qg):
                own = seed_pos[g][np.isfinite(neg[g])]
                own = own[own < seed_cand.size]
                qmask_seed[g] = np.isin(seed, sp[own])

        # solve stage stays v_r-bucketed: per-chunk staging, reused for
        # the seed and survivor solves
        solve_all = self._make_solver(queries, chunks, live_q)

        # seed residual scope = the union of real seed docs (any of them
        # can contend for any query once thresholds exist); own picks
        # drive only the warm profile — see _prune_full
        d_seed, xprofs = solve_all(seed, None, "seed", prof=qmask_seed)
        thresh = self._threshold(jnp.asarray(d_seed), k, seed.size)
        surv = pruner.survivors(index, sup_g, r_g, mask_g, cdists, pm,
                                qcent, thresh, exclude=seed)
        cand = np.concatenate([seed, surv])
        if surv.size:
            qmask_surv = None
            if scoped:
                # per-query survivor membership: re-bound the FINAL
                # survivor set with the cascade's tightest stage (one
                # extra fused dispatch on the post-prune set) against
                # each query's own threshold
                from .prune import _pad_pow2_ids as _pp2
                sps = _pp2(surv)
                lbs = pruner.stage_bounds(
                    pruner.stages[-1], index, sup_g, r_g, mask_g, sps,
                    surv.size,
                    pruner.id_qmask(index, pm, sps, surv.size,
                                    qp=sup_g.shape[0]), qcent=qcent)
                qmask_surv = (np.asarray(lbs[:qg, :surv.size])
                              <= np.asarray(thresh)[:qg, None])
            warm = (xprofs if (self.warm_start and self.tol is not None)
                    else None)
            d_surv, _ = solve_all(surv, qmask_surv, "survivor", warm=warm)
            d_cand = np.concatenate([d_seed, d_surv], axis=1)
        else:
            d_cand = d_seed
        cand_ext = self._ext(cand)           # storage -> caller doc ids
        for g, qi in enumerate(live_q):
            order = np.argsort(d_cand[g], kind="stable")[:k]
            out_i[qi, :order.size] = cand_ext[order]
            out_d[qi, :order.size] = d_cand[g, order]
            solved[qi] = cand.size
