"""Batched multi-query WMD engine: persistent corpus index + bucketed solves.

The paper's motivating scenario ("finding whether a given tweet is similar to
any other tweets happened in a day") is *many* queries against one shared
corpus, but a per-query loop over :func:`repro.core.wmd.one_to_many` re-ships
the vocabulary embeddings to the device, re-reduces their norms, and re-jits
for every distinct query support size ``v_r`` — the naive-baseline shape the
paper gets its 700x over. This module keeps the corpus side *resident* and
batches the query side:

``CorpusIndex``
    Freezes everything query-independent exactly once: the ELL document
    collection (``docs.idx/val``), the vocabulary embeddings, and the
    per-word squared norms that form the corpus half of the ``cdist`` GEMM.
    Documents are also nnz-sorted and split into width-trimmed
    :class:`DocGroup` slices (ELL row grouping), so the per-query solve
    never touches padding slots shorter docs don't have — a one-time cost
    at build that every subsequent query amortizes. Every query after the
    first touches none of this again.

``WmdEngine``
    Shape-buckets incoming queries to a small set of power-of-two ``v_r``
    sizes (padded query rows carry ``r = 1, G = 0`` — the established
    padding contract of :mod:`repro.kernels.sddmm_spmm`, proven inert by the
    kernel tests), stacks each bucket into one ``(Q, v_r, ...)`` problem and
    runs the solver ONCE per bucket: the per-query ``(v_r, V)`` cdist
    becomes a single ``(Q*v_r, V)`` GEMM, the Sinkhorn loop runs as one
    batched einsum or one Pallas launch with a query grid dimension
    (:func:`repro.kernels.sddmm_spmm.sinkhorn_fused_all_batched`), and jit
    caching collapses to one executable per bucket shape instead of one per
    distinct ``v_r``. GM is reconstructed from G everywhere (never
    materialized), so the per-bucket footprint is two nnz-sized arrays.

``WmdEngine.search`` (the staged retrieval pipeline, ISSUE 2)
    The paper's motivating workload is top-k retrieval, and exhaustive
    scoring does asymptotically too much work for it: ``search(queries, k)``
    runs *prune -> solve -> rank*. A cheap admissible lower bound from
    :mod:`repro.core.prune` (WCD / doc-side RWMD) scores every (query, doc)
    pair first; the Sinkhorn solve then runs only on (a) the k best-bounded
    seed docs and (b) the docs whose bound cannot be excluded by the kth
    seed distance — gathered out of the frozen index into a trimmed ELL
    subset slice. With an admissible bound the returned top-k equals the
    exhaustive one exactly; ``prune=None`` reproduces exhaustive
    ``query_batch`` + argsort bit-for-bit.

Typical use::

    index = build_index(corpus.docs, corpus.vecs)
    engine = WmdEngine(index, lam=9.0, n_iter=15, impl="sparse")
    dists = engine.query_batch(queries)            # (Q, N) exhaustive
    res = engine.search(queries, k=10)             # pruned top-k
    index2 = append_docs(index, more_docs)         # streaming, no rebuild
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .sinkhorn import LamUnderflowError, underflow_report
from .sinkhorn_sparse import reconstruct_gm
from .sparse import PaddedDocs

ENGINE_IMPLS = ("sparse", "kernel")


class DocGroup(NamedTuple):
    """One length-homogeneous slice of the corpus, ELL-trimmed to its own
    max word count (classic ELL row-grouping: the solver never multiplies
    padding slots a shorter doc group doesn't have)."""

    docs: PaddedDocs    # idx/val (N_g, L_g), L_g = group max words
    cols: jax.Array     # (N_g,) original doc positions (for reassembly)


class IvfClusters(NamedTuple):
    """Frozen IVF coarse quantizer over the per-doc WCD centroids.

    k-means runs ONCE at :func:`build_index` (mini-batch Lloyd, device-side);
    :func:`append_docs` assigns new docs to the nearest existing center
    without touching the clustering — centers are reused by identity, only
    the host-side membership arrays (and the grown clusters' radii) change.
    The cluster structure powers the :class:`~repro.core.prune.CascadePruner`
    cascade twice: the (Q, n_clusters) probe GEMM replaces the (Q, N) sweep
    for candidate generation, and ``radii`` gives a *cluster-level* lower
    bound ``||qcent - center_c|| - radius_c <= wcd(q, n)`` for every member
    n (triangle inequality; Werner & Laber-style), so whole clusters are
    excluded against the pruning threshold without touching their docs.
    """

    centers: jax.Array   # (C, w) cluster centers, device-resident
    assign: np.ndarray   # (N,) host: cluster id per doc
    order: np.ndarray    # (N,) host: doc ids sorted by cluster id
    starts: np.ndarray   # (C + 1,) host: cluster c owns order[starts[c]:
    #                      starts[c + 1]] — contiguous shortlist slices
    radii: np.ndarray    # (C,) host: max ||center_c - centroid_n|| over
    #                      members (cluster-level bound; grows on append)
    assign_dev: jax.Array  # (N,) device mirror of ``assign`` (the dense
    #                        prune pass looks up doc -> probed cluster)

    @property
    def n_clusters(self) -> int:
        return self.centers.shape[0]

    @property
    def sizes(self) -> np.ndarray:
        return np.diff(self.starts)


@jax.jit
def _assign_clusters(points: jax.Array, centers: jax.Array) -> jax.Array:
    """Nearest-center assignment for one mini-batch: (B, w) -> (B,)."""
    d2 = (jnp.sum(points * points, axis=1)[:, None]
          + jnp.sum(centers * centers, axis=1)[None, :]
          - 2.0 * (points @ centers.T))
    return jnp.argmin(d2, axis=1)


@jax.jit
def _kmeans_accum(points: jax.Array, centers: jax.Array):
    """One mini-batch's contribution to the Lloyd update: per-center
    coordinate sums + member counts (one-hot GEMM, stays on device)."""
    onehot = jax.nn.one_hot(_assign_clusters(points, centers),
                            centers.shape[0], dtype=points.dtype)
    return onehot.T @ points, jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("c",))
def _farthest_point_init(points: jax.Array, c: int, start) -> jax.Array:
    """Maxmin (farthest-point) seeding: each new center is the point
    farthest from all chosen so far. Deterministic, device-side, O(C*N*w)
    once at build — spreads centers across the corpus' actual modes (a
    random draw lands several centers in one dense mode and none in small
    ones, which inflates cluster radii and blunts the triangle bound)."""
    mind = jnp.sum((points - points[start]) ** 2, axis=1)
    centers = jnp.zeros((c, points.shape[1]), points.dtype)
    centers = centers.at[0].set(points[start])

    def body(i, carry):
        centers, mind = carry
        cen = points[jnp.argmax(mind)]
        centers = centers.at[i].set(cen)
        return centers, jnp.minimum(mind, jnp.sum((points - cen) ** 2,
                                                  axis=1))

    centers, _ = lax.fori_loop(1, c, body, (centers, mind))
    return centers


def _kmeans(centroids: jax.Array, n_clusters: int, n_iters: int = 10,
            batch: int = 4096, seed: int = 0, init_sample: int = 65536):
    """Mini-batch Lloyd k-means over the doc centroids, device-side.

    Farthest-point init (on an ``init_sample``-capped subset at corpus
    scale), then each Lloyd iteration streams the (N, w) centroid matrix
    through :func:`_kmeans_accum` in ``batch``-sized slices (the (B, C)
    one-hot and the assignment cdist never exceed a mini-batch) and applies
    one exact update; empty clusters keep their previous center.
    Deterministic in ``seed``. Returns (centers (C, w), assign host (N,)).
    """
    n = centroids.shape[0]
    rng = np.random.default_rng(seed)
    pool = centroids
    if n > init_sample:
        keep = np.sort(rng.choice(n, size=init_sample, replace=False))
        pool = jnp.take(centroids, jnp.asarray(keep, jnp.int32), axis=0)
    centers = _farthest_point_init(pool, n_clusters,
                                   int(rng.integers(pool.shape[0])))
    for _ in range(n_iters):
        sums = jnp.zeros_like(centers)
        counts = jnp.zeros((n_clusters,), centers.dtype)
        for lo in range(0, n, batch):
            s, c = _kmeans_accum(centroids[lo:lo + batch], centers)
            sums, counts = sums + s, counts + c
        centers = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts, 1.0)[:, None],
                            centers)
    assign = np.concatenate([
        np.asarray(_assign_clusters(centroids[lo:lo + batch], centers))
        for lo in range(0, n, batch)]).astype(np.int32)
    return centers, assign


def _membership(assign: np.ndarray, n_clusters: int):
    """(order, starts) from an assignment: cluster c's docs are the
    contiguous slice order[starts[c]:starts[c + 1]]."""
    order = np.argsort(assign, kind="stable").astype(np.int32)
    starts = np.searchsorted(assign[order],
                             np.arange(n_clusters + 1)).astype(np.int64)
    return order, starts


def _member_dists(centroids, centers, assign: np.ndarray,
                  chunk: int = 4096) -> np.ndarray:
    """(N,) host distances from each doc centroid to its assigned center."""
    n = assign.shape[0]
    out = np.empty(n, np.float64)
    assign_dev = jnp.asarray(assign.astype(np.int32))
    for lo in range(0, n, chunk):
        own = jnp.take(centers, assign_dev[lo:lo + chunk], axis=0)
        d = jnp.linalg.norm(centroids[lo:lo + chunk] - own, axis=1)
        out[lo:lo + chunk] = np.asarray(d, np.float64)
    return out


def _cluster_radii(centroids, centers, assign: np.ndarray,
                   n_clusters: int) -> np.ndarray:
    """(C,) max member distance per cluster (0 for empty clusters)."""
    radii = np.zeros(n_clusters, np.float64)
    if assign.size:
        np.maximum.at(radii, assign, _member_dists(centroids, centers,
                                                   assign))
    return radii


def default_n_clusters(n_docs: int) -> int:
    """sqrt(N) coarse-quantizer heuristic (classic IVF sizing)."""
    return max(1, min(n_docs, int(round(float(np.sqrt(max(n_docs, 1)))))))


class CorpusIndex(NamedTuple):
    """Query-independent corpus state, frozen once and reused forever."""

    docs: PaddedDocs     # full ELL corpus: idx (N, L) int32, val (N, L)
    groups: tuple        # tuple[DocGroup, ...] — nnz-sorted, width-trimmed
    vecs: jax.Array      # (V, w) vocabulary embeddings, device-resident
    vecs_sq: jax.Array   # (V,) per-word |b|^2 — corpus half of the cdist GEMM
    centroids: jax.Array  # (N, w) per-doc mass centroids (WCD prune stage)
    docs_host: PaddedDocs  # np mirror of ``docs`` — candidate staging reads
    #                        row slices host-side without a full D2H copy
    clusters: IvfClusters = None  # IVF coarse quantizer over the centroids
    #                               (the CascadePruner's shortlist stage)

    @property
    def n_docs(self) -> int:
        return self.docs.idx.shape[0]

    @property
    def vocab_size(self) -> int:
        return self.vecs.shape[0]

    @property
    def embed_dim(self) -> int:
        return self.vecs.shape[1]

    def subset(self, doc_ids) -> DocGroup:
        """Candidate-subset slice for the solve stage: gather ``doc_ids``
        out of the full ELL corpus into one width-trimmed :class:`DocGroup`
        (slots are front-compacted at build, so trimming to the subset's
        max nnz loses nothing). Gathers from the host mirror — candidate
        sets are small post-prune and change per query chunk, so they are
        staged like queries: O(|doc_ids| * L) work, one small H2D upload,
        no device round-trip.

        Shapes are BUCKETED like the query side (doc count padded to a
        power of two with inert all-zero docs, ELL width to a multiple of
        8): candidate counts are data-dependent per search step and would
        otherwise compile a fresh solver executable per step under serving
        traffic. ``cols`` keeps only the real ids — consumers slice the
        solve output to ``cols.shape[0]`` columns."""
        doc_ids = np.asarray(doc_ids, np.int32)
        idx = self.docs_host.idx[doc_ids]
        val = self.docs_host.val[doc_ids]
        lg = max(1, int((val > 0).sum(axis=1).max(initial=0)))
        lg = min(-(-lg // 8) * 8, idx.shape[1])
        n_pad = 8
        while n_pad < doc_ids.size:
            n_pad *= 2
        pad = ((0, n_pad - doc_ids.size), (0, 0))
        return DocGroup(docs=PaddedDocs(
            idx=jnp.asarray(np.pad(idx[:, :lg], pad)),
            val=jnp.asarray(np.pad(val[:, :lg], pad))),
            cols=jnp.asarray(doc_ids))


def _compact_slots(docs: PaddedDocs, dtype):
    """Host copies with live slots compacted to the front (front-filled is
    the builders' contract, but cheap to enforce for arbitrary inputs)."""
    idx_np = np.asarray(docs.idx, np.int32)
    val_np = np.asarray(docs.val, dtype)
    slot_order = np.argsort(~(val_np > 0), axis=1, kind="stable")
    return (np.take_along_axis(idx_np, slot_order, 1),
            np.take_along_axis(val_np, slot_order, 1))


def _doc_centroids(idx_np, val_np, vecs_np, chunk: int = 2048):
    """Per-doc mass centroids sum_l val[n,l] * vecs[idx[n,l]] — the frozen
    corpus half of the WCD prune stage. Chunked so the (n, L, w) gather
    intermediate stays small at corpus scale."""
    n = idx_np.shape[0]
    out = np.empty((n, vecs_np.shape[1]), vecs_np.dtype)
    for lo in range(0, max(n, 1), chunk):
        hi = min(lo + chunk, n)
        out[lo:hi] = np.einsum("nl,nlw->nw", val_np[lo:hi],
                               vecs_np[idx_np[lo:hi]])
    return out


def build_index(docs: PaddedDocs, vecs, dtype=jnp.float32,
                doc_groups: int = 4, n_clusters: int | None = None,
                ivf_iters: int = 10, ivf_seed: int = 0) -> CorpusIndex:
    """Freeze the corpus side: device-resident docs + embeddings + norms +
    per-doc centroids (the WCD prune stage's corpus half) + the IVF coarse
    quantizer over those centroids (the cascade's shortlist stage).

    Documents are additionally sorted by nnz and split into ``doc_groups``
    equal-count groups, each trimmed to its own max word count — the
    per-query solve work drops by the corpus' ELL padding fraction, paid
    once here instead of on every query. ``n_clusters`` defaults to the
    sqrt(N) IVF heuristic; clustering runs mini-batch Lloyd on device and
    is frozen afterwards (:func:`append_docs` only assigns).
    """
    vecs = jnp.asarray(vecs, dtype)
    vecs_np = np.asarray(vecs)
    idx_np, val_np = _compact_slots(docs, dtype)
    nnz = (val_np > 0).sum(1)
    order = np.argsort(nnz, kind="stable")
    n = max(1, len(order))
    gsz = -(-n // max(1, doc_groups))
    groups = []
    for lo in range(0, len(order), gsz):
        sel = order[lo:lo + gsz]
        lg = max(1, int(nnz[sel].max(initial=0)))
        groups.append(DocGroup(
            docs=PaddedDocs(idx=jnp.asarray(idx_np[sel][:, :lg]),
                            val=jnp.asarray(val_np[sel][:, :lg])),
            cols=jnp.asarray(sel.astype(np.int32))))
    centroids = jnp.asarray(_doc_centroids(idx_np, val_np, vecs_np))
    n_docs = idx_np.shape[0]
    if n_clusters is None:
        n_clusters = default_n_clusters(n_docs)
    n_clusters = max(1, min(int(n_clusters), max(n_docs, 1)))
    if n_docs:
        centers, assign = _kmeans(centroids, n_clusters, n_iters=ivf_iters,
                                  seed=ivf_seed)
    else:
        centers = jnp.zeros((n_clusters, vecs.shape[1]), dtype)
        assign = np.zeros((0,), np.int32)
    c_order, c_starts = _membership(assign, n_clusters)
    radii = _cluster_radii(centroids, centers, assign, n_clusters)
    return CorpusIndex(docs=PaddedDocs(idx=jnp.asarray(idx_np),
                                       val=jnp.asarray(val_np)),
                       groups=tuple(groups), vecs=vecs,
                       vecs_sq=jnp.sum(vecs * vecs, axis=1),
                       centroids=centroids,
                       docs_host=PaddedDocs(idx=idx_np, val=val_np),
                       clusters=IvfClusters(centers=centers, assign=assign,
                                            order=c_order, starts=c_starts,
                                            radii=radii,
                                            assign_dev=jnp.asarray(assign)))


def _pad_width(a, width: int):
    """Right-pad axis 1 with zeros; np in -> np out, jax in -> jax out."""
    if a.shape[1] >= width:
        return a
    pads = ((0, 0), (0, width - a.shape[1]))
    return (jnp.pad(a, pads) if isinstance(a, jax.Array)
            else np.pad(a, pads))


def append_docs(index: CorpusIndex, new_docs: PaddedDocs,
                dtype=jnp.float32) -> CorpusIndex:
    """Streaming index update: add documents WITHOUT a full rebuild.

    The new docs join the group with the fewest members (widened only if
    they are longer than its current ELL trim); every other group's arrays
    are reused as-is — no re-sort, no re-gather, no centroid recompute for
    existing docs. New docs get ids ``[n_docs, n_docs + n_new)``.
    ``search``/``query_batch`` after an append match a from-scratch
    ``build_index`` exactly: per-doc solves are independent and grouping /
    ELL padding are inert (proven by the engine tests).

    IVF clusters are FROZEN: the new docs are assigned to their nearest
    existing center (no re-clustering — ``centers`` is reused by identity)
    and only the host-side membership arrays are rebuilt. Exact search
    (``nprobe = n_clusters``) is unaffected; smaller-``nprobe`` recall
    degrades only as far as the frozen centers drift from the grown
    corpus — rebuild when that matters.
    """
    n_new = new_docs.idx.shape[0]
    if n_new == 0:
        return index
    new_idx, new_val = _compact_slots(new_docs, dtype)
    if int(new_idx.max(initial=0)) >= index.vocab_size:
        raise ValueError("new docs reference word ids outside the index "
                         f"vocabulary ({index.vocab_size})")
    nnz = (new_val > 0).sum(1)
    lg_new = max(1, int(nnz.max(initial=0)))
    new_idx, new_val = new_idx[:, :lg_new], new_val[:, :lg_new]
    n_old = index.n_docs

    # full ELL corpus: widen whichever side is narrower, then concat — the
    # device side on-device and the host mirror on-host, so only the NEW
    # docs ever cross the device boundary
    width = max(index.docs.idx.shape[1], lg_new)
    docs = PaddedDocs(
        idx=jnp.concatenate([_pad_width(index.docs.idx, width),
                             jnp.asarray(_pad_width(new_idx, width))]),
        val=jnp.concatenate([_pad_width(index.docs.val, width),
                             jnp.asarray(_pad_width(new_val, width))]))
    docs_host = PaddedDocs(
        idx=np.concatenate([_pad_width(index.docs_host.idx, width),
                            _pad_width(new_idx, width)]),
        val=np.concatenate([_pad_width(index.docs_host.val, width),
                            _pad_width(new_val, width)]))

    # grow only the smallest group; all others are reused untouched
    gi = int(np.argmin([g.cols.shape[0] for g in index.groups]))
    grp = index.groups[gi]
    gw = max(grp.docs.idx.shape[1], lg_new)
    grown = DocGroup(
        docs=PaddedDocs(
            idx=jnp.concatenate([_pad_width(grp.docs.idx, gw),
                                 jnp.asarray(_pad_width(new_idx, gw))]),
            val=jnp.concatenate([_pad_width(grp.docs.val, gw),
                                 jnp.asarray(_pad_width(new_val, gw))])),
        cols=jnp.concatenate([grp.cols,
                              jnp.arange(n_old, n_old + n_new,
                                         dtype=jnp.int32)]))
    groups = tuple(grown if i == gi else g
                   for i, g in enumerate(index.groups))

    cent_new = _doc_centroids(new_idx, new_val, np.asarray(index.vecs))
    clusters = index.clusters
    if clusters is not None:
        cent_new_dev = jnp.asarray(cent_new)
        assign_new = np.asarray(
            _assign_clusters(cent_new_dev,
                             clusters.centers)).astype(np.int32)
        assign = np.concatenate([clusters.assign, assign_new])
        c_order, c_starts = _membership(assign, clusters.n_clusters)
        # frozen centers: only the grown clusters' radii can expand
        radii = clusters.radii.copy()
        np.maximum.at(radii, assign_new,
                      _member_dists(cent_new_dev, clusters.centers,
                                    assign_new))
        clusters = clusters._replace(assign=assign, order=c_order,
                                     starts=c_starts, radii=radii,
                                     assign_dev=jnp.asarray(assign))
    return index._replace(
        docs=docs, groups=groups, docs_host=docs_host,
        centroids=jnp.concatenate([index.centroids,
                                   jnp.asarray(cent_new)]),
        clusters=clusters)


def bucket_size(v_r: int, min_bucket: int = 8) -> int:
    """Smallest power-of-two bucket (>= min_bucket) holding v_r query rows."""
    b = max(1, int(min_bucket))
    while b < v_r:
        b *= 2
    return b


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def _solve_batched_einsum(g, val, r, mask, lam, n_iter):
    """Batched ELL Sinkhorn + distance line in the CPU/XLA-friendly layout.

    g (Q, N, L, B): query rows on the MINOR axis, so both contractions are
    contiguous per-(doc, query) tiles — measured ~4x faster per live row
    than the (Q, B, N, L) order whose k-reduction strides by N*L. Only ONE
    G tensor is kept: diag(1/r) is folded into the x-update (r is constant
    per row) instead of materializing G_over_r, halving resident bytes.
    val (N, L); r, mask (Q, B); padded rows (G == 0, r == 1) are inert.
    Returns wmd (Q, N).
    """
    q, n, length, b = g.shape
    live = val > 0                                      # (N, L)
    rinv = _safe_inv(r)[:, None, :]                     # (Q, 1, B)
    denom = jnp.sum(mask, axis=1, keepdims=True)
    x0 = jnp.where(mask > 0, 1.0 / jnp.maximum(denom, 1.0), 0.0)
    x = jnp.broadcast_to(x0[:, None, :], (q, n, b))

    # pad rows keep x == 0 exactly (their G is 0), so a single x > 0 guard
    # on u suffices — the untaken 1/0 branch yields inf which the select
    # discards; live-entry arithmetic matches the per-query oracle's.
    def body(x, _):
        u = jnp.where(x > 0, 1.0 / x, 0.0)
        t = jnp.einsum("qnlb,qnb->qnl", g, u)           # SDDMM
        w = jnp.where(live[None], val[None] / t, 0.0)
        x = jnp.einsum("qnlb,qnl->qnb", g, w) * rinv    # SpMM (fused)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = jnp.where(x > 0, 1.0 / x, 0.0)
    t = jnp.einsum("qnlb,qnb->qnl", g, u)
    w = jnp.where(live[None], val[None] / t, 0.0)
    return jnp.einsum("qnb,qnlb,qnl->qn", u, reconstruct_gm(g, lam), w)


@functools.partial(jax.jit, static_argnames=("lam",))
def _compute_kq(sup: jax.Array, mask: jax.Array, vecs: jax.Array,
                vecs_sq: jax.Array, lam: float) -> jax.Array:
    """Stacked cdist GEMM -> K for one query chunk: (Q, B) ids -> (Q, V, B).

    One (V, Q*B) GEMM replaces Q separate (v_r, V) cdists. The TRANSPOSED
    orientation makes the subsequent doc-word gathers copy contiguous rows
    instead of striding over the vocab axis; the reorder to (Q, V, B)
    happens on this SMALL matrix, never on the Q*N*L*B gather output.
    Padded rows (mask == 0) come out as all-zero K columns (G == 0).
    """
    q, b = sup.shape
    a = jnp.take(vecs, sup, axis=0)                     # (Q, B, w)
    a2 = jnp.sum(a * a, axis=-1)                        # (Q, B)
    ab = vecs @ a.reshape(q * b, -1).T                  # (V, Q*B)
    d2 = jnp.maximum(vecs_sq[:, None] + a2.reshape(1, -1) - 2.0 * ab, 0.0)
    kt = jnp.exp(-lam * jnp.sqrt(d2)) * mask.reshape(1, -1)
    return jnp.transpose(kt.reshape(-1, q, b), (1, 0, 2))    # (Q, V, B)


@functools.partial(jax.jit, static_argnames=("layout",))
def _gather_g(kq: jax.Array, idx: jax.Array, layout: str = "qnlb"):
    """Gather doc-word columns of K: (Q, V, B) x (N, L) -> G.

    Kept as its own jit (with :func:`_compute_kq` separate too): XLA CPU
    otherwise fuses the exp/sqrt producer INTO the gather and recomputes it
    per gathered element (~2.4x slower end to end); on TPU the boundary is
    where the engine hands off to the Mosaic kernel anyway.
    """
    if layout == "qbnl":
        # TPU tile layout: (v_r, block_n, L) per query, sublane = query rows
        return jnp.take(jnp.transpose(kq, (0, 2, 1)), idx, axis=2)
    return jnp.take(kq, idx, axis=1)                         # (Q, N, L, B)


_solve_gathered = jax.jit(_solve_batched_einsum,
                          static_argnames=("lam", "n_iter"))


def _prepare_query(q, bucket: int, dtype):
    """Host-side support selection + bucket padding for one query row."""
    q = np.asarray(q, dtype=np.float64).reshape(-1)
    idx = np.nonzero(q > 0)[0]
    v_r = idx.size
    if v_r > bucket:
        raise ValueError(f"query v_r={v_r} exceeds bucket {bucket}")
    sup = np.zeros(bucket, np.int32)
    sup[:v_r] = idx
    r = np.ones(bucket, dtype)                # pad rows carry r == 1
    r[:v_r] = (q[idx] / q[idx].sum()).astype(dtype)
    mask = np.zeros(bucket, dtype)
    mask[:v_r] = 1.0
    return sup, r, mask


class SearchResult(NamedTuple):
    """Top-k retrieval result from :meth:`WmdEngine.search`.

    Rows for empty queries (no support) hold ``indices == -1`` and NaN
    distances. ``solved`` counts the documents that went through the exact
    Sinkhorn solve for each query — ``n_docs`` when exhaustive, the
    surviving-candidate count when pruned.
    """

    indices: np.ndarray    # (Q, k) int32 doc ids, ascending distance
    distances: np.ndarray  # (Q, k)
    solved: np.ndarray     # (Q,) int64 exact solves per query


class WmdEngine:
    """Persistent multi-query WMD engine over a frozen :class:`CorpusIndex`.

    Parameters
    ----------
    index:       corpus state from :func:`build_index` (reused across calls)
    lam, n_iter: Sinkhorn strength / iteration count (static per engine)
    impl:        "sparse" (batched einsum) or "kernel" (batched Pallas)
    min_bucket:  smallest v_r bucket; queries are padded up to powers of two
    max_batch:   per-solve query cap — larger buckets are chunked so the
                 (Q, B, N, L) gathered tile stays memory-bounded
    pad_q:       round each chunk's Q up to a power of two with inert all-pad
                 queries, bounding the set of compiled shapes under serving
                 traffic (Q buckets x v_r buckets executables total)
    prune_slack: relative safety margin on the prune threshold in
                 :meth:`search` — admissible bounds and exact scores are
                 both fp32, so a candidate is kept unless its bound exceeds
                 the threshold by more than this fraction. Costs a few extra
                 survivors; guards the exact-top-k contract against rounding.
    """

    def __init__(self, index: CorpusIndex, lam: float = 10.0,
                 n_iter: int = 15, impl: str = "sparse",
                 min_bucket: int = 8, max_batch: int = 4,
                 pad_q: bool = True, block_n: int = 128,
                 interpret: bool | None = None, dtype=jnp.float32,
                 prune_slack: float = 1e-3):
        if impl not in ENGINE_IMPLS:
            raise ValueError(f"impl must be one of {ENGINE_IMPLS}, "
                             f"got {impl!r}")
        self.index = index
        self.lam = float(lam)
        self.n_iter = int(n_iter)
        self.impl = impl
        self.min_bucket = int(min_bucket)
        self.max_batch = int(max_batch)
        self.pad_q = bool(pad_q)
        self.block_n = int(block_n)
        self.interpret = interpret
        self.dtype = np.dtype(jnp.dtype(dtype).name)
        self.prune_slack = float(prune_slack)

    def query(self, r_full) -> jax.Array:
        """WMD from one full-vocab query histogram to every doc: (N,)."""
        return self.query_batch([r_full])[0]

    # ------------------------------------------------------------ staging
    def _plan(self, queries: list):
        """Bucket + chunk the query set: [(input positions, width), ...].

        Queries are grouped into power-of-two v_r buckets and SORTED by v_r
        inside each bucket; each ``max_batch``-sized chunk is then trimmed
        to the smallest multiple-of-8 width (the TPU sublane) covering its
        members. The pow2 buckets bound the executable count, the sort +
        trim bounds padding waste to < 8 rows per query. Empty queries
        (no support) are left out entirely.
        """
        vr = [int((q > 0).sum()) for q in queries]
        buckets: dict[int, list[int]] = {}
        for qi in range(len(queries)):
            if vr[qi] == 0:
                continue        # empty marginal: NaN row, never solved
            buckets.setdefault(bucket_size(vr[qi], self.min_bucket),
                               []).append(qi)
        chunks = []
        for b in sorted(buckets):
            members = sorted(buckets[b], key=lambda qi: vr[qi])
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                width = max(8, min(b, -(-max(vr[qi] for qi in chunk) // 8) * 8))
                chunks.append((chunk, width))
        return vr, chunks

    def _prep_chunk(self, chunk_queries: list, width: int):
        """Stage one chunk: (sup, r, mask) device arrays, q-padded to a
        power of two with inert fillers (no support -> G rows all 0, r == 1)
        when ``pad_q``."""
        prepared = [_prepare_query(q, width, self.dtype)
                    for q in chunk_queries]
        n_live = len(prepared)
        q_pad = n_live
        if self.pad_q:
            q_pad = 1
            while q_pad < n_live:
                q_pad *= 2
        filler = (np.zeros(width, np.int32), np.ones(width, self.dtype),
                  np.zeros(width, self.dtype))
        prepared += [filler] * (q_pad - n_live)
        return (jnp.asarray(np.stack([p[0] for p in prepared])),
                jnp.asarray(np.stack([p[1] for p in prepared])),
                jnp.asarray(np.stack([p[2] for p in prepared])))

    def _solve_group(self, kq, r, mask, grp: DocGroup):
        """Solve one prepared chunk against one doc group (device array,
        not yet synced): gather the group's K columns, run the batched
        solver. Works for index groups and pruned candidate subsets alike —
        the solve stage of the pipeline."""
        layout = "qbnl" if self.impl == "kernel" else "qnlb"
        g = _gather_g(kq, grp.docs.idx, layout=layout)
        if self.impl == "kernel":
            from repro.kernels.ops import sinkhorn_fused_all_batched
            return sinkhorn_fused_all_batched(
                g, grp.docs.val, r, self.lam, self.n_iter,
                block_n=self.block_n, interpret=self.interpret)
        return _solve_gathered(g, grp.docs.val, r, mask, self.lam,
                               self.n_iter)

    def _kq(self, sup, mask):
        return _compute_kq(sup, mask, self.index.vecs, self.index.vecs_sq,
                           self.lam)

    def _raise_if_nan(self, wmd_np: np.ndarray, chunk_queries: list) -> None:
        """Every chunk query has support, so NaN here means the lam-driven
        K underflow — diagnose (host-side, error path only) and raise
        instead of returning NaN distances."""
        bad = np.isnan(wmd_np).any(axis=1)
        if bad.any():
            from .sinkhorn import select_support
            q = chunk_queries[int(np.nonzero(bad)[0][0])]
            _, vecs_sel, _ = select_support(q, self.index.vecs)
            raise LamUnderflowError(underflow_report(
                self.lam, vecs_sel, self.index.vecs, self.index.docs))

    # ----------------------------------------------------------- scoring
    def query_batch(self, queries: Sequence) -> jax.Array:
        """Exhaustive WMD for Q queries (full-vocab histogram rows) ->
        (Q, N). Row order matches the input; a query with no support yields
        a NaN row (WMD is undefined for an empty marginal). Raises
        :class:`LamUnderflowError` if lam underflows K for a corpus word
        (the distances would be NaN).
        """
        queries = [np.asarray(q) for q in queries]
        if not queries:
            return jnp.zeros((0, self.index.n_docs), self.dtype)
        vr, chunks = self._plan(queries)
        # dispatch every chunk before collecting any result: device compute
        # of chunk i overlaps host prep of chunk i+1
        pending = []
        for chunk, width in chunks:
            sup, r, mask = self._prep_chunk([queries[qi] for qi in chunk],
                                            width)
            kq = self._kq(sup, mask)
            parts = [(grp, self._solve_group(kq, r, mask, grp))
                     for grp in self.index.groups]
            pending.append((chunk, parts))
        out = np.zeros((len(queries), self.index.n_docs), self.dtype)
        for qi in range(len(queries)):
            if vr[qi] == 0:
                out[qi] = np.nan
        for chunk, parts in pending:
            for grp, wmd_g in parts:
                w = np.asarray(wmd_g)[:len(chunk)]
                self._raise_if_nan(w, [queries[qi] for qi in chunk])
                out[np.ix_(chunk, np.asarray(grp.cols))] = w
        return jnp.asarray(out)

    # ------------------------------------------------------------ search
    def search(self, queries: Sequence, k: int, prune: object = "rwmd",
               nprobe: int | None = None) -> SearchResult:
        """Staged top-k retrieval: prune -> solve -> rank.

        ``prune=None`` scores exhaustively (:meth:`query_batch` + argsort,
        bit-for-bit). Otherwise ``prune`` names a lower bound from
        :mod:`repro.core.prune` (``"wcd"``, ``"rwmd"``, ``"wcd+rwmd"``, a
        cascaded ``"ivf+wcd+rwmd"``) or is a
        :class:`~repro.core.prune.Pruner` /
        :class:`~repro.core.prune.CascadePruner` instance, and per chunk:

        1. *prune*: admissible lower bounds, one batched pass. Full-sweep
           pruners score every (query, doc) pair; a cascade first
           shortlists via the index's IVF clusters (``nprobe`` nearest per
           query; ``None`` = all = exact), bounds only the shortlist, and
           computes each later (costlier) bound only on the docs the
           previous stage could not exclude;
        2. *solve* (seed): exact Sinkhorn on the union of each query's k
           best-bounded docs, gathered into a trimmed ELL subset slice;
           the per-query kth-smallest exact distance becomes the pruning
           threshold t_q — any doc with lb > t_q cannot enter the top-k.
           Seed selection and thresholding run device-side (top_k / sort
           on the bound matrices); only compact id arrays reach the host;
        3. *solve* (survivors): exact Sinkhorn on the docs whose bound
           passes t_q (+ ``prune_slack`` fp margin);
        4. *rank*: merge and argsort the exact distances.

        With an admissible bound the result equals the exhaustive top-k
        (indices and distances, up to tie order) while Sinkhorn runs on a
        strict subset of documents — ``result.solved`` reports how strict.
        The guarantee holds for ``"rwmd"`` (and its compositions), which
        bounds the *computed* truncated-Sinkhorn score; ``"wcd"`` alone
        bounds exact EMD and is exact only up to the iteration's
        query-marginal residual vs ``prune_slack`` — near-exact at
        practical ``n_iter``, see :mod:`repro.core.prune`. A cascade at
        ``nprobe < n_clusters`` is *approximate*: un-probed clusters are
        never scored, recall is measured (monotone in ``nprobe``), and a
        query with fewer than k reachable candidates pads its result row
        with ``-1`` / NaN.
        """
        queries = [np.asarray(q) for q in queries]
        n = self.index.n_docs
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        k = min(int(k), n)
        nq = len(queries)
        out_i = np.full((nq, k), -1, np.int32)
        out_d = np.full((nq, k), np.nan, self.dtype)
        solved = np.zeros(nq, np.int64)
        if nq == 0 or n == 0:
            return SearchResult(out_i, out_d, solved)

        if prune is None:
            d = np.asarray(self.query_batch(queries))
            for qi in range(nq):
                if np.isnan(d[qi]).all():
                    continue                      # empty marginal
                order = np.argsort(d[qi], kind="stable")[:k]
                out_i[qi], out_d[qi] = order, d[qi, order]
                solved[qi] = n
            return SearchResult(out_i, out_d, solved)

        from .prune import CascadePruner, resolve_pruner
        pruner = resolve_pruner(prune, use_kernel=(self.impl == "kernel"),
                                interpret=self.interpret, nprobe=nprobe)
        _, chunks = self._plan(queries)
        if isinstance(pruner, CascadePruner):
            if chunks:
                self._search_cascade(queries, k, pruner, nprobe, chunks,
                                     out_i, out_d, solved)
            return SearchResult(out_i, out_d, solved)
        for chunk, width in chunks:
            cq = [queries[qi] for qi in chunk]
            qc = len(chunk)
            sup, r, mask = self._prep_chunk(cq, width)
            kq = self._kq(sup, mask)              # shared by both solves

            def solve(doc_ids):     # -> (qc, |ids|) np, NaN-checked
                w = np.asarray(self._solve_group(
                    kq, r, mask, self.index.subset(doc_ids)))
                w = w[:qc, :doc_ids.size]  # drop q/doc shape padding
                self._raise_if_nan(w, cq)
                return w

            cand, d_cand = self._prune_full(pruner, sup, r, mask, qc, k,
                                            solve)
            for ci, qi in enumerate(chunk):
                order = np.argsort(d_cand[ci], kind="stable")[:k]
                out_i[qi, :order.size] = cand[order]
                out_d[qi, :order.size] = d_cand[ci, order]
                solved[qi] = cand.size
        return SearchResult(out_i, out_d, solved)

    def _threshold(self, d_seed_dev, k: int, n_seed: int):
        """Device-side pruning threshold: per-query kth-smallest exact
        distance among the solved seeds (+ fp slack margin). With fewer
        than k solved docs nothing may be excluded yet -> +inf."""
        if n_seed >= k:
            t = jnp.sort(d_seed_dev, axis=1)[:, k - 1]
        else:
            t = jnp.full((d_seed_dev.shape[0],), jnp.inf,
                         d_seed_dev.dtype)
        return t + self.prune_slack * (jnp.abs(t) + 1.0)

    def _prune_full(self, pruner, sup, r, mask, qc, k, solve):
        """PR 2's full-sweep prune stage, with seed selection and
        thresholding moved device-side: (Qc, N) argpartition/partition
        become top_k/sort on the device bound matrix, and only compact id
        arrays (seeds, the survivor bitmap) cross to the host."""
        from .prune import _keep_any
        lb = pruner.lower_bounds(self.index, sup, r, mask)   # (Qp, N) dev
        # seed: each query's k best-bounded docs (chunk union — extra
        # exact distances only tighten the other queries' thresholds)
        _, seed_pos = jax.lax.top_k(-lb[:qc], k)
        seed = np.unique(np.asarray(seed_pos)).astype(np.int32)
        d_seed = solve(seed)
        thresh = self._threshold(jnp.asarray(d_seed), k, seed.size)
        surv = np.nonzero(np.asarray(_keep_any(lb, thresh)))[0] \
            .astype(np.int32)
        surv = surv[~np.isin(surv, seed)]
        cand = np.concatenate([seed, surv])
        d_cand = (np.concatenate([d_seed, solve(surv)], axis=1)
                  if surv.size else d_seed)
        return cand, d_cand

    def _search_cascade(self, queries, k, pruner, nprobe, chunks,
                        out_i, out_d, solved):
        """CascadePruner driver — sub-O(N) per-doc prune work, ONE global
        prune pass for the whole query set:

        The bound stages don't need the solve's v_r bucketing (they read
        the (Q, B) support arrays directly), so all live queries are staged
        once at the widest chunk's bucket and every prune dispatch covers
        the full set — per-chunk pruning would pay the fixed dispatch
        chain per v_r bucket for no extra precision. Flow:

        1. cluster probe (one (Q, C) GEMM) + seed candidates from each
           query's nearest probed clusters (just enough to cover k docs);
        2. first-stage bounds on the seed candidates -> per-query best-k
           seeds -> exact seed solve (per solve chunk) -> threshold t_q;
        3. ``pruner.survivors``: cluster-radius triangle bound drops whole
           clusters, then the per-doc stages cheapest-first on what
           remains;
        4. exact solve on the final survivors, rank.
        """
        from .prune import _pad_pow2_ids
        index = self.index
        live_q = [qi for chunk, _ in chunks for qi in chunk]
        qg = len(live_q)
        width_g = max(width for _, width in chunks)
        sup_g, r_g, mask_g = self._prep_chunk(
            [queries[qi] for qi in live_q], width_g)
        cdists, pm, qcent = pruner.probe(index, sup_g, r_g, mask_g, nprobe)
        seed_cand = pruner.seed_candidates(index, cdists, mask_g, k, pm)
        if seed_cand.size == 0:
            return
        sp = _pad_pow2_ids(seed_cand)
        lb = pruner.stage_bounds(
            pruner.stages[0], index, sup_g, r_g, mask_g, sp,
            seed_cand.size,
            pruner.id_qmask(index, pm, sp, seed_cand.size,
                            qp=sup_g.shape[0]), qcent=qcent)
        k_eff = min(k, seed_cand.size)
        neg, seed_pos = jax.lax.top_k(-lb[:qg], k_eff)
        seed_pos = np.asarray(seed_pos)
        # -inf picks are non-candidates (a query with < k_eff candidates)
        pos_seed = np.unique(seed_pos[np.isfinite(np.asarray(neg))])
        pos_seed = pos_seed[pos_seed < seed_cand.size]
        if pos_seed.size == 0:
            return
        seed = sp[pos_seed]

        # solve stage stays v_r-bucketed: per-chunk staging, reused for
        # the seed and survivor solves
        row_of = {qi: g for g, qi in enumerate(live_q)}
        prepped = []
        for chunk, width in chunks:
            cq = [queries[qi] for qi in chunk]
            sup, r, mask = self._prep_chunk(cq, width)
            prepped.append((chunk, cq, sup, r, mask, self._kq(sup, mask)))

        def solve_all(doc_ids):       # -> (qg, |ids|) np, NaN-checked
            out = np.empty((qg, doc_ids.size), self.dtype)
            grp = index.subset(doc_ids)   # one gather, shared by chunks
            for chunk, cq, sup, r, mask, kq in prepped:
                w = np.asarray(self._solve_group(kq, r, mask, grp))
                w = w[:len(chunk), :doc_ids.size]
                self._raise_if_nan(w, cq)
                out[[row_of[qi] for qi in chunk]] = w
            return out

        d_seed = solve_all(seed)
        thresh = self._threshold(jnp.asarray(d_seed), k, seed.size)
        surv = pruner.survivors(index, sup_g, r_g, mask_g, cdists, pm,
                                qcent, thresh, exclude=seed)
        cand = np.concatenate([seed, surv])
        d_cand = (np.concatenate([d_seed, solve_all(surv)], axis=1)
                  if surv.size else d_seed)
        for g, qi in enumerate(live_q):
            order = np.argsort(d_cand[g], kind="stable")[:k]
            out_i[qi, :order.size] = cand[order]
            out_d[qi, :order.size] = d_cand[g, order]
            solved[qi] = cand.size
