"""Dense one-to-many Sinkhorn-Knopp WMD solver (paper Algorithm 1 / Fig. 2).

This module is the *paper-faithful* baseline: a direct JAX transliteration of
the python implementation in Fig. 2 of the paper (which itself implements
Cuturi'13 Algorithm 1 specialized to WMD). All matrices are dense; the hot
kernel is the dense ``K.T @ u`` followed by the sparse elementwise selection —
exactly the formulation the paper profiles in Table 1 and then replaces with
sparse kernels (see :mod:`repro.core.sinkhorn_sparse`).

Shapes follow the paper's notation:
  V    vocabulary size
  v_r  number of unique words in the query/source document (nnz of r)
  N    number of target documents
  w    word-embedding width

Conventions: ``lam`` is the positive regularization strength; the kernel is
``K = exp(-lam * M)`` (the paper negates lambda before the call; we negate
inside).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


# ln(fp32 min normal) ~ -87.3: exp(-x) flushes to exactly 0 beyond this,
# and an all-zero gathered K column turns the Sinkhorn 1/(K^T u) line into
# inf/NaN for every document containing that word.
MAX_NEG_EXP = 87.0


class LamUnderflowError(FloatingPointError):
    """``K = exp(-lam*M)`` underflowed to all-zero for some corpus word.

    Raised by the engine / ``one_to_many`` instead of silently returning
    (and benchmarking!) NaN distances — the failure mode the seed fig6
    config was timing at lam=9 on a distance-scale-10 corpus.
    """


def underflow_report(lam: float, vecs_sel, vecs, docs) -> str:
    """Host-side diagnosis for :class:`LamUnderflowError` (error path only).

    Finds the corpus words whose K column is all-zero — i.e. words farther
    than ``MAX_NEG_EXP / lam`` from *every* query word — and counts the
    documents containing one, so the message names the actual culprit
    instead of a bare NaN.
    """
    import numpy as np

    a = np.asarray(vecs_sel, np.float64)
    b = np.asarray(vecs, np.float64)
    d2 = (np.sum(a * a, 1)[:, None] + np.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    mincol = np.sqrt(np.maximum(d2, 0.0)).min(axis=0)     # (V,) to nearest
    dead = lam * mincol > MAX_NEG_EXP                     # query word
    idx = np.asarray(docs.idx)
    live = np.asarray(docs.val) > 0
    hit = dead[idx] & live
    n_docs = int(hit.any(axis=1).sum())
    scale = float(np.median(mincol[np.isfinite(mincol)]))
    return (
        f"K = exp(-lam*M) underflowed to an all-zero column for "
        f"{int(dead[np.unique(idx[hit])].size)} corpus word(s) in {n_docs} "
        f"document(s) at lam={lam:g} (fp32 cutoff: lam*dist > ~{MAX_NEG_EXP:.0f}; "
        f"max lam*min-dist here = {lam * float(mincol.max()):.0f}). The "
        f"Sinkhorn division by these columns would make every affected "
        f"distance NaN. Reduce lam (corpus min-distance scale ~{scale:.1f} "
        f"-> lam <~ {MAX_NEG_EXP / max(scale, 1e-9):.1f}), or opt into the "
        f"log-domain solve — precision='log' on WmdEngine / "
        f"sinkhorn_wmd_sparse (underflow-free at any lam), or "
        f"impl='dense_stabilized' for the dense path."
    )


def cdist(a: jax.Array, b: jax.Array, gemm_dtype=None) -> jax.Array:
    """Pairwise Euclidean distance, GEMM-shaped (paper §6).

    ``m[i, j] = sqrt(|a_i|^2 + |b_j|^2 - 2 a_i.b_j)`` — one big matmul plus
    rank-1 corrections instead of a broadcast-subtract (which would
    materialize an (v_r, V, w) intermediate). This is the paper's
    "matrix-multiplication-like" Euclidean distance restructuring.

    ``gemm_dtype`` (e.g. ``jnp.bfloat16``) casts ONLY the matmul operands;
    the accumulation and the rank-1 norms stay fp32 (the
    :class:`~repro.core.sinkhorn_sparse.SolvePrecision` bf16 policy).
    """
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    if gemm_dtype is None:
        ab = a @ b.T
    else:
        ab = jnp.matmul(a.astype(gemm_dtype), b.astype(gemm_dtype).T,
                        preferred_element_type=jnp.float32)
    d2 = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    return jnp.sqrt(d2)


class SinkhornPrecompute(NamedTuple):
    """Loop-invariant matrices (paper: "can be pre-computed once and reused")."""

    M: jax.Array          # (v_r, V) transport cost
    K: jax.Array          # (v_r, V) exp(-lam*M)
    K_over_r: jax.Array   # (v_r, V) diag(1/r) K
    KM: jax.Array         # (v_r, V) K * M


def precompute(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
               lam: float) -> SinkhornPrecompute:
    """Compute M, K, K_over_r, KM for the selected query words.

    ``r``        (v_r,)   normalized word frequencies of the query (nnz only)
    ``vecs_sel`` (v_r, w) embeddings of the query words
    ``vecs``     (V, w)   full vocabulary embeddings
    """
    M = cdist(vecs_sel, vecs)
    K = jnp.exp(-lam * M)
    return SinkhornPrecompute(M=M, K=K, K_over_r=K / r[:, None], KM=K * M)


@functools.partial(jax.jit, static_argnames=("n_iter",))
def sinkhorn_wmd_dense(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                       c: jax.Array, lam: float, n_iter: int) -> jax.Array:
    """Paper Fig. 2, dense: WMD of one query against N target documents.

    ``c`` (V, N) column-normalized word-frequency matrix of the targets,
    *dense* here (the paper's python baseline stores it sparse but the
    compute is dense GEMM + elementwise mask — identical arithmetic).

    Returns ``wmd`` (N,).
    """
    pre = precompute(r, vecs_sel, vecs, lam)
    v_r = r.shape[0]
    n_docs = c.shape[1]
    x = jnp.full((v_r, n_docs), 1.0 / v_r, dtype=pre.K.dtype)

    def body(x, _):
        u = 1.0 / x
        # Table 1 hot line: v = c.multiply(1 / (K.T @ u))  (91.9% of runtime)
        kt_u = pre.K.T @ u                       # (V, N) dense GEMM
        v = c * (1.0 / kt_u)                     # sparse selection, dense here
        x = pre.K_over_r @ v                     # (v_r, N) "SpMM" line
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = 1.0 / x
    v = c * (1.0 / (pre.K.T @ u))
    return jnp.sum(u * (pre.KM @ v), axis=0)


@functools.partial(jax.jit, static_argnames=("n_iter",))
def sinkhorn_wmd_dense_stabilized(r: jax.Array, vecs_sel: jax.Array,
                                  vecs: jax.Array, c: jax.Array, lam: float,
                                  n_iter: int) -> jax.Array:
    """Beyond-paper: log-domain Sinkhorn (numerically stable for large lam).

    The paper runs fp64 on CPU; on TPU (fp32/bf16) large ``lam`` underflows
    ``exp(-lam*M)``. The log-domain iteration replaces scaling vectors with
    dual potentials f, g and matmuls with logsumexp reductions.

    Solves the same fixed point: P = diag(exp(f*lam)) K diag(exp(g*lam)).
    """
    M = cdist(vecs_sel, vecs)                    # (v_r, V)
    v_r = r.shape[0]
    n_docs = c.shape[1]
    log_r = jnp.log(r)                           # (v_r,)
    # columns with c==0 contribute -inf log-mass
    log_c = jnp.where(c > 0, jnp.log(jnp.where(c > 0, c, 1.0)), -jnp.inf)

    f = jnp.zeros((v_r, n_docs), M.dtype)        # potential per (word, doc)
    g = jnp.zeros_like(c)                        # (V, N)

    def body(carry, _):
        f, g = carry
        # g update: column marginal  (logsumexp over query words)
        s = -lam * M[:, :, None] + f[:, None, :]            # (v_r, V, N)
        g = log_c - jax.nn.logsumexp(s, axis=0)             # (V, N)
        g = jnp.where(jnp.isneginf(log_c), -jnp.inf, g)
        # f update: row marginal (logsumexp over vocabulary)
        t = -lam * M[:, :, None] + g[None, :, :]            # (v_r, V, N)
        f = log_r[:, None] - jax.nn.logsumexp(t, axis=1)    # (v_r, N)
        return (f, g), None

    (f, g), _ = lax.scan(body, (f, g), None, length=n_iter)
    # transport plan P[k, i, n] = exp(f + g - lam*M); WMD = <P, M>
    log_p = f[:, None, :] + g[None, :, :] - lam * M[:, :, None]
    p = jnp.exp(jnp.where(jnp.isneginf(log_p), -jnp.inf, log_p))
    return jnp.sum(p * M[:, :, None], axis=(0, 1))


def select_support(r_full, vecs, dtype=jnp.float32):
    """Host-side support selection (paper: ``sel = r.squeeze() > 0``).

    Dynamic-shape step, so it runs outside jit. Returns (r_sel, vecs_sel, idx).
    """
    import numpy as np

    r_full = np.asarray(r_full).reshape(-1)
    idx = np.nonzero(r_full > 0)[0]
    r_sel = r_full[idx].astype(dtype)
    r_sel = r_sel / r_sel.sum()
    return jnp.asarray(r_sel), jnp.asarray(np.asarray(vecs)[idx], dtype=dtype), idx
