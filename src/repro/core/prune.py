"""Pluggable admissible lower bounds for the staged retrieval pipeline.

``WmdEngine.search`` runs *prune -> solve -> rank*: a cheap lower bound on
every (query, doc) pair first, the O(v_r * V * n_iter) Sinkhorn solve only
on candidates the bound cannot exclude (Atasu et al., LC-RWMD,
arXiv:1711.07227; Werner & Laber, arXiv:1912.00509; Kusner et al.'s
prefetch-and-prune). Each bound implements the small :class:`Pruner`
protocol, so stages are pluggable and composable (:class:`MaxPruner` takes
the elementwise max of several admissible bounds, which is itself
admissible).

Admissibility — what "lower bound" means *here*. The engine's score is not
exact EMD but ``<P, M>`` for the plan the truncated Sinkhorn iteration
produces. That plan satisfies the **document-side marginal exactly** (the
distance line recomputes ``w = val / (G^T u)``, so column sums equal
``val`` by construction) while the query-side marginal holds only
approximately. Hence:

``RwmdPruner`` (doc-side relaxed WMD)
    ``lb[q, n] = sum_l val[n, l] * min_k M[k, idx[n, l]]`` — every unit of
    doc mass pays at least its distance to the *nearest* query word. Since
    the engine's plan transports exactly ``val[n, l]`` out of each doc word,
    ``lb <= <P, M>`` holds for the *computed* score (up to fp rounding —
    covered by the engine's ``prune_slack``). This is the default pruner
    and the one the exact-top-k guarantee rests on.

``WcdPruner`` (word-centroid distance)
    ``lb[q, n] = ||sum_k r_k vec_k - centroid_n||`` — one GEMM per query
    chunk against centroids frozen in the :class:`~.index.CorpusIndex`.
    Admissible w.r.t. exact EMD (Jensen), but w.r.t. the truncated-Sinkhorn
    score only up to the query-marginal residual of the unconverged
    iteration — at very small ``n_iter`` that residual can exceed the
    engine's ``prune_slack`` and exclude a true top-k doc. WCD alone is
    therefore *near*-exact, not guaranteed; the exact-top-k contract rests
    on RWMD. Use WCD composed (``"wcd+rwmd"``, still guaranteed: MaxPruner
    keeps every doc RWMD keeps... see below) or standalone when approximate
    top-k at converged ``n_iter`` is acceptable.

    (Note on composition: ``max(wcd, rwmd) <= score`` requires *both*
    bounds admissible, so at tiny ``n_iter`` the same caveat applies to the
    composite; at practical iteration counts the residual is far below the
    slack — see ``test_bounds_below_engine_scores``.)

Bounds are in raw distance units (no lam): they bound the transport-cost
part ``<P, M>``, which is exactly what the solve stage returns.

``CascadePruner`` (ISSUE 3) runs these stages *cheapest-first* over a
shrinking candidate set — IVF cluster shortlist, pivot triangle bound,
WCD on the shortlist, RWMD only on WCD survivors (and only over the
survivors' own vocabulary) — instead of computing every bound on every
document; see its docstring for the exactness-vs-``nprobe`` contract.

Spec resolution (runnable — the CI ``docs`` job executes this as a
doctest)::

    >>> from repro.core.prune import PRUNERS, resolve_pruner
    >>> "ivf+pivot+wcd+rwmd" in PRUNERS
    True
    >>> type(resolve_pruner("ivf+pivot+wcd+rwmd")).__name__
    'CascadePruner'
    >>> resolve_pruner("ivf+pivot+wcd+rwmd").stages
    ('pivot', 'wcd', 'rwmd')
    >>> resolve_pruner("rwmd").name
    'rwmd'
"""
from __future__ import annotations

import functools
from typing import Protocol, Sequence, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp


@runtime_checkable
class Pruner(Protocol):
    """One prune stage: admissible lower bounds for a prepared query chunk.

    ``sup``/``r``/``mask`` are the engine's bucketed chunk layout
    ((Qp, B) support word ids, normalized frequencies with pad rows == 1,
    and the live-row mask) — the same arrays the solve stage consumes, so
    a pruner slots in front of any solve without re-staging queries.
    Returns (Qp, N) bounds; rows past the live queries are don't-care.
    """

    name: str

    def lower_bounds(self, index, sup: jax.Array, r: jax.Array,
                     mask: jax.Array) -> jax.Array: ...


@jax.jit
def _wcd_bounds(qcent: jax.Array, centroids: jax.Array) -> jax.Array:
    a2 = jnp.sum(qcent * qcent, axis=1)[:, None]
    b2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (qcent @ centroids.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def _query_centroids(sup, r, mask, vecs):
    a = jnp.take(vecs, sup, axis=0)                  # (Qp, B, w)
    return jnp.einsum("qb,qbw->qw", r * mask, a)     # pad rows (r==1) masked


class WcdPruner:
    """Word-centroid distance: one (Qp, w) x (w, N) GEMM per chunk."""

    name = "wcd"

    def lower_bounds(self, index, sup, r, mask):
        return _wcd_bounds(_query_centroids(sup, r, mask, index.vecs),
                           index.centroids)


# XLA fallback for kernels.rwmd: the kernels' oracle IS the implementation
# (single source of truth; ref.py imports only jax, so no core<->ops cycle)
from repro.kernels.ref import rwmd_min_cdist_ref

_min_cdist_xla = jax.jit(rwmd_min_cdist_ref)


@jax.jit
def _min_cdist_subset_xla(sup, mask, vecs, vids):
    """Candidate-vocab min-cdist with the support/vocab gathers fused in
    (one dispatch; the XLA twin of kernels.rwmd.rwmd_min_cdist_subset)."""
    return rwmd_min_cdist_ref(jnp.take(vecs, sup, axis=0), mask,
                              jnp.take(vecs, vids, axis=0))


@jax.jit
def _rwmd_gather(minm: jax.Array, idx: jax.Array, val: jax.Array):
    """Own jit on purpose: XLA CPU would otherwise fuse the cdist producer
    into the gather and recompute it per element (see ROADMAP note)."""
    g = jnp.take(minm, idx, axis=1)                  # (Qp, N, L)
    return jnp.einsum("qnl,nl->qn", g, val)


class RwmdPruner:
    """Doc-side relaxed WMD — tight, provably <= the engine's score.

    ``use_kernel=True`` computes the masked min-cdist with the query-grid
    Pallas kernel (:mod:`repro.kernels.rwmd`) so the prune stage is as
    TPU-resident as the solve stage; the O(nnz) gather stays in XLA either
    way (same boundary as the solve's G gather).
    """

    name = "rwmd"

    def __init__(self, use_kernel: bool = False,
                 interpret: bool | None = None):
        self.use_kernel = use_kernel
        self.interpret = interpret

    def lower_bounds(self, index, sup, r, mask):
        a = jnp.take(index.vecs, sup, axis=0)        # (Qp, B, w)
        if self.use_kernel:
            from repro.kernels import ops
            minm = ops.rwmd_min_cdist(a, mask, index.vecs,
                                      interpret=self.interpret)
        else:
            minm = _min_cdist_xla(a, mask, index.vecs)
        # all-pad filler rows have minm == +inf; inf * 0-mass stays out of
        # live rows, and callers slice fillers off anyway
        return _rwmd_gather(jnp.where(jnp.isfinite(minm), minm, 0.0),
                            index.docs.idx, index.docs.val)


class MaxPruner:
    """Elementwise max of several admissible bounds (still admissible)."""

    def __init__(self, pruners: Sequence[Pruner]):
        self.pruners = tuple(pruners)
        self.name = "+".join(p.name for p in self.pruners)

    def lower_bounds(self, index, sup, r, mask):
        bounds = [p.lower_bounds(index, sup, r, mask) for p in self.pruners]
        return functools.reduce(jnp.maximum, bounds)


# ---------------------------------------------------------------- cascade
def _pad_pow2_ids(ids: np.ndarray, min_size: int = 8) -> np.ndarray:
    """Pow2-pad an id array (pad slots get id 0 — a valid row whose
    computed bounds are garbage the candidacy masks exclude) so
    data-dependent candidate counts hit a bounded set of compiled shapes."""
    n_pad = min_size
    while n_pad < ids.size:
        n_pad *= 2
    out = np.zeros(n_pad, np.int32)
    out[:ids.size] = ids
    return out


# Fused per-stage jits: each cascade stage is ONE device dispatch (bounds +
# candidacy fold), plus one tiny dispatch for the threshold compare — the
# stage arrays are small post-shortlist, so op-by-op dispatch overhead would
# otherwise dominate the stage compute (measured ~4x on CPU at N=8k).

@jax.jit
def _wcd_stage(qcent, centroids, ids_pad, qmask):
    """Centroid bounds for a candidate id array, qmask folded to +inf:
    gather candidate centroids -> cdist vs the (probe-computed) query
    centroids -> mask."""
    cand = jnp.take(centroids, ids_pad, axis=0)          # (Sp, w)
    a2 = jnp.sum(qcent * qcent, axis=1)[:, None]
    b2 = jnp.sum(cand * cand, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (qcent @ cand.T)
    return jnp.where(qmask, jnp.sqrt(jnp.maximum(d2, 0.0)), jnp.inf)


@jax.jit
def _wcd_dense_keep_all(qcent, centroids, thresh):
    """Exhaustive-probe variant of :func:`_wcd_dense_keep`: every doc is a
    candidate of every query, so the doc -> probed-cluster lookup drops
    out of the dispatch entirely."""
    qc = thresh.shape[0]
    q = qcent[:qc]
    a2 = jnp.sum(q * q, axis=1)[:, None]
    b2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    d2 = jnp.maximum(a2 + b2 - 2.0 * (q @ centroids.T), 0.0)
    return jnp.any(d2 <= jnp.square(thresh)[:, None], axis=0)


@jax.jit
def _wcd_dense_keep(qcent, centroids, pm, assign, thresh):
    """Dense WCD threshold pass, ONE dispatch end to end: per-doc centroid
    bounds over the whole corpus (no candidate gather, query centroids
    reused from the probe, squared-distance compare — sqrt is monotone),
    candidacy via the doc -> probed-cluster lookup, keep = any live
    query's bound passes. The dispatch-economy twin of the gathered
    :func:`_wcd_stage` path — the survivor pass picks by surviving-cluster
    mass (a (Q, N) GEMM beats gather + mask dispatch chains once most docs
    survive the cluster filter)."""
    qc = thresh.shape[0]
    q = qcent[:qc]
    a2 = jnp.sum(q * q, axis=1)[:, None]
    b2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    d2 = jnp.maximum(a2 + b2 - 2.0 * (q @ centroids.T), 0.0)
    cand = jnp.take(pm[:qc], assign, axis=1)             # (qc, N) candidacy
    return jnp.any(cand & (d2 <= jnp.square(thresh)[:, None]), axis=0)


@jax.jit
def _pivot_stage(qd, dd, ids_pad, qmask):
    """Pivot triangle bounds for a candidate id array, one dispatch:
    gather candidate pivot-distance rows -> ``max_p |d(q,p) - d(n,p)|``
    (reverse triangle inequality in the embedding metric, so it
    lower-bounds the WCD) -> candidacy fold to +inf."""
    cand = jnp.take(dd, ids_pad, axis=0)                 # (Sp, P)
    lb = jnp.max(jnp.abs(qd[:, None, :] - cand[None, :, :]), axis=-1)
    return jnp.where(qmask, lb, jnp.inf)


@jax.jit
def _pivot_dense_keep(qd, dd, pm, assign, thresh):
    """Dense pivot threshold pass over the whole corpus, one dispatch —
    the pivot twin of :func:`_wcd_dense_keep`, at O(P) per pair instead
    of the WCD GEMM's O(w)."""
    qc = thresh.shape[0]
    lb = jnp.max(jnp.abs(qd[:qc, None, :] - dd[None, :, :]), axis=-1)
    cand = jnp.take(pm[:qc], assign, axis=1)             # (qc, N) candidacy
    return jnp.any(cand & (lb <= thresh[:, None]), axis=0)


@jax.jit
def _pivot_dense_keep_all(qd, dd, thresh):
    """Exhaustive-probe variant of :func:`_pivot_dense_keep`."""
    qc = thresh.shape[0]
    lb = jnp.max(jnp.abs(qd[:qc, None, :] - dd[None, :, :]), axis=-1)
    return jnp.any(lb <= thresh[:, None], axis=0)


@jax.jit
def _rwmd_epilogue(minm, rel, val, qmask):
    """RWMD gather + doc-mass contraction + candidacy fold, one dispatch.
    Separate from the min-cdist producer on purpose (the XLA CPU
    producer-into-gather refusion hazard — see the ROADMAP note)."""
    g = jnp.take(jnp.where(jnp.isfinite(minm), minm, 0.0), rel, axis=1)
    lb = jnp.einsum("qnl,nl->qn", g, val)
    return jnp.where(qmask, lb, jnp.inf)


@jax.jit
def _rwmd_keep(minm, rel, val, pm, assign_ids, n_real, thresh):
    """:func:`_rwmd_epilogue` fused with candidacy lookup and the
    threshold test — the post-threshold RWMD stage in one dispatch after
    the min-cdist producer."""
    qc = thresh.shape[0]
    g = jnp.take(jnp.where(jnp.isfinite(minm), minm, 0.0), rel, axis=1)
    lb = jnp.einsum("qnl,nl->qn", g[:qc], val)
    cand = (jnp.take(pm[:qc], assign_ids, axis=1)
            & (jnp.arange(assign_ids.shape[0])[None, :] < n_real))
    return jnp.any(cand & (lb <= thresh[:, None]), axis=0)


@jax.jit
def _rwmd_keep_all(minm, rel, val, n_real, thresh):
    """Exhaustive-probe variant of :func:`_rwmd_keep` (no cluster
    candidacy lookup; only the pad tail is masked)."""
    qc = thresh.shape[0]
    g = jnp.take(jnp.where(jnp.isfinite(minm), minm, 0.0), rel, axis=1)
    lb = jnp.einsum("qnl,nl->qn", g[:qc], val)
    keep = jnp.any(lb <= thresh[:, None], axis=0)
    return keep & (jnp.arange(rel.shape[0]) < n_real)


@jax.jit
def _keep_any(lbm, thresh):
    """Columns any live query still needs: lbm (Qp, Sp) with +inf at
    non-candidates, thresh (qc,) margined thresholds -> (Sp,) bool."""
    return jnp.any(lbm[:thresh.shape[0]] <= thresh[:, None], axis=0)


@jax.jit
def _cluster_keep_fused(cdists, radii, pm, thresh):
    """Cluster-radius filter, one dispatch: triangle bound + candidacy +
    threshold test -> (C,) bool of clusters some live query still needs."""
    lbm = jnp.where(pm, cdists - radii[None, :], jnp.inf)
    return jnp.any(lbm[:thresh.shape[0]] <= thresh[:, None], axis=0)


@jax.jit
def _cluster_keep_all(cdists, radii, thresh):
    """Exhaustive-probe variant of :func:`_cluster_keep_fused`."""
    lbm = cdists - radii[None, :]
    return jnp.any(lbm[:thresh.shape[0]] <= thresh[:, None], axis=0)


@jax.jit
def _probe_dists(sup, r, mask, vecs, centers):
    """Query centroids + cluster-center distances, one dispatch:
    (cdists (Qp, C), qcent (Qp, w) — reused by the dense WCD pass)."""
    qcent = jnp.einsum("qb,qbw->qw", r * mask, jnp.take(vecs, sup, axis=0))
    a2 = jnp.sum(qcent * qcent, axis=1)[:, None]
    b2 = jnp.sum(centers * centers, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (qcent @ centers.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0)), qcent


@functools.partial(jax.jit, static_argnames=("nprobe",))
def _probe_mask(cdists, nprobe: int):
    """(Qp, C) bool: True at each query's ``nprobe`` nearest clusters."""
    _, idx = jax.lax.top_k(-cdists, nprobe)
    rows = jnp.arange(cdists.shape[0])[:, None]
    return jnp.zeros(cdists.shape, bool).at[rows, idx].set(True)


@jax.jit
def _ids_qmask(pm, assign_ids, n_real):
    """Per-query candidacy for a padded doc-id array: the doc's cluster
    must be probed by the query, and the slot must be real (``n_real`` is
    traced, so shape bucketing stays data-independent)."""
    sub = jnp.take(pm, assign_ids, axis=1)
    return sub & (jnp.arange(assign_ids.shape[0])[None, :] < n_real)


class CascadePruner:
    """Cheapest-first cascade over a shrinking candidate set: IVF cluster
    probe + cluster-radius filter -> pivot triangle bounds -> per-doc WCD
    -> RWMD min-cdist.

    Unlike the full-sweep pruners above (one (Q, N) bound matrix), the
    cascade's per-doc work is sub-O(N):

    1. *ivf probe*: one (Q, n_clusters) GEMM against the frozen k-means
       centers. ``nprobe`` nearest clusters per query define the candidate
       universe (all clusters when ``nprobe=None`` — the exact mode). Seed
       docs come from each query's nearest probed clusters (just enough to
       cover k members), so even seed selection never sweeps the corpus.
    2. *ivf radius filter*: after the seed solve fixes the threshold t_q,
       the triangle inequality ``wcd(q, n) >= ||qcent - center_c|| -
       radius_c`` (:class:`~.index.IvfClusters` ``radii``) drops whole
       clusters against t_q — their members are never touched again.
    3. *pivot* (optional, the cheapest per-doc rung — Werner & Laber,
       arXiv:1912.00509): ``max_p |d(q, p) - d(n, p)|`` over the
       ``n_pivots`` reference words frozen at ``build_index``, using the
       precomputed ``doc_pivot_d`` table — O(P) per pair vs the WCD
       GEMM's O(w). The reverse triangle inequality makes it a lower
       bound on WCD, so it inherits WCD's admissibility (and WCD's
       truncated-iteration caveat) while touching no embeddings. Spelled
       ``"ivf+pivot+wcd+rwmd"``; requires an index built with
       ``n_pivots > 0`` (the default).
    4. *wcd*: the centroid bound, only on surviving clusters' members.
    5. *rwmd*: the tight bound, only on WCD survivors — and only over the
       vocabulary those survivors actually use, so the min-cdist block
       shrinks from (Q*B, V) to (Q*B, V_survivors)
       (:func:`repro.kernels.rwmd.rwmd_min_cdist_subset`).

    Admissibility: the radius bound under-estimates WCD (triangle
    inequality), so at ``nprobe = n_clusters`` the drop set is contained
    in the ``"wcd+rwmd"`` :class:`MaxPruner`'s-with-cluster-bounds and the
    exact-top-k story is identical to ``"wcd+rwmd"`` — guaranteed through
    the RWMD stage, near-exact through WCD's truncated-iteration caveat
    above (the cluster bound inherits the same caveat: it lower-bounds
    WCD). At smaller ``nprobe`` un-probed clusters are skipped entirely:
    approximate retrieval with *measured* recall, monotone in ``nprobe``
    for a fixed query batch (probe sets are nested, and every returned
    doc carries its exact distance — the result contains at least the
    top-k of the query's own probed universe, plus any batch-mates' union
    candidates that rank better, which can only raise recall).

    Sharded serving (:class:`~repro.core.shard_index.ShardedWmdEngine`)
    runs one cascade PER SHARD over that shard's own clusters, so
    ``nprobe`` is a per-shard knob: each shard probes its ``nprobe``
    nearest owned clusters (clamped to the shard's cluster count by the
    ``np_eff`` clamp in :meth:`probe`), and a doc is reachable iff its
    cluster ranks among its OWNING shard's probes. ``nprobe=None``
    therefore stays globally exact (every shard probes everything and
    the merge is a true global top-k), and the recall-vs-``nprobe``
    monotonicity above holds per shard count — but the probed universes
    at a fixed finite ``nprobe`` differ between shard counts (S shards
    probe up to ``S * nprobe`` clusters globally, drawn shard-locally).

    The driver is :meth:`WmdEngine.search <repro.core.index.WmdEngine>`;
    this class owns the stage computations.
    """

    def __init__(self, stages: Sequence[str] = ("wcd", "rwmd"),
                 nprobe: int | None = None, use_kernel: bool = False,
                 interpret: bool | None = None):
        stages = tuple(stages)
        if not stages or any(s not in ("pivot", "wcd", "rwmd")
                             for s in stages):
            raise ValueError(f"cascade stages must be drawn from "
                             f"('pivot', 'wcd', 'rwmd'), got {stages!r}")
        self.stages = stages
        self.nprobe = nprobe
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.name = "+".join(("ivf",) + stages)

    # -------------------------------------------------------- stage 0: ivf
    def probe(self, index, sup, r, mask, nprobe: int | None = None):
        """Cluster probe for one query staging: (cdists (Qp, C) device,
        pm (Qp, C) device bool — True at each query's probed clusters,
        qcent (Qp, w) query centroids for downstream reuse).
        ``nprobe=None`` uses the pruner's default, which itself defaults
        to all clusters."""
        cl = index.clusters
        if cl is None:
            raise ValueError(
                "CorpusIndex has no IVF clusters — rebuild with "
                "build_index() (clusters are built by default)")
        if nprobe is None:
            nprobe = self.nprobe
        c = cl.n_clusters
        np_eff = c if nprobe is None else max(1, min(int(nprobe), c))
        cdists, qcent = _probe_dists(sup, r, mask, index.vecs, cl.centers)
        # pm None == exhaustive probe: every cluster is every query's
        # candidate, and the hot stages skip the candidacy lookups
        pm = None if np_eff == c else _probe_mask(cdists, np_eff)
        return cdists, pm, qcent

    def seed_candidates(self, index, cdists, mask, k: int,
                        pm) -> np.ndarray:
        """Seed-candidate doc ids: per live query, walk probed clusters
        nearest-first until they cover k members; the union across the
        chunk is returned (host — O(Q * C), never O(N))."""
        cl = index.clusters
        sizes = cl.sizes
        cd = np.asarray(cdists)
        pm_np = None if pm is None else np.asarray(pm)
        live = np.asarray(mask).sum(axis=1) > 0
        chosen = np.zeros(cl.n_clusters, bool)
        for q in np.nonzero(live)[0]:
            covered = 0
            for c in np.argsort(cd[q], kind="stable"):
                if (pm_np is not None and not pm_np[q, c]) or sizes[c] == 0:
                    continue
                chosen[c] = True
                covered += sizes[c]
                if covered >= k:
                    break
        picked = np.nonzero(chosen)[0]
        if picked.size == 0:
            return np.zeros(0, np.int32)
        # cluster-sorted storage ids: with the index's cluster-major layout
        # (ISSUE 4) this concat of per-cluster slices is a near-contiguous
        # run of storage rows — exactly what subset()'s gather wants
        return np.concatenate(
            [cl.order[cl.starts[c]:cl.starts[c + 1]] for c in picked])

    def id_qmask(self, index, pm, ids_pad: np.ndarray, n_real: int,
                 qp: int | None = None) -> jax.Array:
        """(Qp, Sp) candidacy for a padded id array (see _ids_qmask).
        ``pm=None`` (exhaustive probe) needs ``qp`` to shape the valid-slot
        mask."""
        if pm is None:
            valid = jnp.arange(ids_pad.size) < n_real
            return jnp.broadcast_to(valid[None, :], (qp, ids_pad.size))
        assign_ids = jnp.asarray(
            index.clusters.assign[ids_pad].astype(np.int32))
        return _ids_qmask(pm, assign_ids, n_real)

    def cluster_keep(self, index, cdists, pm, thresh) -> np.ndarray:
        """(C,) host bool: clusters some live query still needs, by the
        cluster-radius triangle bound against the threshold."""
        radii = index.clusters.radii.astype(np.float32)
        if pm is None:
            return np.asarray(_cluster_keep_all(cdists, radii, thresh))
        return np.asarray(_cluster_keep_fused(cdists, radii, pm, thresh))

    def cluster_members(self, index, keep_c: np.ndarray) -> np.ndarray:
        """Cluster-sorted doc ids of the kept clusters (host slice concat —
        a near-contiguous storage run under the cluster-major layout)."""
        cl = index.clusters
        kept = np.nonzero(keep_c[:cl.n_clusters])[0]
        if kept.size == 0:
            return np.zeros(0, np.int32)
        return np.concatenate(
            [cl.order[cl.starts[c]:cl.starts[c + 1]] for c in kept])

    # --------------------------------------- post-threshold survivor pass
    def survivors(self, index, sup, r, mask, cdists, pm, qcent, thresh,
                  exclude: np.ndarray | None = None,
                  dense_cutoff: float = 0.25) -> np.ndarray:
        """The post-threshold prune pass, cheapest-first: cluster-radius
        filter, then the per-doc stages on what remains. Returns surviving
        doc ids (``exclude`` — typically the already-solved seeds —
        removed). Shared by ``WmdEngine._prune_cascade`` and the fig9
        prune-stage benchmark, so the measured pass IS the serving pass.

        When the cluster filter keeps most of the corpus (loose clusters,
        or simply a hard query), the gathered per-doc WCD stage is replaced
        by :func:`_wcd_dense_keep` — one dense dispatch over all docs beats
        gather + mask dispatch chains precisely when the gather wouldn't
        shrink the problem (the radius bound under-estimates every
        member's WCD, so the dense threshold test subsumes the cluster
        filter)."""
        cl = index.clusters
        radii = cl.radii.astype(np.float32)
        stages = self.stages
        # dispatch the cluster filter and the (speculative) dense
        # first-stage pass back to back, then sync once — the dense result
        # is discarded in the rare tight-cluster case where the gather
        # path wins, but the serial dispatch->sync->dispatch latency it
        # saves dominates its (Q, N) cost on every other call. The pivot
        # stage gets the same treatment as WCD (its dense pass is O(P)
        # per pair, cheaper still).
        qd = None
        if stages[0] == "pivot":
            if index.pivots is None:
                raise ValueError("cascade has a 'pivot' stage but the "
                                 "index has no pivot words — rebuild with "
                                 "build_index(n_pivots > 0)")
            from .index import _pivot_dists
            qd = _pivot_dists(qcent, index.pivots)
        if pm is None:
            keep_c_dev = _cluster_keep_all(cdists, radii, thresh)
            if stages[0] == "wcd":
                keep_d_dev = _wcd_dense_keep_all(qcent, index.centroids,
                                                 thresh)
            elif qd is not None:
                keep_d_dev = _pivot_dense_keep_all(qd, index.doc_pivot_d,
                                                   thresh)
            else:
                keep_d_dev = None
        else:
            keep_c_dev = _cluster_keep_fused(cdists, radii, pm, thresh)
            if stages[0] == "wcd":
                keep_d_dev = _wcd_dense_keep(qcent, index.centroids, pm,
                                             cl.assign_dev, thresh)
            elif qd is not None:
                keep_d_dev = _pivot_dense_keep(qd, index.doc_pivot_d, pm,
                                               cl.assign_dev, thresh)
            else:
                keep_d_dev = None
        keep_c = np.asarray(keep_c_dev)
        kept_docs = int(cl.sizes[keep_c[:cl.n_clusters]].sum())
        if (keep_d_dev is not None
                and kept_docs >= dense_cutoff * index.n_docs):
            surv = np.nonzero(np.asarray(keep_d_dev))[0].astype(np.int32)
            stages = stages[1:]
        else:
            surv = self.cluster_members(index, keep_c)
        if exclude is not None and exclude.size and surv.size:
            surv = surv[~np.isin(surv, exclude)]
        for stage in stages:
            if surv.size == 0:
                break
            sp = _pad_pow2_ids(surv)
            if stage == "rwmd":
                prep = self._rwmd_prep(index, sup, mask, sp, surv.size)
                if prep is None:
                    break
                minm, rel, val = prep
                rel, val = jnp.asarray(rel), jnp.asarray(val)
                if pm is None:
                    keep = np.asarray(_rwmd_keep_all(
                        minm, rel, val, surv.size, thresh))
                else:
                    assign_ids = jnp.asarray(cl.assign[sp].astype(np.int32))
                    keep = np.asarray(_rwmd_keep(
                        minm, rel, val, pm, assign_ids, surv.size, thresh))
            else:
                lbm = self.stage_bounds(
                    stage, index, sup, r, mask, sp, surv.size,
                    self.id_qmask(index, pm, sp, surv.size,
                                  qp=sup.shape[0]), qcent=qcent)
                keep = np.asarray(_keep_any(lbm, thresh))
            surv = surv[keep[:surv.size]]
        return surv

    # ----------------------------------------------------- bounded stages
    def stage_bounds(self, stage: str, index, sup, r, mask,
                     ids_pad: np.ndarray, n_real: int, qmask: jax.Array,
                     qcent: jax.Array | None = None) -> jax.Array:
        """Masked lower bounds for one cascade stage on a candidate id
        array: (Qp, Sp) device, +inf wherever ``qmask`` is False (pad
        slots and per-query non-candidates). One fused dispatch per stage
        (plus the min-cdist producer for RWMD). Pass the ``qcent`` the
        probe already computed to skip recomputing query centroids."""
        if stage in ("wcd", "pivot"):
            if qcent is None:
                qcent = _query_centroids(sup, r, mask, index.vecs)
            if stage == "pivot":
                if index.pivots is None:
                    raise ValueError(
                        "cascade has a 'pivot' stage but the index has no "
                        "pivot words — rebuild with build_index("
                        "n_pivots > 0)")
                from .index import _pivot_dists
                return _pivot_stage(_pivot_dists(qcent, index.pivots),
                                    index.doc_pivot_d,
                                    jnp.asarray(ids_pad), qmask)
            return _wcd_stage(qcent, index.centroids,
                              jnp.asarray(ids_pad), qmask)
        return self._rwmd_subset(index, sup, mask, ids_pad, n_real, qmask)

    def _rwmd_prep(self, index, sup, mask, ids_pad, n_real):
        """Shared RWMD-subset prep: gather candidate rows host-side (like
        ``CorpusIndex.subset``), remap their word ids into the compact
        candidate-vocab space, min-cdist only those embedding rows — the
        (Q*B, V) block shrinks to (Q*B, V_survivors). Returns
        (minm device, rel np, val np) or None when the subset is empty."""
        idx = index.docs_host.idx[ids_pad]
        val = index.docs_host.val[ids_pad].copy()
        val[n_real:] = 0.0                    # pad rows out of the vocab
        nnz = (val > 0).sum(axis=1)
        lg = max(1, int(nnz.max(initial=0)))
        lg = min(-(-lg // 8) * 8, idx.shape[1])
        idx, val = idx[:, :lg], val[:, :lg]
        live = val > 0
        vids = np.unique(idx[live])
        if vids.size == 0:
            return None
        rel = np.searchsorted(vids, idx).astype(np.int32)
        rel[~live] = 0
        # pow2-bucket the candidate vocab so data-dependent survivor sets
        # don't compile a fresh min-cdist per step (pad ids repeat vids[0];
        # the padded columns are computed but never gathered)
        vids_pad = _pad_pow2_ids(vids, min_size=128)
        vids_pad[vids.size:] = vids[0]
        if self.use_kernel:
            from repro.kernels import ops
            minm = ops.rwmd_min_cdist(
                jnp.take(index.vecs, sup, axis=0), mask, index.vecs,
                interpret=self.interpret,
                vocab_ids=jnp.asarray(vids_pad, jnp.int32))
        else:
            minm = _min_cdist_subset_xla(sup, mask, index.vecs,
                                         jnp.asarray(vids_pad, jnp.int32))
        return minm, rel, val

    def _rwmd_subset(self, index, sup, mask, ids_pad, n_real, qmask):
        """Masked RWMD bounds on a candidate subset (see _rwmd_prep)."""
        prep = self._rwmd_prep(index, sup, mask, ids_pad, n_real)
        if prep is None:
            return jnp.where(qmask, 0.0, jnp.inf)
        minm, rel, val = prep
        return _rwmd_epilogue(minm, jnp.asarray(rel), jnp.asarray(val),
                              qmask)


PRUNERS = ("wcd", "rwmd", "wcd+rwmd", "ivf", "ivf+wcd", "ivf+rwmd",
           "ivf+wcd+rwmd", "ivf+pivot+wcd+rwmd", "ivf+pivot+rwmd")


def resolve_pruner(spec, use_kernel: bool = False,
                   interpret: bool | None = None,
                   nprobe: int | None = None):
    """Turn a spec (``"wcd"``, ``"rwmd"``, ``"wcd+rwmd"``, a cascaded
    ``"ivf[+wcd][+rwmd]"``, or a :class:`Pruner`/:class:`CascadePruner`
    instance) into a pruner instance. ``nprobe`` applies to cascades only
    (``None`` probes every cluster — the exact mode)."""
    if isinstance(spec, str):
        parts = [p.strip() for p in spec.replace(",", "+").split("+") if p]
        if parts and parts[0] == "ivf":
            stages = tuple(parts[1:]) or ("wcd", "rwmd")
            return CascadePruner(stages=stages, nprobe=nprobe,
                                 use_kernel=use_kernel, interpret=interpret)
        if nprobe is not None:
            raise ValueError(
                f"nprobe={nprobe} only applies to ivf cascades; "
                f"{spec!r} sweeps every document")
        made = []
        for p in parts:
            if p == "wcd":
                made.append(WcdPruner())
            elif p == "rwmd":
                made.append(RwmdPruner(use_kernel=use_kernel,
                                       interpret=interpret))
            elif p == "pivot":
                raise ValueError(
                    "the pivot prestage reads the index's precomputed "
                    "doc_pivot_d table and runs inside the ivf cascade — "
                    "spell it 'ivf+pivot+...'")
            else:
                raise ValueError(
                    f"unknown pruner {p!r}; pick from {PRUNERS} or pass a "
                    f"Pruner instance")
        if not made:
            raise ValueError(f"empty pruner spec {spec!r}")
        return made[0] if len(made) == 1 else MaxPruner(made)
    if isinstance(spec, CascadePruner):
        if nprobe is not None and spec.nprobe != nprobe:
            raise ValueError(
                f"nprobe={nprobe} conflicts with the CascadePruner's own "
                f"nprobe={spec.nprobe}; set it on the pruner")
        return spec
    if isinstance(spec, Pruner):
        if nprobe is not None:
            raise ValueError(
                f"nprobe={nprobe} only applies to ivf cascades; "
                f"{type(spec).__name__} sweeps every document")
        return spec
    raise TypeError(f"prune must be a str, None, or Pruner, got {spec!r}")
