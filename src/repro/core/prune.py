"""Pluggable admissible lower bounds for the staged retrieval pipeline.

``WmdEngine.search`` runs *prune -> solve -> rank*: a cheap lower bound on
every (query, doc) pair first, the O(v_r * V * n_iter) Sinkhorn solve only
on candidates the bound cannot exclude (Atasu et al., LC-RWMD,
arXiv:1711.07227; Werner & Laber, arXiv:1912.00509; Kusner et al.'s
prefetch-and-prune). Each bound implements the small :class:`Pruner`
protocol, so stages are pluggable and composable (:class:`MaxPruner` takes
the elementwise max of several admissible bounds, which is itself
admissible).

Admissibility — what "lower bound" means *here*. The engine's score is not
exact EMD but ``<P, M>`` for the plan the truncated Sinkhorn iteration
produces. That plan satisfies the **document-side marginal exactly** (the
distance line recomputes ``w = val / (G^T u)``, so column sums equal
``val`` by construction) while the query-side marginal holds only
approximately. Hence:

``RwmdPruner`` (doc-side relaxed WMD)
    ``lb[q, n] = sum_l val[n, l] * min_k M[k, idx[n, l]]`` — every unit of
    doc mass pays at least its distance to the *nearest* query word. Since
    the engine's plan transports exactly ``val[n, l]`` out of each doc word,
    ``lb <= <P, M>`` holds for the *computed* score (up to fp rounding —
    covered by the engine's ``prune_slack``). This is the default pruner
    and the one the exact-top-k guarantee rests on.

``WcdPruner`` (word-centroid distance)
    ``lb[q, n] = ||sum_k r_k vec_k - centroid_n||`` — one GEMM per query
    chunk against centroids frozen in the :class:`~.index.CorpusIndex`.
    Admissible w.r.t. exact EMD (Jensen), but w.r.t. the truncated-Sinkhorn
    score only up to the query-marginal residual of the unconverged
    iteration — at very small ``n_iter`` that residual can exceed the
    engine's ``prune_slack`` and exclude a true top-k doc. WCD alone is
    therefore *near*-exact, not guaranteed; the exact-top-k contract rests
    on RWMD. Use WCD composed (``"wcd+rwmd"``, still guaranteed: MaxPruner
    keeps every doc RWMD keeps... see below) or standalone when approximate
    top-k at converged ``n_iter`` is acceptable.

    (Note on composition: ``max(wcd, rwmd) <= score`` requires *both*
    bounds admissible, so at tiny ``n_iter`` the same caveat applies to the
    composite; at practical iteration counts the residual is far below the
    slack — see ``test_bounds_below_engine_scores``.)

Bounds are in raw distance units (no lam): they bound the transport-cost
part ``<P, M>``, which is exactly what the solve stage returns.
"""
from __future__ import annotations

import functools
from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class Pruner(Protocol):
    """One prune stage: admissible lower bounds for a prepared query chunk.

    ``sup``/``r``/``mask`` are the engine's bucketed chunk layout
    ((Qp, B) support word ids, normalized frequencies with pad rows == 1,
    and the live-row mask) — the same arrays the solve stage consumes, so
    a pruner slots in front of any solve without re-staging queries.
    Returns (Qp, N) bounds; rows past the live queries are don't-care.
    """

    name: str

    def lower_bounds(self, index, sup: jax.Array, r: jax.Array,
                     mask: jax.Array) -> jax.Array: ...


@jax.jit
def _wcd_bounds(qcent: jax.Array, centroids: jax.Array) -> jax.Array:
    a2 = jnp.sum(qcent * qcent, axis=1)[:, None]
    b2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (qcent @ centroids.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def _query_centroids(sup, r, mask, vecs):
    a = jnp.take(vecs, sup, axis=0)                  # (Qp, B, w)
    return jnp.einsum("qb,qbw->qw", r * mask, a)     # pad rows (r==1) masked


class WcdPruner:
    """Word-centroid distance: one (Qp, w) x (w, N) GEMM per chunk."""

    name = "wcd"

    def lower_bounds(self, index, sup, r, mask):
        return _wcd_bounds(_query_centroids(sup, r, mask, index.vecs),
                           index.centroids)


# XLA fallback for kernels.rwmd: the kernels' oracle IS the implementation
# (single source of truth; ref.py imports only jax, so no core<->ops cycle)
from repro.kernels.ref import rwmd_min_cdist_ref

_min_cdist_xla = jax.jit(rwmd_min_cdist_ref)


@jax.jit
def _rwmd_gather(minm: jax.Array, idx: jax.Array, val: jax.Array):
    """Own jit on purpose: XLA CPU would otherwise fuse the cdist producer
    into the gather and recompute it per element (see ROADMAP note)."""
    g = jnp.take(minm, idx, axis=1)                  # (Qp, N, L)
    return jnp.einsum("qnl,nl->qn", g, val)


class RwmdPruner:
    """Doc-side relaxed WMD — tight, provably <= the engine's score.

    ``use_kernel=True`` computes the masked min-cdist with the query-grid
    Pallas kernel (:mod:`repro.kernels.rwmd`) so the prune stage is as
    TPU-resident as the solve stage; the O(nnz) gather stays in XLA either
    way (same boundary as the solve's G gather).
    """

    name = "rwmd"

    def __init__(self, use_kernel: bool = False,
                 interpret: bool | None = None):
        self.use_kernel = use_kernel
        self.interpret = interpret

    def lower_bounds(self, index, sup, r, mask):
        a = jnp.take(index.vecs, sup, axis=0)        # (Qp, B, w)
        if self.use_kernel:
            from repro.kernels import ops
            minm = ops.rwmd_min_cdist(a, mask, index.vecs,
                                      interpret=self.interpret)
        else:
            minm = _min_cdist_xla(a, mask, index.vecs)
        # all-pad filler rows have minm == +inf; inf * 0-mass stays out of
        # live rows, and callers slice fillers off anyway
        return _rwmd_gather(jnp.where(jnp.isfinite(minm), minm, 0.0),
                            index.docs.idx, index.docs.val)


class MaxPruner:
    """Elementwise max of several admissible bounds (still admissible)."""

    def __init__(self, pruners: Sequence[Pruner]):
        self.pruners = tuple(pruners)
        self.name = "+".join(p.name for p in self.pruners)

    def lower_bounds(self, index, sup, r, mask):
        bounds = [p.lower_bounds(index, sup, r, mask) for p in self.pruners]
        return functools.reduce(jnp.maximum, bounds)


PRUNERS = ("wcd", "rwmd", "wcd+rwmd")


def resolve_pruner(spec, use_kernel: bool = False,
                   interpret: bool | None = None) -> Pruner:
    """Turn a spec (``"wcd"``, ``"rwmd"``, ``"wcd+rwmd"``, or any object
    implementing :class:`Pruner`) into a pruner instance."""
    if isinstance(spec, str):
        parts = [p.strip() for p in spec.replace(",", "+").split("+") if p]
        made = []
        for p in parts:
            if p == "wcd":
                made.append(WcdPruner())
            elif p == "rwmd":
                made.append(RwmdPruner(use_kernel=use_kernel,
                                       interpret=interpret))
            else:
                raise ValueError(
                    f"unknown pruner {p!r}; pick from {PRUNERS} or pass a "
                    f"Pruner instance")
        if not made:
            raise ValueError(f"empty pruner spec {spec!r}")
        return made[0] if len(made) == 1 else MaxPruner(made)
    if isinstance(spec, Pruner):
        return spec
    raise TypeError(f"prune must be a str, None, or Pruner, got {spec!r}")
