"""Sparse document-collection containers.

The paper stores the target-document word-frequency matrix ``c`` (V x N,
density ~0.0035%) in CSR and load-balances by splitting nnz across threads
with a binary search. Neither variable-length CSR rows nor runtime binary
search map onto XLA/TPU (static shapes, no scalar-efficient gather loops), so
we adapt the same *work-avoidance* idea to two TPU-native layouts:

``PaddedDocs`` (ELL / padded-CSC by document)
    Each target document j stores its word ids ``idx[j, :L]`` and normalized
    frequencies ``val[j, :L]``, padded to the collection max ``L`` (~dozens).
    nnz work becomes dense (N, L, v_r) einsums — every FLOP is useful up to
    the pad fraction, all accesses are unit-stride after one gather, and the
    layout is trivially shardable over documents. This is the layout the
    sparse Sinkhorn solver and the SDDMM_SpMM Pallas kernel consume.

``BlockSparse`` (BSR over the (V, N) matrix)
    MXU-aligned zero-tile skipping, used by the block-sparse kernel variant
    and as the general-purpose format when documents share vocabulary.

Load balancing (paper: equal nnz per thread) is done at ingest: documents are
sorted by nnz and dealt round-robin to shards, then padded — see
``repro.data.corpus.shard_balanced``.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp


class PaddedDocs(NamedTuple):
    """ELL-format document collection: c[idx[j,l], j] = val[j,l]."""

    idx: jnp.ndarray   # (N, L) int32 word ids; padding repeats id 0
    val: jnp.ndarray   # (N, L) float   normalized frequencies; padding == 0

    @property
    def n_docs(self) -> int:
        return self.idx.shape[0]

    @property
    def max_words(self) -> int:
        return self.idx.shape[1]

    def mask(self) -> jnp.ndarray:
        return self.val > 0


def padded_docs_from_dense(c: np.ndarray, max_words: int | None = None,
                           dtype=np.float32) -> PaddedDocs:
    """Build ELL docs from a dense (V, N) column-normalized matrix.

    Fully vectorized (one np.nonzero + scatter): per-doc slots are the
    column-sorted nnz positions, truncated at ``length`` like the original
    per-column loop.
    """
    c = np.asarray(c)
    v, n = c.shape
    cols, rows = np.nonzero(c.T > 0)        # sorted by doc, then word id
    counts = np.bincount(cols, minlength=n)
    length = int(max_words if max_words is not None
                 else max(1, counts.max(initial=0)))
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(cols.size) - np.repeat(starts, counts)
    keep = slot < length
    idx = np.zeros((n, length), dtype=np.int32)
    val = np.zeros((n, length), dtype=dtype)
    idx[cols[keep], slot[keep]] = rows[keep]
    val[cols[keep], slot[keep]] = c[rows[keep], cols[keep]]
    return PaddedDocs(idx=jnp.asarray(idx), val=jnp.asarray(val))


def padded_docs_from_lists(word_ids: list[np.ndarray], counts: list[np.ndarray],
                           max_words: int | None = None,
                           dtype=np.float32) -> PaddedDocs:
    """Build ELL docs from per-document (unique word id, count) lists.

    Frequencies are normalized per document (paper: ``sum(c[:, j]) == 1``).
    """
    n = len(word_ids)
    length = int(max_words if max_words is not None
                 else max(1, max(len(w) for w in word_ids)))
    idx = np.zeros((n, length), dtype=np.int32)
    val = np.zeros((n, length), dtype=dtype)
    for j, (w, cnt) in enumerate(zip(word_ids, counts)):
        w = np.asarray(w)[:length]
        cnt = np.asarray(cnt, dtype=np.float64)[:length]
        idx[j, : len(w)] = w
        val[j, : len(w)] = (cnt / cnt.sum()).astype(dtype)
    return PaddedDocs(idx=jnp.asarray(idx), val=jnp.asarray(val))


def padded_docs_to_dense(docs: PaddedDocs, vocab_size: int) -> np.ndarray:
    """Inverse of :func:`padded_docs_from_dense` (tests / dense baseline).

    One np.add.at scatter over the live ELL slots (duplicated word ids
    accumulate, matching the original O(N*L) loop).
    """
    idx = np.asarray(docs.idx)
    val = np.asarray(docs.val)
    n, length = idx.shape
    c = np.zeros((vocab_size, n), dtype=val.dtype)
    jj, ll = np.nonzero(val > 0)
    np.add.at(c, (idx[jj, ll], jj), val[jj, ll])
    return c


class BlockSparse(NamedTuple):
    """BSR over a (V, N) matrix with MXU-aligned (bv, bn) tiles.

    Only tiles containing at least one nonzero are stored. ``blocks`` holds
    the dense tile contents; (``brow``, ``bcol``) the tile coordinates. The
    count of retained tiles is padded to ``n_blocks`` (zero tiles appended at
    coordinate (0, 0) with all-zero content) so shapes are static.
    """

    blocks: jnp.ndarray  # (n_blocks, bv, bn) tile values
    brow: jnp.ndarray    # (n_blocks,) int32 tile row (vocab) index
    bcol: jnp.ndarray    # (n_blocks,) int32 tile col (doc) index
    shape: tuple[int, int]  # padded (V, N)

    @property
    def block_shape(self) -> tuple[int, int]:
        return self.blocks.shape[1], self.blocks.shape[2]


def block_sparse_from_dense(c: np.ndarray, bv: int = 128, bn: int = 128,
                            pad_blocks_to: int | None = None,
                            dtype=np.float32) -> BlockSparse:
    c = np.asarray(c, dtype=dtype)
    v, n = c.shape
    vp, np_ = -(-v // bv) * bv, -(-n // bn) * bn
    cp = np.zeros((vp, np_), dtype=dtype)
    cp[:v, :n] = c
    tiles = cp.reshape(vp // bv, bv, np_ // bn, bn).transpose(0, 2, 1, 3)
    nz = np.argwhere(np.abs(tiles).sum(axis=(2, 3)) > 0)
    total = len(nz) if pad_blocks_to is None else pad_blocks_to
    if total < len(nz):
        raise ValueError(f"pad_blocks_to={total} < {len(nz)} live tiles")
    blocks = np.zeros((max(total, 1), bv, bn), dtype=dtype)
    brow = np.zeros((max(total, 1),), dtype=np.int32)
    bcol = np.zeros((max(total, 1),), dtype=np.int32)
    for k, (i, j) in enumerate(nz):
        blocks[k] = tiles[i, j]
        brow[k], bcol[k] = i, j
    return BlockSparse(blocks=jnp.asarray(blocks), brow=jnp.asarray(brow),
                       bcol=jnp.asarray(bcol), shape=(vp, np_))


def block_density(c: np.ndarray, bv: int = 128, bn: int = 128) -> float:
    """Fraction of (bv, bn) tiles with any nonzero — the TPU work ratio."""
    c = np.asarray(c)
    v, n = c.shape
    vp, np_ = -(-v // bv) * bv, -(-n // bn) * bn
    cp = np.zeros((vp, np_), dtype=c.dtype)
    cp[:v, :n] = c
    tiles = cp.reshape(vp // bv, bv, np_ // bn, bn).transpose(0, 2, 1, 3)
    live = (np.abs(tiles).sum(axis=(2, 3)) > 0).sum()
    return float(live) / (tiles.shape[0] * tiles.shape[1])
