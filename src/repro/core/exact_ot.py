"""Exact EMD via linear programming — test oracle only (scipy, host-side).

Cuturi'13 proves the Sinkhorn distance converges to the exact optimal
transport distance as lambda grows; tests use this to validate the solver
end-to-end rather than only against itself.
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linprog


def exact_emd(r: np.ndarray, c: np.ndarray, m: np.ndarray) -> float:
    """min <P, M> s.t. P 1 = r, P^T 1 = c, P >= 0.

    ``r`` (a,), ``c`` (b,), ``m`` (a, b). Returns the optimal cost.
    """
    r = np.asarray(r, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    a, b = m.shape
    # equality constraints: row sums == r, col sums == c (drop one redundant)
    a_eq = np.zeros((a + b - 1, a * b))
    for i in range(a):
        a_eq[i, i * b:(i + 1) * b] = 1.0
    for j in range(b - 1):
        a_eq[a + j, j::b] = 1.0
    b_eq = np.concatenate([r, c[:-1]])
    res = linprog(m.reshape(-1), A_eq=a_eq, b_eq=b_eq, bounds=(0, None),
                  method="highs")
    if not res.success:
        raise RuntimeError(f"linprog failed: {res.message}")
    return float(res.fun)
