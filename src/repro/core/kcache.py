"""Cross-request cache of per-word corpus-distance rows (ISSUE 10).

The paper's core trick is corpus-side reuse of ``K = exp(-lam*M)`` WITHIN
one dispatch (one stacked cdist GEMM per query chunk, ``_compute_kq``);
this module extends the reuse ACROSS dispatches. Real query traffic is
Zipfian over the vocabulary, so the same query words — and therefore the
same ``(V,)`` cdist rows against the frozen corpus vocabulary — recur
constantly between requests. :class:`KCache` keeps the hot words' rows
resident on device in a fixed-capacity slot array with an LRU clock:

- the cache stores the RAW Euclidean distance row ``m[w] = ||vecs - w||``
  per word id, which is independent of ``lam`` and of the solve's
  precision DOMAIN — the linear path derives ``exp(-lam*m)`` and the
  log-domain path ``-lam*m`` elementwise at assembly time
  (:func:`assemble_kq`), so both :class:`SolvePrecision` domains share
  one entry space. The GEMM precision (``fp32`` vs ``bf16``) IS part of
  the cache identity: bf16 operands change ``m`` itself, so a cache is
  built for one ``gemm`` spelling (the engine passes its own).
- miss rows are computed by :func:`_cdist_rows` — the SAME per-element
  reduction as ``_compute_kq``'s stacked GEMM, just ``U`` columns instead
  of ``Q*B``. On the backends this repo targets the per-element dot
  product is bitwise independent of the other output dimensions, so
  cache-on search results are BIT-EXACT against cache-off (pinned by the
  kcache property suite; if a future backend breaks per-row bitwise
  equality the suite's failure is the signal to document a tolerance).
- hot-path dispatch economy (the ROADMAP refusion note): the cached path
  costs a gather + a misses-only GEMM + a scatter instead of one stacked
  GEMM, so on CPU it only wins when enough rows actually hit. The engine
  falls back to the one-shot GEMM below ``kcache_min_hits`` hits — and
  still WARMS the cache from that chunk's ``mq`` (the stacked rows are
  bitwise the rows the cache would have computed).

Shape discipline: every jit here sees pow2-padded operands (unique-id
count, miss count) so serving traffic compiles a bounded executable set,
mirroring the engine's own v_r/Q bucketing. The store carries one extra
SCRATCH row that padded scatter lanes land in and nothing ever reads.

Validity: the cache is keyed against one embedding table by OBJECT
IDENTITY (:attr:`KCache.vecs`). ``append_docs`` grows a corpus without
touching ``vecs`` (``CorpusIndex._replace`` reuses it), so appends are
cache-safe by construction — the engine asserts the identity each staged
chunk and :meth:`KCache.rebind` drops every entry when the table it was
built against is swapped (a different index, a reloaded snapshot).

Not thread-safe: one cache belongs to one engine, whose dispatches are
already serialized (the serving runtime's single worker thread; one
fan-out thread per shard for the sharded engine's per-shard caches).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp


def _pow2(n: int, floor: int = 8) -> int:
    b = max(1, int(floor))
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("gemm",))
def _cdist_rows(ids: jax.Array, vecs: jax.Array, vecs_sq: jax.Array,
                gemm: str = "fp32") -> jax.Array:
    """(U,) word ids -> (U, V) distance rows against the whole vocabulary.

    Mirrors ``_compute_kq``'s reduction exactly — same operands, same
    ``max(.., 0)`` clamp, same sqrt — with the word axis as the GEMM's N
    dimension, so each output element is the identical dot product the
    stacked chunk GEMM would have produced for that (word, vocab) pair.
    """
    a = jnp.take(vecs, ids, axis=0)                       # (U, w)
    a2 = jnp.sum(a * a, axis=-1)                          # (U,)
    if gemm == "bf16":
        ab = jnp.matmul(vecs.astype(jnp.bfloat16),
                        a.T.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    else:
        ab = vecs @ a.T                                   # (V, U)
    d2 = jnp.maximum(vecs_sq[:, None] + a2[None, :] - 2.0 * ab, 0.0)
    return jnp.sqrt(d2).T                                 # (U, V)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(store, slots, rows):
    """In-place (donated) slot update; padded lanes target the scratch
    row, which is never gathered."""
    return store.at[slots].set(rows)


@jax.jit
def _gather_rows(store, slots):
    return jnp.take(store, slots, axis=0)


@jax.jit
def _extract_rows(mq, qq, bb):
    """Pull per-word rows out of a staged chunk's (Q, V, B) cdist block:
    row for word ``sup[qq[i], bb[i]]`` is ``mq[qq[i], :, bb[i]]``."""
    return mq[qq, :, bb]                                  # (U, V)


@functools.partial(jax.jit, static_argnames=("lam", "log_domain"))
def assemble_kq(rows: jax.Array, inv: jax.Array, mask: jax.Array,
                lam: float, log_domain: bool = False):
    """Cached rows -> the ``(kq, mq)`` pair ``_compute_kq`` returns.

    ``rows`` is (U, V) distance rows, ``inv`` (Q, B) maps each chunk slot
    to its row. The kernel derivation is the same elementwise formula as
    the uncached path (``exp(-lam*m) * mask`` / masked ``-lam*m``), so on
    bitwise-equal ``m`` the pair is bitwise equal too. ``mq`` stays
    unmasked, exactly like the uncached pair — pad slots carry word id
    0's true row and the solve epilogue's ``g > 0`` guard excludes them.
    """
    m = jnp.transpose(jnp.take(rows, inv, axis=0), (0, 2, 1))  # (Q, V, B)
    if log_domain:
        kq = jnp.where(mask[:, None, :] > 0, -lam * m, -jnp.inf)
    else:
        kq = jnp.exp(-lam * m) * mask[:, None, :]
    return kq, m


class KCache:
    """Fixed-capacity device-resident cdist-row cache with an LRU clock.

    ``slots`` bounds device memory at ``(slots + 1) * V`` floats (one
    scratch row). The host side keeps the word->slot map and per-slot
    last-use ticks; all row data stays on device.

    Counters (:meth:`stats`): ``hits``/``misses`` count per-word row
    lookups over ALL traffic (including chunks the engine then served
    via the one-shot fallback — the hit rate is an honest property of
    the traffic, not of the path taken), ``evictions`` counts LRU
    replacements, ``inserts`` rows written, ``lookups`` staged chunks
    seen, ``fallbacks`` chunks served by the one-shot GEMM, ``oversize``
    chunks whose unique-word count exceeded capacity.
    """

    def __init__(self, vecs: jax.Array, vecs_sq: jax.Array, slots: int,
                 gemm: str = "fp32"):
        if slots < 1:
            raise ValueError(f"kcache needs at least 1 slot, got {slots}")
        self.vecs = vecs
        self.vecs_sq = vecs_sq
        self.slots = int(slots)
        self.gemm = gemm
        v = vecs.shape[0]
        self._store = jnp.zeros((self.slots + 1, v), vecs.dtype)
        self._slot_of: dict[int, int] = {}
        self._word_of = np.full(self.slots, -1, np.int64)
        self._last_use = np.zeros(self.slots, np.int64)
        self._tick = 0
        self.reset_counters()

    # ------------------------------------------------------------ queries
    def reset_counters(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.inserts = self.lookups = self.fallbacks = self.oversize = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"slots": self.slots, "used": len(self._slot_of),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "inserts": self.inserts,
                "lookups": self.lookups, "fallbacks": self.fallbacks,
                "oversize": self.oversize,
                "hit_rate": round(self.hits / total, 4) if total else 0.0}

    def lookup(self, ids: np.ndarray) -> int:
        """Count one chunk's unique word ids against the resident set —
        the engine's cached-vs-fallback decision point. Updates the
        hit/miss counters (every chunk's rows are counted exactly once,
        whichever path then serves it) but not the LRU clock."""
        n_hit = sum(1 for w in ids if int(w) in self._slot_of)
        self.lookups += 1
        self.hits += n_hit
        self.misses += len(ids) - n_hit
        return n_hit

    def note_fallback(self, oversize: bool = False) -> None:
        """The engine served a chunk via the one-shot stacked GEMM —
        either below the hit threshold or because the chunk's unique
        words exceed capacity (``oversize``)."""
        self.fallbacks += 1
        if oversize:
            self.oversize += 1

    # ------------------------------------------------------------- slots
    def _claim_slots(self, miss_ids, keep: set) -> np.ndarray:
        """One slot per miss id: free slots first, then LRU victims —
        never a slot holding a word of the CURRENT chunk (``keep``)."""
        out = np.empty(len(miss_ids), np.int64)
        free = np.nonzero(self._word_of < 0)[0]
        n_free = min(free.size, len(miss_ids))
        out[:n_free] = free[:n_free]
        need = len(miss_ids) - n_free
        if need > 0:
            order = np.argsort(self._last_use, kind="stable")
            victims = [s for s in order
                       if self._word_of[s] >= 0
                       and int(self._word_of[s]) not in keep]
            assert len(victims) >= need, "kcache slot accounting broken"
            for j, s in enumerate(victims[:need]):
                del self._slot_of[int(self._word_of[s])]
                self.evictions += 1
                out[n_free + j] = s
        for w, s in zip(miss_ids, out):
            self._slot_of[int(w)] = int(s)
            self._word_of[s] = int(w)
        return out

    def _insert(self, miss_ids, rows_padded, pad_to: int,
                keep: set) -> None:
        """Scatter ``len(miss_ids)`` freshly computed rows (carried in a
        ``pad_to``-long device batch; surplus lanes hit the scratch
        row). ``keep`` is the CURRENT chunk's word set — its slots are
        exempt from LRU eviction while the chunk is being staged."""
        slots = self._claim_slots(miss_ids, keep)
        target = np.full(pad_to, self.slots, np.int32)   # scratch row
        target[:len(miss_ids)] = slots
        self._store = _scatter_rows(self._store, jnp.asarray(target),
                                    rows_padded)
        self._last_use[slots] = self._tick
        self.inserts += len(miss_ids)

    # -------------------------------------------------------------- rows
    def rows(self, ids: np.ndarray) -> jax.Array:
        """(U,) sorted unique word ids -> (U_pad, V) resident rows (tail
        lanes repeat the last id — callers index through ``ids`` order,
        so the padding is inert). Misses are computed by the uncached
        reduction and inserted; every id's slot is touched on the LRU
        clock. Counters are :meth:`lookup`'s job — call it first."""
        assert len(ids) <= self.slots, "caller must fall back on oversize"
        self._tick += 1
        miss = [int(w) for w in ids if int(w) not in self._slot_of]
        # touch hits BEFORE claiming miss slots so this chunk's own rows
        # are never the LRU victims of its own misses
        hit_slots = [self._slot_of[int(w)] for w in ids
                     if int(w) in self._slot_of]
        if hit_slots:
            self._last_use[np.asarray(hit_slots)] = self._tick
        if miss:
            pad = _pow2(len(miss))
            padded = np.zeros(pad, np.int32)
            padded[:len(miss)] = miss
            fresh = _cdist_rows(jnp.asarray(padded), self.vecs,
                                self.vecs_sq, gemm=self.gemm)
            self._insert(miss, fresh, pad,
                         keep=set(int(w) for w in ids))
        u_pad = _pow2(len(ids))
        slot_idx = np.full(u_pad, self._slot_of[int(ids[-1])], np.int32)
        slot_idx[:len(ids)] = [self._slot_of[int(w)] for w in ids]
        return _gather_rows(self._store, jnp.asarray(slot_idx))

    def warm(self, sup_np: np.ndarray, mq: jax.Array) -> None:
        """Insert a fallback chunk's rows from its already-computed
        ``(Q, V, B)`` cdist block — bitwise the rows :meth:`rows` would
        have produced, at the cost of one small gather instead of a
        GEMM. Oversize chunks only warm as many rows as fit."""
        self._tick += 1
        flat = sup_np.reshape(-1)
        ids, first = np.unique(flat, return_index=True)
        fresh = [(int(w), int(f)) for w, f in zip(ids, first)
                 if int(w) not in self._slot_of]
        # refresh resident rows' clock even on the fallback path — they
        # were just used by this chunk
        hit_slots = [self._slot_of[int(w)] for w in ids
                     if int(w) in self._slot_of]
        if hit_slots:
            self._last_use[np.asarray(hit_slots)] = self._tick
        # warming never EVICTS: a cold chunk's rows must not displace the
        # hot resident set the LRU clock is protecting — only free slots
        # are filled
        room = self.slots - len(self._slot_of)
        if room <= 0 or not fresh:
            return
        fresh = fresh[:room]
        pad = _pow2(len(fresh))
        qq = np.zeros(pad, np.int32)
        bb = np.zeros(pad, np.int32)
        b = sup_np.shape[1]
        for j, (_, f) in enumerate(fresh):
            qq[j], bb[j] = f // b, f % b
        rows = _extract_rows(mq, jnp.asarray(qq), jnp.asarray(bb))
        self._insert([w for w, _ in fresh], rows, pad,
                     keep=set(int(w) for w in ids))

    # ----------------------------------------------------------- validity
    def rebind(self, vecs: jax.Array, vecs_sq: jax.Array) -> "KCache":
        """The embedding table this cache was built against is gone —
        drop every entry and bind to the new one (counters survive: a
        rebind is an operational event worth seeing in the hit rate)."""
        fresh = KCache(vecs, vecs_sq, self.slots, gemm=self.gemm)
        for k in ("hits", "misses", "evictions", "inserts", "lookups",
                  "fallbacks", "oversize"):
            setattr(fresh, k, getattr(self, k))
        return fresh
