"""Sparse-kernel Sinkhorn-Knopp WMD — the paper's contribution (§4), TPU form.

The paper's transformation: the dense hot line
``v = c.multiply(1 / (K.T @ u))`` computes a (V, N) GEMM and then throws away
99.996% of it; SDDMM computes only the nnz(c) dot products, and SDDMM_SpMM
fuses the following ``x = K_over_r @ v`` so ``v`` never round-trips memory.

TPU adaptation (see DESIGN.md §4): CSR loops become ELL-format einsums. With
``G[k, j, l] = K[k, idx[j, l]]`` gathered once before the loop (K is
loop-invariant — the same observation the paper uses to hoist K, K.T,
K_over_r), each iteration is

    t[j, l] = sum_k G[k, j, l] * u[k, j]        # SDDMM
    w[j, l] = val[j, l] / t[j, l]               # sparse selection
    x[k, j] = sum_l G[k, j, l] / r[k] * w[j, l] # SpMM (fused: same G tile)

which is 4*N*L*v_r flops/iter versus the dense 4*N*V*v_r — a V/L ~ 2800x
work reduction at the paper's corpus statistics, with zero gather traffic
inside the loop. The Pallas kernel (:mod:`repro.kernels.sddmm_spmm`) executes
the same schedule tile-by-tile out of VMEM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .sinkhorn import LamUnderflowError, cdist, underflow_report
from .sparse import PaddedDocs


class SolvePrecision(NamedTuple):
    """Solve-stage numeric policy (ISSUE 4): which dtype the GEMMs run in
    and whether the kernel matrix is kept in the log domain.

    ``gemm="bf16"`` runs the cdist and SDDMM/SpMM contractions with bf16
    inputs and fp32 accumulation (``preferred_element_type``); ``x`` and
    the marginals stay fp32, so only the GEMM operand traffic is halved —
    the Atasu et al. (LC-RWMD) mixed-precision lever, tolerance-bounded.

    ``log_domain=True`` keeps ``log K = -lam*M`` unexponentiated through
    the gather and max-subtracts per gathered column before the solve
    (:func:`precompute_sparse_log`): every column's largest entry becomes
    exactly 1, so an all-zero K column — the :class:`LamUnderflowError`
    failure mode — is structurally impossible at any ``lam``. The Sinkhorn
    iteration is invariant under per-column rescaling of G (the factor
    cancels between the SDDMM and SpMM lines), and the distance line picks
    up the closed-form correction ``-(1/lam) sum_l shift*val`` — exact, not
    an approximation (see :func:`log_shift_correction`).

    Spellings accepted by :meth:`parse` (engine/serve/CLI knob):
    ``"fp32"``, ``"bf16"``, ``"log"``, ``"bf16+log"`` (order-insensitive).
    """

    gemm: str = "fp32"        # "fp32" | "bf16"
    log_domain: bool = False

    @classmethod
    def parse(cls, spec) -> "SolvePrecision":
        if isinstance(spec, cls):
            return spec
        if spec is None:
            return cls()
        parts = [p.strip() for p in str(spec).split("+") if p.strip()]
        gemm, log_domain = "fp32", False
        for p in parts:
            if p in ("fp32", "bf16"):
                gemm = p
            elif p == "log":
                log_domain = True
            else:
                raise ValueError(
                    f"unknown precision token {p!r} in {spec!r}; spell it "
                    f"from {{'fp32', 'bf16', 'log'}} joined by '+'")
        return cls(gemm=gemm, log_domain=log_domain)

    @property
    def gemm_dtype(self):
        return jnp.bfloat16 if self.gemm == "bf16" else None

    @property
    def name(self) -> str:
        return self.gemm + ("+log" if self.log_domain else "")


class SparsePrecompute(NamedTuple):
    """Loop-invariant gathered tiles: everything the iteration touches.

    Only TWO nnz-sized arrays: the (K*M) gather the distance line needs is
    reconstructable from G (``GM = -G*log(G)/lam`` since ``G`` holds gathered
    ``K = exp(-lam*M)`` entries), so it is never materialized — see
    :func:`reconstruct_gm`.
    """

    G: jax.Array          # (v_r, N, L)  K columns at each doc's words
    G_over_r: jax.Array   # (v_r, N, L)  diag(1/r) G
    val: jax.Array        # (N, L)       normalized frequencies (0 = pad)


class SparsePrecomputeLog(NamedTuple):
    """Log-domain variant of :class:`SparsePrecompute` (ISSUE 4).

    ``G`` holds ``exp(log K - shift)`` with ``shift[n, l] = max_k
    (-lam * M[k, idx[n, l]])`` — each gathered column is rescaled so its
    largest entry is exactly 1. The iteration consumes it unchanged (the
    rescale cancels between SDDMM and SpMM); only the distance line needs
    ``shift`` back (see :func:`log_shift_correction`).
    """

    G: jax.Array          # (v_r, N, L)  exp(-lam*M - shift), col-max == 1
    G_over_r: jax.Array   # (v_r, N, L)  diag(1/r) G
    val: jax.Array        # (N, L)       normalized frequencies (0 = pad)
    shift: jax.Array      # (N, L)       per-column max of -lam*M (<= 0)


def reconstruct_gm(G: jax.Array, lam) -> jax.Array:
    """(K*M) gathered == -G*log(G)/lam; G == 0 entries (padding or exp
    underflow) map to 0, matching the materialized gather."""
    safe = jnp.where(G > 0, G, 1.0)
    return jnp.where(G > 0, -G * jnp.log(safe), 0.0) / lam


def log_shift_correction(shift: jax.Array, val: jax.Array,
                         lam) -> jax.Array:
    """Exact distance-line correction for the log-domain rescale.

    With ``G' = G * exp(-shift)`` per column, the converged selection
    satisfies ``t' * w' = val`` (the doc marginal holds by construction),
    so the rescale's contribution to ``<P, M>`` collapses to
    ``-(1/lam) sum_l shift[n, l] * val[n, l]`` — a per-doc constant, no
    approximation. Returns (N,)."""
    return -jnp.sum(shift * val, axis=-1) / lam


def precompute_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                      docs: PaddedDocs, lam: float,
                      gemm_dtype=None) -> SparsePrecompute:
    """cdist -> K -> gather doc columns. One pass over (v_r, V), then O(nnz).

    ``gemm_dtype`` (e.g. ``jnp.bfloat16``) runs the cdist GEMM with
    reduced-precision inputs and fp32 accumulation (the
    :class:`SolvePrecision` bf16 policy)."""
    M = cdist(vecs_sel, vecs, gemm_dtype=gemm_dtype)       # (v_r, V)
    K = jnp.exp(-lam * M)
    G = jnp.take(K, docs.idx, axis=1)            # (v_r, N, L)
    return SparsePrecompute(G=G, G_over_r=G / r[:, None, None], val=docs.val)


def precompute_sparse_log(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                          docs: PaddedDocs, lam: float,
                          gemm_dtype=None) -> SparsePrecomputeLog:
    """Log-domain precompute: ``log K = -lam*M`` is gathered UNexponentiated
    and max-subtracted per column, so no column can underflow to all-zero
    (its max entry exponentiates to exactly 1) — large-``lam`` configs like
    the paper's ``lam=9`` run without the :class:`LamUnderflowError` guard
    ever tripping."""
    M = cdist(vecs_sel, vecs, gemm_dtype=gemm_dtype)       # (v_r, V)
    lg = jnp.take(-lam * M, docs.idx, axis=1)    # (v_r, N, L) log K gathered
    shift = jnp.max(lg, axis=0)                  # (N, L), <= 0
    G = jnp.exp(lg - shift[None])
    return SparsePrecomputeLog(G=G, G_over_r=G / r[:, None, None],
                               val=docs.val, shift=shift)


def _gemm_cast(a, gemm_dtype):
    return a if gemm_dtype is None else a.astype(gemm_dtype)


def _sddmm(g, u, gemm_dtype=None):
    """t[n, l] = sum_k G[k, n, l] u[k, n] with fp32 accumulation."""
    return jnp.einsum("knl,kn->nl", _gemm_cast(g, gemm_dtype),
                      _gemm_cast(u, gemm_dtype),
                      preferred_element_type=jnp.float32)


def _spmm(g_over_r, w, gemm_dtype=None):
    """x[k, n] = sum_l G_over_r[k, n, l] w[n, l] with fp32 accumulation."""
    return jnp.einsum("knl,nl->kn", _gemm_cast(g_over_r, gemm_dtype),
                      _gemm_cast(w, gemm_dtype),
                      preferred_element_type=jnp.float32)


def marginal_residual(w, w_prev, mask):
    """Per-doc relative doc-marginal residual, the adaptive loops' shared
    exit statistic: ``max_doc max_slot |w - w_prev| / max_slot |w|`` over
    ``mask``-live slots (the last axis is the slot axis; leading axes are
    docs and, for the batched engine, queries). Masked slots contribute 0
    to both the diff and the scale, so padded docs/queries can neither
    stall the loop nor release it early; an all-masked doc's 0/1e-30 is
    exactly 0."""
    diff = jnp.max(jnp.where(mask, jnp.abs(w - w_prev), 0.0), axis=-1)
    scale = jnp.max(jnp.where(mask, jnp.abs(w), 0.0), axis=-1)
    return jnp.max(diff / jnp.maximum(scale, 1e-30))


def marginal_residual_per_query(w, w_prev, mask):
    """Per-QUERY residual vector (ISSUE 5): the same statistic as
    :func:`marginal_residual` — each DOC's diff is normalized by that
    doc's own marginal scale (the last axis is the slot axis) BEFORE any
    cross-doc reduction; mixing a near doc's diff with a far doc's much
    larger marginal scale would release the exit spuriously early — but
    reduced only over each query's own axes: ``w`` is (Q, ..., L) with a
    leading query axis, and the doc-ratio max keeps it, returning (Q,).
    ``mask`` is the per-query residual scope: fold the query's
    *candidate* docs into it and far (query, doc) pairs the ranking
    never needs can no longer hold that query's exit open. A query whose
    scope is empty (an all-pad filler, or no candidates) reduces to
    exactly 0 and converges at the first check."""
    diff = jnp.max(jnp.where(mask, jnp.abs(w - w_prev), 0.0), axis=-1)
    scale = jnp.max(jnp.where(mask, jnp.abs(w), 0.0), axis=-1)
    ratio = diff / jnp.maximum(scale, 1e-30)
    return jnp.max(ratio, axis=tuple(range(1, ratio.ndim)))


def adaptive_loop(step, residual, x0, n_iter: int, tol: float,
                  check_every: int, all_reduce=None,
                  use_fori: bool = False):
    """Shared convergence-adaptive driver for every Sinkhorn variant
    (einsum engine, single-query sparse, distributed shards, Pallas
    kernel bodies — ONE copy of the exit machinery).

    ``step(x) -> (x, w)`` runs one iteration; ``residual(w, w_prev)``
    reduces to the scalar exit statistic (:func:`marginal_residual` with
    the variant's own mask); ``all_reduce`` (optional) agrees on the
    residual across shards (the distributed ``lax.pmax``);
    ``use_fori=True`` drives the inner window with ``fori_loop`` instead
    of ``scan`` (Pallas kernel bodies). The window is SEEDED with one
    real iteration — against ``w_prev == 0`` the first residual would be
    exactly 1.0 and a whole check period would be wasted — so realized
    counts land on ``1 + k*check_every`` with ``n_iter`` the cap
    (overshot by at most ``check_every - 1``). Returns (x, iters)."""
    def window(x, w):
        if use_fori:
            return lax.fori_loop(0, check_every,
                                 lambda _, c: step(c[0]), (x, w))
        out, _ = lax.scan(lambda c, _: (step(c[0]), None), (x, w), None,
                          length=check_every)
        return out

    def cond(state):
        i, _, _, res = state
        return (i < n_iter) & (res > tol)

    def body(state):
        i, x, w_prev, _ = state
        x, w = window(x, w_prev)
        res = residual(w, w_prev)
        if all_reduce is not None:
            res = all_reduce(res)
        return (i + check_every, x, w, res)

    x, w_seed = step(x0)
    state = (jnp.asarray(1, jnp.int32), x, w_seed,
             jnp.asarray(jnp.inf, jnp.float32))
    iters, x, _, _ = lax.while_loop(cond, body, state)
    return x, iters


def adaptive_loop_scoped(step, residual, x0, n_iter: int, tol: float,
                         check_every: int, live_q, all_reduce=None):
    """Per-QUERY convergence-adaptive driver (ISSUE 5).

    Where :func:`adaptive_loop` reduces the exit statistic to one
    chunk-global scalar, this driver keeps a (Q,) residual VECTOR and a
    per-query convergence state:

    - ``step(x, active) -> (x, w)`` runs one iteration with the (Q,) bool
      ``active`` mask folded into the update — frozen queries' operand
      rows are ZEROED (semantically dropped; a dense einsum/GEMM still
      executes at full chunk width, so the saving is the earlier
      per-query EXIT and the honest per-query iteration accounting, not
      fewer FLOPs per remaining iteration — on TPU the Pallas path's
      per-block exit is where frozen work is genuinely skipped);
    - ``residual(w, w_prev) -> (Q,)`` is the per-query exit statistic
      (:func:`marginal_residual_per_query` with the variant's own scope
      mask — fold each query's CANDIDATE docs in and far pairs the
      ranking never needs stop holding its exit open);
    - queries FREEZE their x-columns once converged (``x`` keeps the
      frozen value through every later window; convergence is sticky);
    - the loop exits when every ``live_q`` query has converged or the
      ``n_iter`` cap hits; ``all_reduce`` (the distributed ``lax.pmax``
      over the (Q,) vector — still ONE collective) agrees on the
      residuals across shards so every shard freezes the same queries.

    The query axis is axis 0 of ``x``. The window is seeded with one real
    iteration like the scalar driver, so per-query realized counts land
    on ``1 + k*check_every`` with ``n_iter`` the cap. Returns
    ``(x, iters_q)`` with ``iters_q`` (Q,) int32 — the iterations each
    query's x actually absorbed (fillers stay at the seed count)."""
    bshape = (-1,) + (1,) * (x0.ndim - 1)

    def window(x, w, active):
        act_b = active.reshape(bshape)

        def inner(carry, _):
            x, _ = carry
            x_new, w_new = step(x, active)
            return (jnp.where(act_b, x_new, x), w_new), None

        (x, w), _ = lax.scan(inner, (x, w), None, length=check_every)
        return x, w

    def cond(state):
        i, _, _, conv, _ = state
        return (i < n_iter) & jnp.any(live_q & ~conv)

    def body(state):
        i, x, w_prev, conv, iters_q = state
        active = live_q & ~conv
        x, w = window(x, w_prev, active)
        res = residual(w, w_prev)
        if all_reduce is not None:
            res = all_reduce(res)
        i_new = i + check_every
        iters_q = jnp.where(active, i_new, iters_q)
        conv = conv | (active & (res <= tol))
        return (i_new, x, w, conv, iters_q)

    x, w_seed = step(x0, live_q)
    q = live_q.shape[0]
    state = (jnp.asarray(1, jnp.int32), x, w_seed,
             jnp.zeros((q,), bool), jnp.ones((q,), jnp.int32))
    _, x, _, _, iters_q = lax.while_loop(cond, body, state)
    return x, iters_q


def _inv(x, guarded: bool):
    """``1/x``; the guarded form maps non-positive entries to 0 instead of
    inf/NaN. The LINEAR path keeps the raw division on purpose — an
    underflowed K column must surface as NaN so the
    :class:`LamUnderflowError` guard can trip; the LOG path uses the
    guarded form because column underflow is structurally impossible there
    and a fully-underflowed *row* (a query word beyond the fp32 horizon of
    every doc word) should drop out like its linear-domain K row would."""
    if not guarded:
        return 1.0 / x
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def _select(live, val, t, guarded: bool):
    """Sparse selection ``w = val/t`` on live slots (0 elsewhere)."""
    if not guarded:
        return jnp.where(live, val / t, 0.0)
    ok = live & (t > 0)
    return jnp.where(ok, val / jnp.where(ok, t, 1.0), 0.0)


def _iterate(pre: SparsePrecompute, n_iter: int, gemm_dtype=None,
             guarded: bool = False):
    v_r = pre.G.shape[0]
    n = pre.G.shape[1]
    live = pre.val > 0
    x = jnp.full((v_r, n), 1.0 / v_r, dtype=jnp.float32)

    def body(x, _):
        u = _inv(x, guarded)
        t = _sddmm(pre.G, u, gemm_dtype)                   # SDDMM
        w = _select(live, pre.val, t, guarded)
        x = _spmm(pre.G_over_r, w, gemm_dtype)             # SpMM (fused)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    return x, jnp.asarray(n_iter, jnp.int32)


def _iterate_adaptive(pre, n_iter: int, tol: float, check_every: int,
                      gemm_dtype=None, guarded: bool = False,
                      doc_mask=None):
    """Convergence-adaptive Sinkhorn: a ``lax.while_loop`` that checks the
    doc-marginal residual ``max|val/t - w_prev|`` every ``check_every``
    iterations and exits once every live column is below ``tol``.

    ``n_iter`` becomes a CAP (realized counts land on ``1 + k *
    check_every`` — the window is seeded with one real iteration so even
    the first check can exit — overshooting the cap by at most
    ``check_every - 1``). The residual is RELATIVE to each doc's own
    marginal scale and costs nothing extra: ``w`` falls out of the
    chunk's last inner iteration and is carried between checks. Padded
    slots (``val == 0``) are masked out of the residual, so inert docs
    can neither stall the loop nor release it early. ``doc_mask`` (N,)
    additionally scopes the exit test to the docs the caller actually
    needs (ISSUE 5's residual scoping from this single-query solver's
    perspective): non-candidate docs keep iterating but cannot hold the
    loop open. Returns (x, iters)."""
    v_r = pre.G.shape[0]
    live = pre.val > 0
    resmask = live if doc_mask is None else live & doc_mask[:, None]
    x0 = jnp.full((v_r, pre.val.shape[0]), 1.0 / v_r, dtype=jnp.float32)

    def step(x):
        u = _inv(x, guarded)
        t = _sddmm(pre.G, u, gemm_dtype)
        w = _select(live, pre.val, t, guarded)
        return _spmm(pre.G_over_r, w, gemm_dtype), w

    return adaptive_loop(step,
                         lambda w, wp: marginal_residual(w, wp, resmask),
                         x0, n_iter, tol, check_every)


@functools.partial(jax.jit, static_argnames=("n_iter", "tol", "check_every",
                                             "precision"))
def _sinkhorn_wmd_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                         docs: PaddedDocs, lam: float, n_iter: int,
                         tol=None, check_every: int = 4,
                         precision: SolvePrecision = SolvePrecision(),
                         doc_mask=None):
    gd = precision.gemm_dtype
    guarded = precision.log_domain
    if precision.log_domain:
        pre = precompute_sparse_log(r, vecs_sel, vecs, docs, lam, gd)
    else:
        pre = precompute_sparse(r, vecs_sel, vecs, docs, lam, gd)
    if tol is None:
        x, iters = _iterate(pre, n_iter, gd, guarded)
    else:
        x, iters = _iterate_adaptive(pre, n_iter, tol, check_every, gd,
                                     guarded, doc_mask)
    u = _inv(x, guarded)
    t = _sddmm(pre.G, u, gd)
    w = _select(pre.val > 0, pre.val, t, guarded)
    # wmd[j] = sum_k u[k,j] * sum_l GM[k,j,l] w[j,l]   (paper's final line);
    # GM reconstructed from G, never stored
    wmd = jnp.einsum("kn,knl,nl->n", u, reconstruct_gm(pre.G, lam), w)
    if precision.log_domain:
        wmd = wmd + log_shift_correction(pre.shift, pre.val, lam)
    return wmd, iters


def sinkhorn_wmd_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                        docs: PaddedDocs, lam: float, n_iter: int,
                        check_underflow: bool = True, tol=None,
                        check_every: int = 4, precision=None,
                        return_iters: bool = False, doc_mask=None):
    """Sparse fused Sinkhorn WMD: identical result to the dense Alg. 1.

    Padding entries (val == 0) produce w == 0 and therefore contribute
    nothing — exactly the entries the dense version masks away with c.

    ``tol`` switches the fixed-length scan to the convergence-adaptive
    ``lax.while_loop`` (``n_iter`` becomes a cap; realized counts land on
    ``1 + k*check_every``); ``precision`` is a :class:`SolvePrecision` (or its
    string spelling) selecting bf16 GEMMs and/or the log-domain kernel —
    the log-domain path cannot underflow, so the guard below never trips on
    it. ``return_iters=True`` also returns the realized iteration count.
    ``doc_mask`` (N,) bool scopes the adaptive exit test to the caller's
    candidate docs (ISSUE 5): this solver IS one query, so per-query
    residual scoping means its residual covers only the docs whose
    distances the caller will read — distances of masked-out docs are
    still returned, just not allowed to delay the exit.

    Like the engine and ``one_to_many``, a ``K = exp(-lam*M)`` underflow
    raises :class:`~repro.core.sinkhorn.LamUnderflowError` with a host-side
    diagnosis instead of returning NaN distances. The check syncs the (N,)
    result; pass ``check_underflow=False`` to keep dispatch async (callers
    that run their own guard, e.g. ``one_to_many``, do).
    """
    precision = SolvePrecision.parse(precision)
    out, iters = _sinkhorn_wmd_sparse(
        r, vecs_sel, vecs, docs, lam, n_iter,
        tol=None if tol is None else float(tol),
        check_every=int(check_every), precision=precision,
        doc_mask=None if doc_mask is None else jnp.asarray(doc_mask, bool))
    if (check_underflow and r.shape[0] > 0
            and bool(jnp.isnan(out).any())):
        raise LamUnderflowError(underflow_report(lam, vecs_sel, vecs, docs))
    return (out, iters) if return_iters else out


@functools.partial(jax.jit, static_argnames=("n_iter",))
def sinkhorn_wmd_sparse_unfused(r: jax.Array, vecs_sel: jax.Array,
                                vecs: jax.Array, docs: PaddedDocs, lam: float,
                                n_iter: int) -> jax.Array:
    """Paper-faithful *unfused* sparse variant (separate SDDMM then SpMM,
    re-reading K from HBM each iteration — the paper's Fig. 3 pair before the
    SDDMM_SpMM fusion). Used by benchmarks to measure the fusion win."""
    M = cdist(vecs_sel, vecs)
    K = jnp.exp(-lam * M)
    K_over_r = K / r[:, None]
    KM = K * M
    v_r = r.shape[0]
    n, length = docs.idx.shape
    live = docs.val > 0
    x = jnp.full((v_r, n), 1.0 / v_r, dtype=K.dtype)

    def body(x, _):
        u = 1.0 / x
        # SDDMM with per-iteration gather (no hoisted G):
        g = jnp.take(K, docs.idx, axis=1)                  # (v_r, N, L)
        t = jnp.einsum("knl,kn->nl", g, u)
        w = jnp.where(live, docs.val / t, 0.0)
        # separate SpMM, gathering K_over_r again:
        gor = jnp.take(K_over_r, docs.idx, axis=1)
        x = jnp.einsum("knl,nl->kn", gor, w)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = 1.0 / x
    g = jnp.take(K, docs.idx, axis=1)
    t = jnp.einsum("knl,kn->nl", g, u)
    w = jnp.where(live, docs.val / t, 0.0)
    gm = jnp.take(KM, docs.idx, axis=1)
    return jnp.einsum("kn,knl,nl->n", u, gm, w)
