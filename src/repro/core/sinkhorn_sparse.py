"""Sparse-kernel Sinkhorn-Knopp WMD — the paper's contribution (§4), TPU form.

The paper's transformation: the dense hot line
``v = c.multiply(1 / (K.T @ u))`` computes a (V, N) GEMM and then throws away
99.996% of it; SDDMM computes only the nnz(c) dot products, and SDDMM_SpMM
fuses the following ``x = K_over_r @ v`` so ``v`` never round-trips memory.

TPU adaptation (see DESIGN.md §4): CSR loops become ELL-format einsums. With
``G[k, j, l] = K[k, idx[j, l]]`` gathered once before the loop (K is
loop-invariant — the same observation the paper uses to hoist K, K.T,
K_over_r), each iteration is

    t[j, l] = sum_k G[k, j, l] * u[k, j]        # SDDMM
    w[j, l] = val[j, l] / t[j, l]               # sparse selection
    x[k, j] = sum_l G[k, j, l] / r[k] * w[j, l] # SpMM (fused: same G tile)

which is 4*N*L*v_r flops/iter versus the dense 4*N*V*v_r — a V/L ~ 2800x
work reduction at the paper's corpus statistics, with zero gather traffic
inside the loop. The Pallas kernel (:mod:`repro.kernels.sddmm_spmm`) executes
the same schedule tile-by-tile out of VMEM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .sinkhorn import LamUnderflowError, cdist, underflow_report
from .sparse import PaddedDocs


class SparsePrecompute(NamedTuple):
    """Loop-invariant gathered tiles: everything the iteration touches.

    Only TWO nnz-sized arrays: the (K*M) gather the distance line needs is
    reconstructable from G (``GM = -G*log(G)/lam`` since ``G`` holds gathered
    ``K = exp(-lam*M)`` entries), so it is never materialized — see
    :func:`reconstruct_gm`.
    """

    G: jax.Array          # (v_r, N, L)  K columns at each doc's words
    G_over_r: jax.Array   # (v_r, N, L)  diag(1/r) G
    val: jax.Array        # (N, L)       normalized frequencies (0 = pad)


def reconstruct_gm(G: jax.Array, lam) -> jax.Array:
    """(K*M) gathered == -G*log(G)/lam; G == 0 entries (padding or exp
    underflow) map to 0, matching the materialized gather."""
    safe = jnp.where(G > 0, G, 1.0)
    return jnp.where(G > 0, -G * jnp.log(safe), 0.0) / lam


def precompute_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                      docs: PaddedDocs, lam: float) -> SparsePrecompute:
    """cdist -> K -> gather doc columns. One pass over (v_r, V), then O(nnz)."""
    M = cdist(vecs_sel, vecs)                    # (v_r, V)
    K = jnp.exp(-lam * M)
    G = jnp.take(K, docs.idx, axis=1)            # (v_r, N, L)
    return SparsePrecompute(G=G, G_over_r=G / r[:, None, None], val=docs.val)


def _iterate(pre: SparsePrecompute, n_iter: int) -> jax.Array:
    v_r = pre.G.shape[0]
    n = pre.G.shape[1]
    live = pre.val > 0
    x = jnp.full((v_r, n), 1.0 / v_r, dtype=pre.G.dtype)

    def body(x, _):
        u = 1.0 / x
        t = jnp.einsum("knl,kn->nl", pre.G, u)             # SDDMM
        w = jnp.where(live, pre.val / t, 0.0)
        x = jnp.einsum("knl,nl->kn", pre.G_over_r, w)      # SpMM (fused)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    return x


@functools.partial(jax.jit, static_argnames=("n_iter",))
def _sinkhorn_wmd_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                         docs: PaddedDocs, lam: float,
                         n_iter: int) -> jax.Array:
    pre = precompute_sparse(r, vecs_sel, vecs, docs, lam)
    x = _iterate(pre, n_iter)
    u = 1.0 / x
    t = jnp.einsum("knl,kn->nl", pre.G, u)
    w = jnp.where(pre.val > 0, pre.val / t, 0.0)
    # wmd[j] = sum_k u[k,j] * sum_l GM[k,j,l] w[j,l]   (paper's final line);
    # GM reconstructed from G, never stored
    return jnp.einsum("kn,knl,nl->n", u, reconstruct_gm(pre.G, lam), w)


def sinkhorn_wmd_sparse(r: jax.Array, vecs_sel: jax.Array, vecs: jax.Array,
                        docs: PaddedDocs, lam: float, n_iter: int,
                        check_underflow: bool = True) -> jax.Array:
    """Sparse fused Sinkhorn WMD: identical result to the dense Alg. 1.

    Padding entries (val == 0) produce w == 0 and therefore contribute
    nothing — exactly the entries the dense version masks away with c.

    Like the engine and ``one_to_many``, a ``K = exp(-lam*M)`` underflow
    raises :class:`~repro.core.sinkhorn.LamUnderflowError` with a host-side
    diagnosis instead of returning NaN distances. The check syncs the (N,)
    result; pass ``check_underflow=False`` to keep dispatch async (callers
    that run their own guard, e.g. ``one_to_many``, do).
    """
    out = _sinkhorn_wmd_sparse(r, vecs_sel, vecs, docs, lam, n_iter)
    if (check_underflow and r.shape[0] > 0
            and bool(jnp.isnan(out).any())):
        raise LamUnderflowError(underflow_report(lam, vecs_sel, vecs, docs))
    return out


@functools.partial(jax.jit, static_argnames=("n_iter",))
def sinkhorn_wmd_sparse_unfused(r: jax.Array, vecs_sel: jax.Array,
                                vecs: jax.Array, docs: PaddedDocs, lam: float,
                                n_iter: int) -> jax.Array:
    """Paper-faithful *unfused* sparse variant (separate SDDMM then SpMM,
    re-reading K from HBM each iteration — the paper's Fig. 3 pair before the
    SDDMM_SpMM fusion). Used by benchmarks to measure the fusion win."""
    M = cdist(vecs_sel, vecs)
    K = jnp.exp(-lam * M)
    K_over_r = K / r[:, None]
    KM = K * M
    v_r = r.shape[0]
    n, length = docs.idx.shape
    live = docs.val > 0
    x = jnp.full((v_r, n), 1.0 / v_r, dtype=K.dtype)

    def body(x, _):
        u = 1.0 / x
        # SDDMM with per-iteration gather (no hoisted G):
        g = jnp.take(K, docs.idx, axis=1)                  # (v_r, N, L)
        t = jnp.einsum("knl,kn->nl", g, u)
        w = jnp.where(live, docs.val / t, 0.0)
        # separate SpMM, gathering K_over_r again:
        gor = jnp.take(K_over_r, docs.idx, axis=1)
        x = jnp.einsum("knl,nl->kn", gor, w)
        return x, None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = 1.0 / x
    g = jnp.take(K, docs.idx, axis=1)
    t = jnp.einsum("knl,kn->nl", g, u)
    w = jnp.where(live, docs.val / t, 0.0)
    gm = jnp.take(KM, docs.idx, axis=1)
    return jnp.einsum("kn,knl,nl->n", u, gm, w)
