"""Multi-chip Sinkhorn-WMD via shard_map — the paper's parallelization at pod
scale (DESIGN.md §3).

Two distribution schemes, mirroring the paper's baseline->optimized arc:

``dense`` (paper-faithful distributed baseline)
    Vocabulary V sharded over the ``model`` axis, documents N over ``data``
    (and ``pod`` when present). Per iteration: Kᵀ@u and the c-mask are local;
    the contraction x = K_over_r @ v crosses the V sharding -> one psum of a
    (v_r, N_local) tile over ``model`` per iteration. This is the distributed
    analogue of the paper's shared-memory dense kernel.

``sparse`` (production path)
    After precompute, the ELL iteration touches only per-document state, so
    documents are sharded over *all* mesh axes (N / n_chips docs per chip)
    and the loop runs with ZERO collectives — the pod-scale version of the
    paper's observation that threads own disjoint nnz ranges. Precompute in
    the baseline recomputes cdist per chip (replicated V); the optimized
    variant (``sparse_vshard``) shards cdist over ``model`` and assembles G
    with one psum — see EXPERIMENTS.md §Perf.

Load balance across shards (the paper's nnz binary-search) is handled at
ingest by ``repro.data.corpus.shard_balanced``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .sinkhorn import LamUnderflowError, cdist, underflow_report
from .sinkhorn_sparse import (adaptive_loop, marginal_residual,
                              reconstruct_gm)
from .sparse import PaddedDocs


# jax >= 0.5 requires marking shard-varying scan carries with lax.pvary;
# on older jax (no varying-manual-axes type system) identity is correct.
_pvary = getattr(lax, "pvary", lambda x, axes: x)


def _doc_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes, used jointly to shard the document dimension."""
    return tuple(mesh.axis_names)


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


# --------------------------------------------------------------------------
# dense distributed (paper-faithful baseline)
# --------------------------------------------------------------------------

def sinkhorn_wmd_dense_distributed(r, vecs_sel, vecs, c, lam: float,
                                   n_iter: int, mesh: Mesh):
    """Dense Alg. 1 with V over ``model`` and N over the data axes.

    Inputs: r (v_r,) vecs_sel (v_r, w) vecs (V, w) c (V, N).
    V and N must divide the respective mesh axis sizes.
    """
    data_axes = _data_axes(mesh)
    v_spec = P("model")               # vocab-sharded
    c_spec = P("model", data_axes)
    out_spec = P(data_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), v_spec, c_spec),
        out_specs=out_spec)
    def run(r, vecs_sel, vecs_loc, c_loc):
        m = cdist(vecs_sel, vecs_loc)            # (v_r, V_loc)
        k = jnp.exp(-lam * m)
        k_over_r = k / r[:, None]
        km = k * m
        v_r = r.shape[0]
        n_loc = c_loc.shape[1]
        x = jnp.full((v_r, n_loc), 1.0 / v_r, dtype=k.dtype)
        x = _pvary(x, tuple(data_axes))  # carry varies over doc shards

        def body(x, _):
            u = 1.0 / x
            v = c_loc * (1.0 / (k.T @ u))        # local (V_loc, N_loc)
            # contraction over V crosses the model sharding -> one psum/iter
            x = lax.psum(k_over_r @ v, "model")
            return x, None

        x, _ = lax.scan(body, x, None, length=n_iter)
        u = 1.0 / x
        v = c_loc * (1.0 / (k.T @ u))
        return lax.psum(jnp.sum(u * (km @ v), axis=0), "model")

    return run(r, vecs_sel, vecs, c)


# --------------------------------------------------------------------------
# sparse distributed (production path)
# --------------------------------------------------------------------------

def _check_underflow(out, lam, vecs_sel, vecs, docs):
    """Host-side lam-hygiene guard shared by the distributed solvers: a K
    underflow poisons every affected shard's distances with NaN — raise the
    same diagnosed :class:`LamUnderflowError` the engine raises instead of
    returning (and all-reducing) NaN."""
    import numpy as np

    if vecs_sel.shape[0] > 0 and np.isnan(np.asarray(out)).any():
        raise LamUnderflowError(underflow_report(lam, vecs_sel, vecs, docs))
    return out


def sinkhorn_wmd_sparse_distributed(r, vecs_sel, vecs, docs: PaddedDocs,
                                    lam: float, n_iter: int, mesh: Mesh,
                                    vshard_precompute: bool = True,
                                    check_underflow: bool = True,
                                    tol: float | None = None,
                                    check_every: int = 4):
    """ELL fused Sinkhorn with docs sharded over every mesh axis.

    ``vshard_precompute=False``: baseline — every chip computes the full
    (v_r, V) cdist and gathers its docs' columns locally (replicated
    compute, zero collectives).

    ``vshard_precompute=True`` (beyond-paper optimized): cdist is sharded
    over ``model`` (each chip owns V/model_size vocab columns), each chip
    gathers the columns it owns for *its* docs and one psum over ``model``
    assembles G — cutting precompute FLOPs/chip by the model-axis size at
    the cost of a single (v_r, N_loc, L) all-reduce before the loop. (GM is
    reconstructed from G after the collective — each ELL entry is owned by
    exactly one vocab shard, so the scattered G is exact — which halves the
    assembly traffic versus shipping G and GM.)

    Both variants guard lam hygiene like the engine: NaN distances from a
    ``K = exp(-lam*M)`` underflow raise :class:`LamUnderflowError` with a
    diagnosis (``check_underflow=False`` opts out — the check syncs the
    sharded result).

    ``tol`` enables the convergence-adaptive loop (ISSUE 4): every
    ``check_every`` iterations each shard computes its local doc-marginal
    residual and ONE ``lax.pmax`` over the doc axes all-reduces it, so
    every shard exits at the same (earliest safe) iteration — the loop
    stays collective-free except for that scalar. ``n_iter`` becomes a
    cap (realized counts land on ``1 + k*check_every``, overshooting it
    by at most ``check_every - 1``).
    """
    doc_axes = _doc_axes(mesh)
    docs_spec = P(doc_axes)
    out_spec = P(doc_axes)
    # the adaptive path's lax.while_loop has no shard_map replication rule
    # (jax #workaround) — drop the rep check only when it is in play
    rep = {} if tol is None else {"check_rep": False}

    if not vshard_precompute:
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P(), P(), docs_spec, docs_spec),
            out_specs=out_spec, **rep)
        def run(r, vecs_sel, vecs_full, idx_loc, val_loc):
            m = cdist(vecs_sel, vecs_full)                 # replicated (v_r, V)
            k = jnp.exp(-lam * m)
            g = jnp.take(k, idx_loc, axis=1)
            return _ell_loop(r, g, val_loc, lam, n_iter, doc_axes,
                             tol=tol, check_every=check_every)

        out = run(r, vecs_sel, vecs, docs.idx, docs.val)
        if check_underflow:
            _check_underflow(out, lam, vecs_sel, vecs, docs)
        return out

    # optimized: vocab-sharded precompute, psum_scatter-assembled gather.
    # Docs enter sharded over the data axes and REPLICATED over model; each
    # model shard gathers the K columns it owns for every doc in the data
    # shard, then one psum_scatter over model simultaneously (a) sums the
    # per-vocab-shard contributions and (b) deals each model shard its
    # 1/model_size slice of the docs — after which the loop owns docs over
    # data x model jointly, same as the baseline.
    n_model = mesh.shape["model"]
    v = vecs.shape[0]
    v_loc_size = v // n_model
    data_axes = _data_axes(mesh)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P("model"), P(data_axes), P(data_axes)),
        out_specs=P(data_axes + ("model",)), **rep)
    def run(r, vecs_sel, vecs_loc, idx_loc, val_loc):
        midx = lax.axis_index("model")
        lo = midx * v_loc_size
        m = cdist(vecs_sel, vecs_loc)                      # (v_r, V_loc)
        k = jnp.exp(-lam * m)
        # gather only ids this chip owns; others contribute zeros to the sum
        rel = idx_loc - lo
        mine = (rel >= 0) & (rel < v_loc_size)
        rel = jnp.where(mine, rel, 0)
        g = jnp.where(mine[None], jnp.take(k, rel, axis=1), 0.0)
        # assemble + redistribute docs over the model axis in one collective;
        # GM is rebuilt from the assembled G, so it never crosses the wire
        g = lax.psum_scatter(g, "model", scatter_dimension=1, tiled=True)
        n_slice = val_loc.shape[0] // n_model
        val_my = lax.dynamic_slice_in_dim(val_loc, midx * n_slice, n_slice, 0)
        return _ell_loop(r, g, val_my, lam, n_iter,
                         data_axes + ("model",), tol=tol,
                         check_every=check_every)

    out = run(r, vecs_sel, vecs, docs.idx, docs.val)
    if check_underflow:
        _check_underflow(out, lam, vecs_sel, vecs, docs)
    return out


def _ell_loop(r, g, val, lam, n_iter, vary_axes=(), tol=None,
              check_every: int = 4):
    """The collective-free fused SDDMM_SpMM iteration (per shard).

    With ``tol`` set, the fixed scan becomes a ``lax.while_loop``: every
    ``check_every`` iterations each shard computes the doc-marginal
    residual ``max|val/t - w_prev|`` over its own docs (relative to each
    doc's marginal scale, live slots only) and one scalar ``lax.pmax``
    over ``vary_axes`` agrees on the global residual — all shards share
    one exit decision, so the carries stay consistent for the final
    distance line.
    """
    v_r = g.shape[0]
    n_loc, length = val.shape
    g_over_r = g / r[:, None, None]
    live = val > 0
    x = jnp.full((v_r, n_loc), 1.0 / v_r, dtype=g.dtype)
    if vary_axes:
        x = _pvary(x, tuple(vary_axes))  # match shard-varying carry type

    def step(carry, _):
        x, _ = carry
        u = 1.0 / x
        t = jnp.einsum("knl,kn->nl", g, u)
        w = jnp.where(live, val / t, 0.0)
        x = jnp.einsum("knl,nl->kn", g_over_r, w)
        return (x, w), None

    if tol is None:
        # x-only carry — bit-identical to the pre-adaptive loop
        x, _ = lax.scan(lambda x, _: (step((x, None), None)[0][0], None),
                        x, None, length=n_iter)
    else:
        # the one collective in the loop: a scalar all-reduce so every
        # shard takes the same exit
        all_reduce = ((lambda r: lax.pmax(r, tuple(vary_axes)))
                      if vary_axes else None)
        x, _ = adaptive_loop(
            lambda x: step((x, None), None)[0],
            lambda w, wp: marginal_residual(w, wp, live),
            x, n_iter, tol, check_every, all_reduce=all_reduce)
    u = 1.0 / x
    t = jnp.einsum("knl,kn->nl", g, u)
    w = jnp.where(live, val / t, 0.0)
    return jnp.einsum("kn,knl,nl->n", u, reconstruct_gm(g, lam), w)


def sharded_inputs(mesh: Mesh, r, vecs_sel, vecs, docs: PaddedDocs,
                   for_impl: str = "sparse"):
    """Device_put inputs with the shardings the distributed solvers expect."""
    doc_axes = _doc_axes(mesh)
    if for_impl == "sparse":
        specs = dict(vecs=P(), idx=P(doc_axes), val=P(doc_axes))
    else:
        specs = dict(vecs=P("model"), idx=None, val=None)

    def put(x, s):
        return jax.device_put(x, NamedSharding(mesh, s))
    out = dict(r=put(r, P()), vecs_sel=put(vecs_sel, P()),
               vecs=put(vecs, specs["vecs"]))
    if for_impl == "sparse":
        out["docs"] = PaddedDocs(idx=put(docs.idx, specs["idx"]),
                                 val=put(docs.val, specs["val"]))
    return out
