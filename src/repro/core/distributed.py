"""Multi-chip Sinkhorn-WMD via shard_map — the paper's parallelization at pod
scale (DESIGN.md §3).

Two distribution schemes, mirroring the paper's baseline->optimized arc:

``dense`` (paper-faithful distributed baseline)
    Vocabulary V sharded over the ``model`` axis, documents N over ``data``
    (and ``pod`` when present). Per iteration: Kᵀ@u and the c-mask are local;
    the contraction x = K_over_r @ v crosses the V sharding -> one psum of a
    (v_r, N_local) tile over ``model`` per iteration. This is the distributed
    analogue of the paper's shared-memory dense kernel.

``sparse`` (production path)
    After precompute, the ELL iteration touches only per-document state, so
    documents are sharded over *all* mesh axes (N / n_chips docs per chip)
    and the loop runs with ZERO collectives — the pod-scale version of the
    paper's observation that threads own disjoint nnz ranges. Precompute in
    the baseline recomputes cdist per chip (replicated V); the optimized
    variant (``sparse_vshard``) shards cdist over ``model`` and assembles G
    with one psum — see EXPERIMENTS.md §Perf.

Load balance across shards (the paper's nnz binary-search) is handled at
ingest by ``repro.data.corpus.shard_balanced``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .sinkhorn import LamUnderflowError, cdist, underflow_report
from .sinkhorn_sparse import (adaptive_loop_scoped,
                              marginal_residual_per_query, reconstruct_gm)
from .sparse import PaddedDocs


# jax >= 0.5 requires marking shard-varying scan carries with lax.pvary;
# on older jax (no varying-manual-axes type system) identity is correct.
_pvary = getattr(lax, "pvary", lambda x, axes: x)


def _doc_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes, used jointly to shard the document dimension."""
    return tuple(mesh.axis_names)


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


# --------------------------------------------------------------------------
# dense distributed (paper-faithful baseline)
# --------------------------------------------------------------------------

def sinkhorn_wmd_dense_distributed(r, vecs_sel, vecs, c, lam: float,
                                   n_iter: int, mesh: Mesh):
    """Dense Alg. 1 with V over ``model`` and N over the data axes.

    Inputs: r (v_r,) vecs_sel (v_r, w) vecs (V, w) c (V, N).
    V and N must divide the respective mesh axis sizes.
    """
    data_axes = _data_axes(mesh)
    v_spec = P("model")               # vocab-sharded
    c_spec = P("model", data_axes)
    out_spec = P(data_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), v_spec, c_spec),
        out_specs=out_spec)
    def run(r, vecs_sel, vecs_loc, c_loc):
        m = cdist(vecs_sel, vecs_loc)            # (v_r, V_loc)
        k = jnp.exp(-lam * m)
        k_over_r = k / r[:, None]
        km = k * m
        v_r = r.shape[0]
        n_loc = c_loc.shape[1]
        x = jnp.full((v_r, n_loc), 1.0 / v_r, dtype=k.dtype)
        x = _pvary(x, tuple(data_axes))  # carry varies over doc shards

        def body(x, _):
            u = 1.0 / x
            v = c_loc * (1.0 / (k.T @ u))        # local (V_loc, N_loc)
            # contraction over V crosses the model sharding -> one psum/iter
            x = lax.psum(k_over_r @ v, "model")
            return x, None

        x, _ = lax.scan(body, x, None, length=n_iter)
        u = 1.0 / x
        v = c_loc * (1.0 / (k.T @ u))
        return lax.psum(jnp.sum(u * (km @ v), axis=0), "model")

    return run(r, vecs_sel, vecs, c)


# --------------------------------------------------------------------------
# sparse distributed (production path)
# --------------------------------------------------------------------------

def _check_underflow(out, lam, vecs_sel, vecs, docs, mesh: Mesh = None,
                     doc_ids=None):
    """Host-side lam-hygiene guard shared by the distributed solvers: a K
    underflow poisons every affected shard's distances with NaN — raise the
    same diagnosed :class:`LamUnderflowError` the engine raises instead of
    returning (and all-reducing) NaN. Batched (Q, v_r, w) support stacks
    are flattened for the report (it diagnoses per support word).

    With ``mesh`` the report names the OWNING SHARD(S) of the poisoned
    doc positions (docs are dealt to shards in contiguous mesh-order
    blocks, so ownership is position // block), and with ``doc_ids`` it
    quotes EXTERNAL doc ids instead of storage positions — a poisoned
    request's diagnosis stays actionable on the sharded path, mirroring
    the batched-path fix (storage positions are meaningless to callers
    once the cluster-major permutation and the shard deal are applied).
    """
    import numpy as np

    if vecs_sel.shape[0] > 0 and np.isnan(np.asarray(out)).any():
        sel2 = jnp.reshape(vecs_sel, (-1, vecs_sel.shape[-1]))
        msg = underflow_report(lam, sel2, vecs, docs)
        out_np = np.asarray(out)
        nan_docs = np.nonzero(
            np.isnan(out_np).any(axis=0) if out_np.ndim == 2
            else np.isnan(out_np))[0]
        if nan_docs.size:
            ids = (np.asarray(doc_ids)[nan_docs] if doc_ids is not None
                   else nan_docs)
            shown = ids[:8].tolist()
            tail = ", ..." if ids.size > 8 else ""
            kind = "external doc ids" if doc_ids is not None \
                else "doc positions"
            where = f"{nan_docs.size} poisoned docs ({kind} {shown}{tail})"
            if mesh is not None:
                n_shards = int(mesh.devices.size)
                block = max(1, out_np.shape[-1] // n_shards)
                owners = sorted({int(d // block) for d in nan_docs})
                where = (f"owning shard(s) {owners} of {n_shards} on mesh "
                         f"{dict(mesh.shape)}; " + where)
            msg = f"{where} — {msg}"
        raise LamUnderflowError(msg)
    return out


def sinkhorn_wmd_sparse_distributed(r, vecs_sel, vecs, docs: PaddedDocs,
                                    lam: float, n_iter: int, mesh: Mesh,
                                    vshard_precompute: bool = True,
                                    check_underflow: bool = True,
                                    tol: float | None = None,
                                    check_every: int = 4,
                                    qmask=None,
                                    return_iters: bool = False,
                                    doc_ids=None):
    """ELL fused Sinkhorn with docs sharded over every mesh axis.

    ``vshard_precompute=False``: baseline — every chip computes the full
    (v_r, V) cdist and gathers its docs' columns locally (replicated
    compute, zero collectives).

    ``vshard_precompute=True`` (beyond-paper optimized): cdist is sharded
    over ``model`` (each chip owns V/model_size vocab columns), each chip
    gathers the columns it owns for *its* docs and one psum over ``model``
    assembles G — cutting precompute FLOPs/chip by the model-axis size at
    the cost of a single (v_r, N_loc, L) all-reduce before the loop. (GM is
    reconstructed from G after the collective — each ELL entry is owned by
    exactly one vocab shard, so the scattered G is exact — which halves the
    assembly traffic versus shipping G and GM.)

    Both variants guard lam hygiene like the engine: NaN distances from a
    ``K = exp(-lam*M)`` underflow raise :class:`LamUnderflowError` with a
    diagnosis (``check_underflow=False`` opts out — the check syncs the
    sharded result).

    Batched queries (ISSUE 5): ``r`` may be (Q, v_r) with ``vecs_sel``
    (Q, v_r, w) — the solve runs all Q queries against the shared doc
    shards in one launch and returns (Q, N). ``qmask`` (Q, v_r) marks
    live support rows when queries were padded to a common ``v_r``
    (padded rows: ``r == 1``, ``qmask == 0``; their G rows are zeroed so
    they stay inert, the engine's padding contract).

    ``tol`` enables the convergence-adaptive loop: every ``check_every``
    iterations each shard reduces its local doc-marginal residual to a
    PER-QUERY (Q,) vector and ONE ``lax.pmax`` over the doc axes
    all-reduces that vector — still a single collective per check (ISSUE
    4's scalar became ISSUE 5's (Q,) vector). Every shard therefore
    freezes the same queries at the same (earliest safe) iteration:
    converged queries' x-columns stop updating while stubborn batch-mates
    run on, and the loop exits when all live queries converged or the
    ``n_iter`` cap hits (realized counts land on ``1 + k*check_every``,
    overshooting the cap by at most ``check_every - 1``).
    ``return_iters=True`` also returns the per-query realized counts
    ((Q,) int32; scalar-shaped (1,) for a single query).

    ``doc_ids`` (N,) optionally names each doc position's EXTERNAL id in
    the underflow diagnosis (see :func:`_check_underflow`) — callers that
    permuted or shard-dealt storage should pass it so a poisoned
    request's report quotes ids the caller can act on.
    """
    doc_axes = _doc_axes(mesh)
    docs_spec = P(doc_axes)
    batched = jnp.ndim(r) == 2
    out_spec = P(None, doc_axes) if batched else P(doc_axes)
    # the adaptive path's lax.while_loop has no shard_map replication rule
    # (jax #workaround) — drop the rep check only when it is in play
    rep = {} if tol is None else {"check_rep": False}

    def finish(out_iters):
        out, iters = out_iters
        if check_underflow:
            _check_underflow(out, lam, vecs_sel, vecs, docs, mesh=mesh,
                             doc_ids=doc_ids)
        return (out, iters) if return_iters else out

    if not vshard_precompute:
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P(), P(), docs_spec, docs_spec),
            out_specs=(out_spec, P()), **rep)
        def run(r, vecs_sel, vecs_full, idx_loc, val_loc):
            sel2 = vecs_sel.reshape(-1, vecs_sel.shape[-1])
            m = cdist(sel2, vecs_full)            # replicated (Q*v_r, V)
            k = jnp.exp(-lam * m)
            g = jnp.take(k, idx_loc, axis=1)      # (Q*v_r, N_loc, L)
            if batched:
                g = g.reshape(r.shape + idx_loc.shape)
            out, iters = _ell_loop(r, g, val_loc, lam, n_iter, doc_axes,
                                   tol=tol, check_every=check_every,
                                   qmask=qmask)
            return (out if batched else out[0]), iters

        return finish(run(r, vecs_sel, vecs, docs.idx, docs.val))

    # optimized: vocab-sharded precompute, psum_scatter-assembled gather.
    # Docs enter sharded over the data axes and REPLICATED over model; each
    # model shard gathers the K columns it owns for every doc in the data
    # shard, then one psum_scatter over model simultaneously (a) sums the
    # per-vocab-shard contributions and (b) deals each model shard its
    # 1/model_size slice of the docs — after which the loop owns docs over
    # data x model jointly, same as the baseline.
    n_model = mesh.shape["model"]
    v = vecs.shape[0]
    v_loc_size = v // n_model
    data_axes = _data_axes(mesh)
    vs_out = (P(None, data_axes + ("model",)) if batched
              else P(data_axes + ("model",)))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P("model"), P(data_axes), P(data_axes)),
        out_specs=(vs_out, P()), **rep)
    def run(r, vecs_sel, vecs_loc, idx_loc, val_loc):
        midx = lax.axis_index("model")
        lo = midx * v_loc_size
        sel2 = vecs_sel.reshape(-1, vecs_sel.shape[-1])
        m = cdist(sel2, vecs_loc)                 # (Q*v_r, V_loc)
        k = jnp.exp(-lam * m)
        # gather only ids this chip owns; others contribute zeros to the sum
        rel = idx_loc - lo
        mine = (rel >= 0) & (rel < v_loc_size)
        rel = jnp.where(mine, rel, 0)
        g = jnp.where(mine[None], jnp.take(k, rel, axis=1), 0.0)
        # assemble + redistribute docs over the model axis in one collective;
        # GM is rebuilt from the assembled G, so it never crosses the wire
        g = lax.psum_scatter(g, "model", scatter_dimension=1, tiled=True)
        n_slice = val_loc.shape[0] // n_model
        val_my = lax.dynamic_slice_in_dim(val_loc, midx * n_slice, n_slice, 0)
        if batched:
            g = g.reshape(r.shape + (n_slice, idx_loc.shape[1]))
        out, iters = _ell_loop(r, g, val_my, lam, n_iter,
                               data_axes + ("model",), tol=tol,
                               check_every=check_every, qmask=qmask)
        return (out if batched else out[0]), iters

    return finish(run(r, vecs_sel, vecs, docs.idx, docs.val))


def _ell_loop(r, g, val, lam, n_iter, vary_axes=(), tol=None,
              check_every: int = 4, qmask=None):
    """The collective-free fused SDDMM_SpMM iteration (per shard).

    Accepts one query (``g`` (v_r, N_loc, L), ``r`` (v_r,)) or a batch
    (``g`` (Q, v_r, N_loc, L), ``r`` (Q, v_r)); internally everything is
    the batched layout (a single query is Q == 1) so there is ONE copy of
    the loop. Returns ((Q, N_loc) wmd, (Q,) realized iterations).

    With ``tol`` set, the fixed scan becomes the per-query
    :func:`~repro.core.sinkhorn_sparse.adaptive_loop_scoped`: every
    ``check_every`` iterations each shard reduces its local doc-marginal
    residual ``max|val/t - w_prev|`` per query and one (Q,)-vector
    ``lax.pmax`` over ``vary_axes`` agrees on them globally — all shards
    freeze the same queries at the same iteration, so the carries stay
    consistent for the final distance line.
    """
    if g.ndim == 3:
        g, r = g[None], jnp.reshape(r, (1, -1))
    q, v_r, n_loc, length = g.shape
    g_over_r = g / r[:, :, None, None]
    if qmask is not None:
        # padded support rows are structurally inert: G rows zeroed, u
        # rows masked (their x decays to 0 after one iteration)
        g = g * qmask[:, :, None, None]
        g_over_r = g_over_r * qmask[:, :, None, None]
    live = val > 0
    n_live = (jnp.sum(qmask, axis=1) if qmask is not None
              else jnp.full((q,), v_r, g.dtype))
    x0 = 1.0 / jnp.maximum(n_live, 1.0)
    x = jnp.broadcast_to(x0[:, None, None], (q, v_r, n_loc)).astype(g.dtype)
    if qmask is not None:
        x = x * qmask[:, :, None]
    if vary_axes:
        x = _pvary(x, tuple(vary_axes))  # match shard-varying carry type

    def u_of(x):
        if qmask is None:
            return 1.0 / x   # raw: a K underflow must surface as NaN
        return jnp.where(qmask[:, :, None] > 0, 1.0 / jnp.where(
            qmask[:, :, None] > 0, x, 1.0), 0.0)

    def step(carry, _):
        x, _ = carry
        u = u_of(x)
        t = jnp.einsum("qknl,qkn->qnl", g, u)
        w = jnp.where(live[None], val[None] / t, 0.0)
        x = jnp.einsum("qknl,qnl->qkn", g_over_r, w)
        return (x, w), None

    if tol is None:
        # x-only carry — bit-identical to the pre-adaptive loop
        x, _ = lax.scan(lambda x, _: (step((x, None), None)[0][0], None),
                        x, None, length=n_iter)
        iters = jnp.full((q,), n_iter, jnp.int32)
    else:
        # the one collective in the loop: a (Q,) vector all-reduce so
        # every shard freezes the same queries at the same check
        all_reduce = ((lambda res: lax.pmax(res, tuple(vary_axes)))
                      if vary_axes else None)
        live_q = (jnp.sum(qmask, axis=1) > 0 if qmask is not None
                  else jnp.ones((q,), bool))
        resmask = jnp.broadcast_to(live[None], (q,) + val.shape)

        def step_active(x, active):
            # frozen queries' update rows are dropped via the u mask
            u = u_of(x) * active[:, None, None].astype(g.dtype)
            t = jnp.einsum("qknl,qkn->qnl", g, u)
            w = jnp.where(live[None], val[None] / t, 0.0)
            return jnp.einsum("qknl,qnl->qkn", g_over_r, w), w

        x, iters = adaptive_loop_scoped(
            step_active,
            lambda w, wp: marginal_residual_per_query(w, wp, resmask),
            x, n_iter, tol, check_every, live_q, all_reduce=all_reduce)
    u = u_of(x)
    t = jnp.einsum("qknl,qkn->qnl", g, u)
    w = jnp.where(live[None], val[None] / t, 0.0)
    wmd = jnp.einsum("qkn,qknl,qnl->qn", u, reconstruct_gm(g, lam), w)
    return wmd, iters


def sharded_inputs(mesh: Mesh, r, vecs_sel, vecs, docs: PaddedDocs,
                   for_impl: str = "sparse"):
    """Device_put inputs with the shardings the distributed solvers expect."""
    doc_axes = _doc_axes(mesh)
    if for_impl == "sparse":
        specs = dict(vecs=P(), idx=P(doc_axes), val=P(doc_axes))
    else:
        specs = dict(vecs=P("model"), idx=None, val=None)

    def put(x, s):
        return jax.device_put(x, NamedSharding(mesh, s))
    out = dict(r=put(r, P()), vecs_sel=put(vecs_sel, P()),
               vecs=put(vecs, specs["vecs"]))
    if for_impl == "sparse":
        out["docs"] = PaddedDocs(idx=put(docs.idx, specs["idx"]),
                                 val=put(docs.val, specs["val"]))
    return out
