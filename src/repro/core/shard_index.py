"""Sharded corpus serving: cluster-aligned doc shards over a device mesh.

The paper's headline scenario — one query against a day of tweets — is a
corpus-scale problem; one device's memory and FLOPs bound the single-host
:class:`~repro.core.index.WmdEngine`. This module partitions the corpus
into DOC SHARDS across a 1-D device mesh and runs the *entire* existing
cascade (probe -> radius-drop -> WCD -> RWMD -> seed/survivor Sinkhorn)
per shard, locally, on each shard's own device:

- **Cluster-aligned**: whole IVF clusters per shard. One k-means runs
  globally (:func:`shard_corpus`), then a greedy bin-pack over cluster
  sizes balances doc counts; each shard's :class:`CorpusIndex` is built
  via :func:`build_index`'s precomputed-clusters hook over its owned
  clusters (locally relabeled), so PR 4's cluster-major storage makes
  every shard slice contiguous and all downstream invariants hold
  unchanged.
- **One merge collective**: per-shard local top-k results are packed into
  a single ``(S, Q, 2k)`` tensor laid out over the mesh, and the global
  top-k is ONE ``lax.all_gather`` inside a ``shard_map`` followed by a
  local ``lax.top_k`` — never a per-doc or per-cluster exchange. The
  per-shard cascades themselves are collective-free (each shard's
  adaptive exit is local); the only other collective in the codebase's
  sharded story is the per-query ``(Q,)`` residual ``pmax`` on
  :func:`repro.core.distributed.sinkhorn_wmd_sparse_distributed`'s
  cross-shard *solve* path (the PR 5 pattern, unchanged).
- **Exactness contract**: at ``nprobe=None`` (= all clusters) the sharded
  top-k equals the single-device top-k up to tie order, because every
  shard scores all of its clusters exactly and the merge is a true global
  top-k. Smaller ``nprobe`` applies PER SHARD: each shard probes its
  ``nprobe`` nearest owned clusters, so recall semantics match today's
  measured-recall story cluster-for-cluster (a doc is reachable iff its
  cluster is among the ``nprobe`` nearest of its OWNING shard).

Device placement uses committed arrays: each shard's index leaves are
``jax.device_put`` to that shard's device, so the per-shard jitted
cascades execute on their own device (uncommitted staged query arrays
follow the committed index operands). On CPU, force a multi-device mesh
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (see
:func:`repro.runtime.sharding.ensure_host_devices`).

TPU-pod design notes: the same structure maps onto a pod slice — the
mesh axis becomes a physical ring, the packed ``(S, Q, 2k)`` merge rides
the ICI all-gather (``2k * 4`` bytes per query per shard, independent of
corpus size), and per-shard HBM residency is ``~N/S`` docs. The pieces
that change are placement (``jax.make_mesh`` over the slice instead of
host devices) and the host-side staging loop, which should move to
per-shard async dispatch; the collective inventory (one all-gather per
search) already fits a pod's latency budget.

Single-shard use runs in-process with no mesh setup (runnable — the CI
``docs`` job executes this as a doctest)::

    >>> from repro.core import ShardedWmdEngine, shard_corpus
    >>> from repro.data.corpus import make_corpus
    >>> c = make_corpus(vocab_size=64, embed_dim=8, n_docs=12,
    ...                 n_queries=2, words_per_doc=(3, 8), seed=0)
    >>> sindex = shard_corpus(c.docs, c.vecs, 1, n_clusters=3)
    >>> engine = ShardedWmdEngine(sindex, lam=2.0, n_iter=10)
    >>> engine.search(list(c.queries), 3).indices.shape
    (2, 3)
"""
from __future__ import annotations

import functools
import os
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.fault_tolerance import PoisonStep, ShardHealth

from .index import (CorpusIndex, SearchResult, WmdEngine, _assign_clusters,
                    _compact_slots, _doc_centroids, _kmeans, append_docs,
                    auto_n_clusters, build_index, default_n_clusters,
                    load_index, save_index, snapshot_checksum)
from .sinkhorn import LamUnderflowError
from .sparse import PaddedDocs

# global doc ids ride through the merge collective as float32 payload
# lanes; above 2^24 the round-trip stops being exact
_MAX_DOCS_F32 = 1 << 24


def bin_pack_clusters(sizes: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy bin-pack: assign whole clusters to shards, balancing doc
    count. Clusters are placed largest-first onto the currently-lightest
    shard (LPT scheduling — within 4/3 of the optimal makespan, and in
    practice near-balanced for IVF size distributions). Returns
    ``shard_of_cluster`` (C,) int32. Deterministic: ties in both the size
    sort and the argmin break toward lower ids."""
    sizes = np.asarray(sizes, np.int64)
    order = np.argsort(-sizes, kind="stable")
    loads = np.zeros(n_shards, np.int64)
    shard_of = np.empty(sizes.shape[0], np.int32)
    for c in order:
        s = int(np.argmin(loads))
        shard_of[c] = s
        loads[s] += sizes[c]
    return shard_of


def _index_to_device(index: CorpusIndex, device) -> CorpusIndex:
    """Commit every device-array leaf of a :class:`CorpusIndex` to one
    device. Host mirrors (``docs_host``, cluster membership arrays) stay
    host-side; committed leaves pin the per-shard jitted cascades to the
    shard's device, and uncommitted staged query arrays follow them."""
    put = functools.partial(jax.device_put, device=device)
    groups = tuple(g._replace(docs=PaddedDocs(idx=put(g.docs.idx),
                                              val=put(g.docs.val)),
                              cols=put(g.cols)) for g in index.groups)
    clusters = index.clusters
    if clusters is not None:
        clusters = clusters._replace(centers=put(clusters.centers),
                                     assign_dev=put(clusters.assign_dev))
    return index._replace(
        docs=PaddedDocs(idx=put(index.docs.idx), val=put(index.docs.val)),
        groups=groups, vecs=put(index.vecs), vecs_sq=put(index.vecs_sq),
        centroids=put(index.centroids), clusters=clusters,
        pivots=None if index.pivots is None else put(index.pivots),
        doc_pivot_d=(None if index.doc_pivot_d is None
                     else put(index.doc_pivot_d)))


class ShardedCorpusIndex(NamedTuple):
    """Corpus partitioned into cluster-aligned doc shards over a mesh.

    Ids: each shard's :class:`CorpusIndex` speaks its own local id space
    (``ext_ids`` inside a shard translate shard storage -> shard-local
    caller order, exactly as single-device); ``global_ids[s]`` then lifts
    shard-local caller ids to the GLOBAL caller-order doc ids the sharded
    engine reports. ``owner`` is the inverse direction: global doc id ->
    owning shard.
    """

    shards: tuple            # tuple[CorpusIndex] — one per mesh device
    global_ids: tuple        # tuple[np (n_s,)]: shard-local -> global id
    owner: np.ndarray        # (N,) host: global doc id -> shard
    centers: jax.Array       # (C, w) GLOBAL frozen k-means centers
    shard_of_cluster: np.ndarray  # (C,) host: global cluster -> shard
    mesh: Mesh               # 1-D mesh, axis "shard"
    devices: tuple           # the mesh's devices, shard-major

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_docs(self) -> int:
        return int(self.owner.shape[0])

    @property
    def docs_per_shard(self) -> tuple:
        return tuple(ix.n_docs for ix in self.shards)

    @property
    def cluster_counts(self) -> tuple:
        return tuple(ix.clusters.n_clusters for ix in self.shards)


def _resolve_devices(n_shards: int, devices=None):
    devs = tuple(devices) if devices is not None else tuple(jax.devices())
    if len(devs) < n_shards:
        raise RuntimeError(
            f"{n_shards} shards need {n_shards} devices but only "
            f"{len(devs)} are visible. On CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards} "
            f"before the first jax call (or use "
            f"repro.runtime.sharding.ensure_host_devices).")
    return devs[:n_shards]


def shard_corpus(docs: PaddedDocs, vecs, n_shards: int, dtype=jnp.float32,
                 doc_groups: int = 4, n_clusters=None, ivf_iters: int = 10,
                 ivf_seed: int = 0, devices=None, n_pivots: int = 8,
                 pivot_seed: int = 0) -> ShardedCorpusIndex:
    """Partition a corpus into cluster-aligned doc shards.

    One global mini-batch-Lloyd k-means over the per-doc centroids (the
    same quantizer :func:`build_index` would freeze), then
    :func:`bin_pack_clusters` balances whole clusters across ``n_shards``
    by doc count, and each shard's :class:`CorpusIndex` is assembled over
    its owned docs with the global centers subset as a precomputed frozen
    quantizer. The vocabulary embedding table is replicated per shard
    (every shard's cascade needs all word vectors); doc-proportional state
    is ``~N/S`` per shard.

    ``n_clusters`` resolves exactly as in :func:`build_index` (int /
    ``None`` = sqrt(N) / ``"auto"`` / numeric string) and is then clamped
    up to ``n_shards`` so every shard can own at least one cluster.
    ``n_pivots``/``pivot_seed`` flow into each shard's
    :func:`build_index`: pivot selection is over the REPLICATED
    vocabulary embeddings, so every shard freezes the identical pivot set
    and only the per-doc distance tables are shard-local.

    Failure modes: raises :class:`ValueError` when the corpus exceeds the
    merge's 2^24 float32 id-lane limit, when ``n_docs < n_shards``, or
    when a shard would own zero docs; raises :class:`RuntimeError` when
    fewer than ``n_shards`` devices are visible (on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first).
    """
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    devs = _resolve_devices(n_shards, devices)
    mesh = Mesh(np.asarray(devs), axis_names=("shard",))

    dtype = jnp.dtype(dtype)
    vecs_np = np.asarray(vecs, dtype)
    idx_np, val_np = _compact_slots(docs, dtype)
    n_docs = idx_np.shape[0]
    if n_docs >= _MAX_DOCS_F32:
        raise ValueError(
            f"sharded merge packs doc ids into float32 lanes; corpus size "
            f"{n_docs} >= 2^24 breaks the exact round-trip")
    if n_docs < n_shards:
        raise ValueError(f"cannot spread {n_docs} docs over {n_shards} "
                         f"shards")
    centroids_np = _doc_centroids(idx_np, val_np, vecs_np)
    if isinstance(n_clusters, str):
        if n_clusters == "auto":
            n_clusters = auto_n_clusters(centroids_np, seed=ivf_seed)
        elif n_clusters.isdigit():
            n_clusters = int(n_clusters)
        else:
            raise ValueError(f"n_clusters must be an int, None, or "
                             f"'auto', got {n_clusters!r}")
    elif n_clusters is None:
        n_clusters = default_n_clusters(n_docs)
    n_clusters = max(n_shards, min(int(n_clusters), n_docs))

    centers, assign = _kmeans(jnp.asarray(centroids_np), n_clusters,
                              n_iters=ivf_iters, seed=ivf_seed)
    centers_np = np.asarray(centers)
    sizes = np.bincount(assign, minlength=n_clusters)
    shard_of_cluster = bin_pack_clusters(sizes, n_shards)

    shards, global_ids = [], []
    owner = np.empty(n_docs, np.int32)
    for s in range(n_shards):
        owned = np.nonzero(shard_of_cluster == s)[0]
        doc_sel = np.nonzero(np.isin(assign, owned))[0].astype(np.int32)
        if doc_sel.size == 0:
            raise ValueError(
                f"shard {s} of {n_shards} would own no docs "
                f"({n_clusters} clusters, sizes {sizes.tolist()}); use "
                f"fewer shards or more clusters")
        owner[doc_sel] = s
        relabel = np.full(n_clusters, -1, np.int32)
        relabel[owned] = np.arange(owned.size, dtype=np.int32)
        ix = build_index(
            PaddedDocs(idx=idx_np[doc_sel], val=val_np[doc_sel]),
            vecs_np, dtype, doc_groups=doc_groups,
            clusters=(centers_np[owned], relabel[assign[doc_sel]]),
            n_pivots=n_pivots, pivot_seed=pivot_seed)
        shards.append(_index_to_device(ix, devs[s]))
        global_ids.append(doc_sel)
    return ShardedCorpusIndex(
        shards=tuple(shards), global_ids=tuple(global_ids), owner=owner,
        centers=jax.device_put(centers, devs[0]),
        shard_of_cluster=shard_of_cluster, mesh=mesh, devices=devs)


def append_docs_sharded(sindex: ShardedCorpusIndex, new_docs: PaddedDocs,
                        dtype=jnp.float32) -> ShardedCorpusIndex:
    """Streaming sharded append: route each new doc to the shard owning
    its nearest FROZEN global center, then run the single-device
    :func:`append_docs` per grown shard. Because every shard's local
    quantizer is a subset of the global centers and the routed shard
    contains the global argmin center, the per-shard nearest-center
    assignment agrees with the global one — append-then-search matches
    rebuild-then-search exactly at ``nprobe=None`` (property-tested)."""
    n_new = new_docs.idx.shape[0]
    if n_new == 0:
        return sindex
    new_idx, new_val = _compact_slots(new_docs, dtype)
    n_old = sindex.n_docs
    if n_old + n_new >= _MAX_DOCS_F32:
        raise ValueError("appended corpus would exceed the 2^24-doc "
                         "float32 id-lane limit of the sharded merge")
    cent_new = _doc_centroids(new_idx, new_val,
                              np.asarray(sindex.shards[0].vecs))
    assign_new = np.asarray(_assign_clusters(jnp.asarray(cent_new),
                                             sindex.centers))
    owner_new = sindex.shard_of_cluster[assign_new]

    shards, global_ids = list(sindex.shards), list(sindex.global_ids)
    tail = np.arange(n_old, n_old + n_new, dtype=np.int32)
    for s in range(sindex.n_shards):
        mine = np.nonzero(owner_new == s)[0]
        if mine.size == 0:
            continue
        grown = append_docs(
            shards[s],
            PaddedDocs(idx=new_idx[mine], val=new_val[mine]), dtype)
        shards[s] = _index_to_device(grown, sindex.devices[s])
        global_ids[s] = np.concatenate([global_ids[s], tail[mine]])
    return sindex._replace(
        shards=tuple(shards), global_ids=tuple(global_ids),
        owner=np.concatenate([sindex.owner,
                              owner_new.astype(np.int32)]))


class ShardSearchError(Exception):
    """Structured shard fan-out failure, naming the shard(s) involved.

    Raised when a shard's dispatch exhausts its retry budget (per-shard
    structured error, the fan-out analogue of the underflow diagnostics
    that already name the owning shard), or by the fan-out itself when
    EVERY shard failed and there is nothing to merge. Deliberately NOT a
    ``RuntimeError``: the serving ``DispatchGuard`` classifies
    RuntimeError as transient-and-retryable, and a fan-out that already
    consumed its own per-shard retries must not be retried again
    upstream (the ``DispatchFailed`` convention)."""

    def __init__(self, message: str, shard_reasons: dict | None = None):
        super().__init__(message)
        self.shard_reasons = dict(shard_reasons or {})


class ShardCoverage(NamedTuple):
    """How much of the corpus a sharded result actually covers.

    ``fraction == 1.0`` (empty ``missing_shards``) means every shard
    contributed and the usual exactness contract holds; anything less is
    a PARTIAL result — still a true top-k over the responding shards'
    docs, but recall against the full corpus is bounded above by
    ``fraction`` and the serving layer must not claim exactness."""

    fraction: float          # covered docs / corpus docs
    covered_docs: int
    missing_shards: tuple    # shard ids that did not contribute
    reasons: dict            # {shard id: "timeout" | "open_circuit" | error}

    @property
    def full(self) -> bool:
        return not self.missing_shards


# ----------------------------------------------------------------- snapshots
_SHARD_META_FILE = "meta.npz"


def _shard_file(shard_id: int) -> str:
    return f"shard_{shard_id:04d}.npz"


def snapshot_shards(sindex: ShardedCorpusIndex, snapshot_dir) -> list:
    """Persist a sharded index: one :func:`repro.core.index.save_index`
    file per shard plus a checksummed ``meta.npz`` holding the mesh-level
    state (owner map, global centers, cluster->shard map, per-shard
    global ids). Recovery granularity is ONE shard:
    :func:`restore_shard` reloads a single dead shard's file and rejoins
    it to the live mesh without touching the survivors. Returns the
    written paths."""
    os.makedirs(snapshot_dir, exist_ok=True)
    paths = []
    for si, ix in enumerate(sindex.shards):
        p = os.path.join(snapshot_dir, _shard_file(si))
        save_index(ix, p)
        paths.append(p)
    meta = {
        "owner": np.asarray(sindex.owner),
        "centers": np.asarray(sindex.centers),
        "shard_of_cluster": np.asarray(sindex.shard_of_cluster),
        "n_shards": np.asarray(sindex.n_shards, np.int64),
    }
    for si, gids in enumerate(sindex.global_ids):
        meta[f"global_ids_{si}"] = np.asarray(gids)
    meta["checksum"] = np.asarray(snapshot_checksum(meta), np.uint32)
    mp = os.path.join(snapshot_dir, _SHARD_META_FILE)
    with open(mp, "wb") as f:
        np.savez(f, **meta)
    paths.append(mp)
    return paths


def restore_shard(sindex: ShardedCorpusIndex, shard_id: int,
                  snapshot_dir) -> ShardedCorpusIndex:
    """Dead-shard recovery: reload shard ``shard_id`` from its
    :func:`snapshot_shards` file, commit it to the shard's mesh device,
    and return the sharded index with that shard replaced.

    Validates before trusting: the meta checksum must verify, the
    snapshot's shard count must match the live mesh, and the snapshot's
    global-id set for this shard must equal the live one — a snapshot
    taken before an :func:`append_docs_sharded` is STALE for the grown
    shard and restoring it would silently drop documents, so that is a
    ``ValueError``, not a best-effort merge. Restore-then-search is
    bit-compatible with never-failed search (``load_index`` reconstructs
    the identical index; property-tested at ``nprobe=None``)."""
    si = int(shard_id)
    with np.load(os.path.join(snapshot_dir, _SHARD_META_FILE)) as z:
        meta = {k: z[k] for k in z.files}
    stored = int(meta.pop("checksum"))
    actual = snapshot_checksum(meta)
    if actual != stored:
        raise ValueError(
            f"sharded snapshot meta in {snapshot_dir!r} failed its "
            f"integrity check (stored crc32 {stored:#010x}, recomputed "
            f"{actual:#010x})")
    snap_shards = int(meta["n_shards"])
    if snap_shards != sindex.n_shards:
        raise ValueError(f"snapshot has {snap_shards} shards; live mesh "
                         f"has {sindex.n_shards}")
    if not 0 <= si < sindex.n_shards:
        raise ValueError(f"shard id {si} out of range "
                         f"[0, {sindex.n_shards})")
    gids = meta[f"global_ids_{si}"]
    if not np.array_equal(gids, sindex.global_ids[si]):
        raise ValueError(
            f"snapshot for shard {si} is STALE: it covers {gids.size} "
            f"docs but the live shard owns {sindex.global_ids[si].size} "
            f"(the corpus grew since the snapshot; re-snapshot after "
            f"append_docs_sharded)")
    ix = load_index(os.path.join(snapshot_dir, _shard_file(si)))
    ix = _index_to_device(ix, sindex.devices[si])
    shards = sindex.shards[:si] + (ix,) + sindex.shards[si + 1:]
    return sindex._replace(shards=shards)


# --------------------------------------------------------------- collectives
# NOTE: shard_map's `pbroadcast` is deliberately absent — it is the
# replication-rule annotation (identity at lowering), not communication
_COLLECTIVE_STEMS = ("all_gather", "psum", "pmax", "pmin", "ppermute",
                     "all_to_all", "reduce_scatter", "pgather")


def count_collectives(jaxpr) -> dict:
    """Count communication primitives in a (closed) jaxpr, recursing into
    sub-jaxprs (while/cond/pjit/shard_map bodies). The sharded engine's
    structural contract — exactly ONE all_gather in the merge, zero
    collectives in the per-shard cascade — is asserted with this in
    ``tests/test_shard_index.py``."""
    counts: dict[str, int] = {}

    def walk_param(v):
        if isinstance(v, (list, tuple)):
            for x in v:
                walk_param(x)
        elif hasattr(v, "jaxpr"):       # ClosedJaxpr
            walk(v.jaxpr)
        elif hasattr(v, "eqns"):        # raw Jaxpr
            walk(v)

    def walk(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if any(stem in name for stem in _COLLECTIVE_STEMS):
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                walk_param(v)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def _build_merge(mesh: Mesh, n_shards: int, k: int):
    """The ONE cross-shard collective: global top-k merge.

    Input: ``(S, Q, 2k)`` float32 laid out over the mesh's shard axis —
    per shard, ``k`` ascending local-best distances then ``k`` global doc
    ids as float lanes (invalid slots: +inf distance / -1 id). Inside the
    shard_map: one tiled ``all_gather`` reunites all shards' candidates
    (the only communication), then each device computes the identical
    global ``lax.top_k`` over its ``S*k`` candidates per query — the
    output is replicated. Flattening is SHARD-MAJOR with shard 0 first,
    so ``top_k``'s lowest-index tie-break makes the 1-shard mesh
    bit-compatible with the single-device ranking.
    """

    def merge(packed):                       # local block: (1, Q, 2k)
        packed = lax.all_gather(packed, "shard", axis=0, tiled=True)
        scores, ids = packed[:, :, :k], packed[:, :, k:]
        qn = scores.shape[1]
        s_flat = jnp.transpose(scores, (1, 0, 2)).reshape(qn, n_shards * k)
        i_flat = jnp.transpose(ids, (1, 0, 2)).reshape(qn, n_shards * k)
        neg, pos = lax.top_k(-s_flat, k)
        return -neg, jnp.take_along_axis(i_flat, pos, axis=1)

    return jax.jit(shard_map(merge, mesh=mesh, in_specs=(P("shard"),),
                             out_specs=(P(), P()), check_rep=False))


class ShardedWmdEngine:
    """Drop-in sharded counterpart of :class:`~repro.core.index.WmdEngine`.

    Holds one single-device :class:`WmdEngine` per shard (identical
    hyperparameters) and a compiled single-collective top-k merge over
    the mesh. ``search`` dispatches the full per-shard cascades
    concurrently (one host thread per shard — jit dispatch releases the
    GIL during device execution, so shards overlap on a real multi-device
    mesh), lifts shard-local ids to global ids, and merges with ONE
    ``all_gather`` + local ``top_k``. Exposes the same duck-typed surface
    ``runtime/serving.py`` consumes (``search``, ``min_bucket``,
    ``iter_stats*``, ``dtype``/``impl``/``interpret``/``precision``)
    plus sharding extras (``n_shards``, ``docs_per_shard``,
    ``cluster_counts``, ``iter_stats_by_shard``).

    Fault tolerance (ISSUE 9): the fan-out is deadline-bounded and
    health-gated. Each shard dispatch runs under a per-shard retry loop
    (``shard_retries`` transient retries with exponential backoff); the
    collection waits at most ``shard_timeout_s`` wall-clock for the whole
    fan-out; a shard that times out or errors is EXCLUDED from the merge
    — the packed ``(S, Q, 2k)`` tensor's +inf/-1 defaults make a missing
    shard's lane inert, so the collective itself is unchanged — and the
    result is tagged via ``last_coverage`` (a :class:`ShardCoverage`)
    with the covered doc fraction and the missing shard ids. A
    :class:`~repro.runtime.fault_tolerance.ShardHealth` breaker skips a
    consecutively-failing shard and probes it on a deterministic cadence;
    ``snapshot()``/``restore_shard()`` persist and recover shards via
    :func:`snapshot_shards`/:func:`restore_shard` (restore-then-search is
    bit-compatible with never-failed search). ``last_coverage`` is a
    plain attribute handoff: safe under the serving runtime, which
    serializes engine dispatches on one worker thread.

    Deterministic per-request failures (``LamUnderflowError``) are NOT
    shard faults: they re-raise unchanged (naming the owning shard) so
    the serving layer can isolate the poisoned request. ``query_batch``
    is the unguarded debugging path and keeps the bare fan-out.

    Accepts every :class:`WmdEngine` keyword and forwards it per shard.
    """

    def __init__(self, sindex: ShardedCorpusIndex, *,
                 shard_timeout_s: float | None = 30.0,
                 shard_retries: int = 1, shard_backoff_s: float = 0.01,
                 fail_threshold: int = 3, probe_every: int = 4,
                 snapshot_dir: str | None = None,
                 shard_fault_hook=None, **engine_kwargs):
        self.sindex = sindex
        # kept for shard recovery: a restored shard's WmdEngine must be
        # rebuilt with the exact hyperparameters of its dead predecessor
        self._engine_kwargs = dict(engine_kwargs)
        self.engines = tuple(WmdEngine(ix, **engine_kwargs)
                             for ix in sindex.shards)
        e0 = self.engines[0]
        self.lam, self.n_iter = e0.lam, e0.n_iter
        self.impl, self.interpret = e0.impl, e0.interpret
        self.min_bucket, self.dtype = e0.min_bucket, e0.dtype
        self.precision, self.tol = e0.precision, e0.tol
        self._pool = ThreadPoolExecutor(max_workers=sindex.n_shards,
                                        thread_name_prefix="wmd-shard")
        self._merge_cache: dict = {}
        # collective-overhead accounting for the fig11 trajectory note:
        # wall seconds spent in the merge step (pack + collective + sync)
        self.merge_seconds = 0.0
        self.shard_timeout_s = shard_timeout_s
        self.shard_retries = max(0, int(shard_retries))
        self.shard_backoff_s = float(shard_backoff_s)
        self.health = ShardHealth(sindex.n_shards,
                                  fail_threshold=fail_threshold,
                                  probe_every=probe_every)
        self.snapshot_dir = snapshot_dir
        # fault-injection entry point (shard, fan-out seq, attempt) ->
        # None, run inside the per-shard retry region; the serving
        # runtime wires FaultInjector.before_shard_attempt here
        self.shard_fault_hook = shard_fault_hook
        self.fanouts = 0       # fan-out sequence counter (public: chaos
        #                        drills key crash windows off it)
        self.last_coverage = ShardCoverage(1.0, sindex.n_docs, (), {})

    # ------------------------------------------------------------- surface
    @property
    def n_shards(self) -> int:
        return self.sindex.n_shards

    @property
    def n_docs(self) -> int:
        return self.sindex.n_docs

    @property
    def docs_per_shard(self) -> tuple:
        return self.sindex.docs_per_shard

    @property
    def cluster_counts(self) -> tuple:
        return self.sindex.cluster_counts

    @property
    def iter_stats_dropped(self) -> int:
        return sum(e.iter_stats_dropped for e in self.engines)

    def reset_iter_stats(self) -> None:
        for e in self.engines:
            e.reset_iter_stats()
        self.merge_seconds = 0.0

    def iter_stats(self, stage: str | None = None) -> np.ndarray:
        """Aggregated realized-iteration log across shards (per-shard
        split: :meth:`iter_stats_by_shard`)."""
        parts = [e.iter_stats(stage=stage) for e in self.engines]
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.int64))

    def iter_stats_by_stage(self) -> dict:
        stages: list[str] = []
        for e in self.engines:
            for st in e.iter_stats_by_stage():
                if st not in stages:
                    stages.append(st)
        return {st: self.iter_stats(stage=st) for st in stages}

    def iter_stats_by_shard(self) -> dict:
        """{shard id: {stage: realized iteration counts}} — the sharded
        ``iter_stats()`` aggregate, split by owning shard."""
        return {s: e.iter_stats_by_stage()
                for s, e in enumerate(self.engines)}

    # ------------------------------------------- cross-request cache (ISSUE 10)
    def enable_kcache(self, slots: int) -> bool:
        """Attach a PER-SHARD cdist-row cache to every shard engine
        (each shard's rows live against its own device-resident ``vecs``
        copy). Recorded in ``_engine_kwargs`` so a restored shard
        (:meth:`restore_shard`) rebuilds with a fresh cache of the same
        capacity. Returns ``False`` (no-op) on the kernel impl."""
        ok = all(e.enable_kcache(slots) for e in self.engines)
        if ok:
            self._engine_kwargs["kcache_slots"] = int(slots)
        return ok

    def kcache_stats(self) -> dict | None:
        """Shard-summed cache counters (``None`` when no shard carries a
        cache); per-shard split under ``"per_shard"``."""
        per = [e.kcache_stats() for e in self.engines]
        if all(p is None for p in per):
            return None
        agg: dict = {"slots": 0, "used": 0, "hits": 0, "misses": 0,
                     "evictions": 0, "inserts": 0, "lookups": 0,
                     "fallbacks": 0, "oversize": 0}
        for p in per:
            for k in agg:
                agg[k] += p[k] if p else 0
        total = agg["hits"] + agg["misses"]
        agg["hit_rate"] = round(agg["hits"] / total, 4) if total else 0.0
        agg["per_shard"] = per
        return agg

    def reset_kcache_stats(self) -> None:
        for e in self.engines:
            e.reset_kcache_stats()

    # --------------------------------------------------------------- merge
    def _merge_fn(self, k: int):
        fn = self._merge_cache.get(k)
        if fn is None:
            fn = self._merge_cache[k] = _build_merge(
                self.sindex.mesh, self.n_shards, k)
        return fn

    def _merge_topk(self, per_shard: dict, nq: int, k: int):
        """Pack per-shard ``{shard id: (indices, distances)}`` host
        results into the (S, Q, 2k) mesh tensor and run the
        single-collective merge. A shard ABSENT from the dict (timed
        out, errored, open-circuited) leaves its lane at the +inf/-1
        defaults — inert under ``top_k`` — so a partial merge uses the
        identical collective as a full one (the dead shard's DEVICE is
        alive; only its dispatch failed). Returns host (Q, k) indices
        (int32, -1 pad) and distances (NaN pad), ascending."""
        t0 = time.perf_counter()
        s_count = self.n_shards
        packed = np.full((s_count, nq, 2 * k), np.inf, np.float32)
        packed[:, :, k:] = -1.0
        for si, (ids, dists) in per_shard.items():
            ks = ids.shape[1]
            gids = np.where(
                ids >= 0,
                self.sindex.global_ids[si][np.maximum(ids, 0)], -1)
            d = np.asarray(dists, np.float32)
            d = np.where((ids >= 0) & np.isfinite(d), d, np.inf)
            packed[si, :, :ks] = d
            packed[si, :, k:k + ks] = gids.astype(np.float32)
        sharding = NamedSharding(self.sindex.mesh, P("shard"))
        dist, ids = self._merge_fn(k)(jax.device_put(packed, sharding))
        dist = np.asarray(jax.device_get(dist))
        ids = np.asarray(jax.device_get(ids)).astype(np.int32)
        dist = np.where(ids >= 0, dist, np.nan).astype(self.dtype)
        self.merge_seconds += time.perf_counter() - t0
        return ids, dist

    # -------------------------------------------------------------- search
    def _shard_search(self, si: int, queries, k, prune, nprobe, mode,
                      refine_factor):
        try:
            return self.engines[si].search(queries, k, prune=prune,
                                           nprobe=nprobe, mode=mode,
                                           refine_factor=refine_factor)
        except LamUnderflowError as e:
            raise LamUnderflowError(
                f"owning shard {si} of {self.n_shards} "
                f"({self.docs_per_shard[si]} docs; any doc counts below "
                f"are shard-local, reported ids are external): {e}"
            ) from e

    def _guarded_shard(self, si: int, seq: int, fn):
        """One shard's dispatch under its DispatchGuard-style retry loop
        (runs on the shard's pool thread). Transient failures — the same
        class set :class:`~repro.runtime.fault_tolerance.StepGuard`
        retries — back off exponentially up to ``shard_retries`` times;
        deterministic per-request failures (``LamUnderflowError``,
        ``PoisonStep``) re-raise immediately; exhaustion raises a
        structured :class:`ShardSearchError` NAMING THE SHARD instead of
        letting the raw exception propagate unstructured out of the
        future. Returns ``(service_seconds, result)``."""
        last = None
        for attempt in range(self.shard_retries + 1):
            t0 = time.perf_counter()
            try:
                if self.shard_fault_hook is not None:
                    self.shard_fault_hook(si, seq, attempt)
                return time.perf_counter() - t0, fn(si)
            except (PoisonStep, FloatingPointError):
                raise          # deterministic per-request: never a retry
            except (jax.errors.JaxRuntimeError, RuntimeError, OSError) as e:
                last = e
                if attempt < self.shard_retries:
                    time.sleep(self.shard_backoff_s * (2 ** attempt))
        raise ShardSearchError(
            f"shard {si} of {self.n_shards} failed after "
            f"{self.shard_retries + 1} attempts "
            f"({type(last).__name__}: {last})",
            {si: f"{type(last).__name__}: {last}"}) from last

    def _fan_out(self, fn, label: str):
        """Deadline-bounded, health-gated fan-out of ``fn(si)`` across
        shards. Returns ``({shard id: result}, ShardCoverage)`` and
        updates ``last_coverage``/``health``.

        Admission: open-circuited shards are skipped (probed on the
        breaker's deterministic cadence); if EVERY circuit is open, all
        shards are force-probed — the engine never refuses to serve on
        breaker state alone. Collection: one shared wall-clock deadline
        of ``shard_timeout_s`` over the whole fan-out; a shard that
        misses it is recorded as ``"timeout"`` and excluded (its worker
        thread finishes in the background — a cooperative bound, like
        the DispatchGuard watchdog: Python cannot preempt a running XLA
        dispatch). A ``LamUnderflowError`` from any shard re-raises
        after the others drain (deterministic per-request poison, not a
        shard fault). Raises :class:`ShardSearchError` only when NO
        shard responded."""
        seq = self.fanouts
        self.fanouts += 1
        reasons: dict = {}
        live = []
        for si in range(self.n_shards):
            if self.health.admit(si):
                live.append(si)
            else:
                reasons[si] = "open_circuit"
        if not live:                     # all circuits open: force-probe
            live = sorted(reasons)
            reasons = {}
        futures = {si: self._pool.submit(self._guarded_shard, si, seq, fn)
                   for si in live}
        deadline = (None if self.shard_timeout_s is None
                    else time.monotonic() + self.shard_timeout_s)
        results: dict = {}
        underflow = None
        for si, f in futures.items():
            try:
                remaining = (None if deadline is None
                             else max(0.0, deadline - time.monotonic()))
                dt, out = f.result(timeout=remaining)
                results[si] = out
                self.health.record_success(si, dt)
            except _FutTimeout:
                reasons[si] = "timeout"
                self.health.record_failure(si)
            except LamUnderflowError as e:
                underflow = e
            except Exception as e:  # noqa: BLE001 — fan-out boundary
                reasons[si] = (str(e) if isinstance(e, ShardSearchError)
                               else f"{type(e).__name__}: {e}")
                self.health.record_failure(si)
        if underflow is not None:
            raise underflow
        if not results:
            detail = "; ".join(f"shard {s}: {r}"
                               for s, r in sorted(reasons.items()))
            raise ShardSearchError(
                f"{label}: all {self.n_shards} shards failed ({detail})",
                reasons)
        covered = sum(self.docs_per_shard[si] for si in results)
        cov = ShardCoverage(
            fraction=covered / max(self.n_docs, 1),
            covered_docs=covered,
            missing_shards=tuple(si for si in range(self.n_shards)
                                 if si not in results),
            reasons=reasons)
        self.last_coverage = cov
        return results, cov

    def search(self, queries: Sequence, k: int, prune: object = "rwmd",
               nprobe: int | None = None, mode: str = "exact",
               refine_factor: int = 4) -> SearchResult:
        """Sharded staged top-k: per-shard cascade -> single-collective
        global merge. Same contract as :meth:`WmdEngine.search`, with the
        per-shard ``nprobe`` semantics documented in the module header;
        ``solved`` sums exact per-query solves across shards.

        ``mode="refine"`` runs rank-then-refine PER SHARD (each shard
        ranks its own candidates and solves its own top
        ``refine_factor * k``); the merge is unchanged — still one
        all_gather over exact distances, so every returned distance is
        exact and the global result at a covering ``refine_factor``
        equals ``mode="exact"`` at the same ``nprobe`` (each shard's
        contribution already does).

        Under shard failure the result is PARTIAL: a true top-k over the
        responding shards only, reported via ``last_coverage`` (see
        :meth:`_fan_out`); callers that need the exactness contract must
        check ``last_coverage.full``."""
        queries = [np.asarray(q) for q in queries]
        nq = len(queries)
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        k = min(int(k), self.n_docs)
        if nq == 0:
            self.last_coverage = ShardCoverage(1.0, self.n_docs, (), {})
            return SearchResult(np.full((0, k), -1, np.int32),
                                np.full((0, k), np.nan, self.dtype),
                                np.zeros(0, np.int64))
        results, _ = self._fan_out(
            lambda si: self._shard_search(si, queries, k, prune, nprobe,
                                          mode, refine_factor),
            label="search")
        ids, dist = self._merge_topk(
            {si: (res.indices, res.distances)
             for si, res in results.items()}, nq, k)
        solved = np.sum([res.solved for res in results.values()], axis=0)
        return SearchResult(ids, dist, solved.astype(np.int64))

    def query_batch(self, queries: Sequence) -> np.ndarray:
        """Exhaustive (Q, N) distance matrix in GLOBAL caller doc order,
        assembled from concurrent per-shard exhaustive solves."""
        queries = [np.asarray(q) for q in queries]
        nq = len(queries)
        out = np.full((nq, self.n_docs), np.nan, self.dtype)
        if nq == 0:
            return out
        futures = [self._pool.submit(self.engines[si].query_batch, queries)
                   for si in range(self.n_shards)]
        for si, f in enumerate(futures):
            out[:, self.sindex.global_ids[si]] = np.asarray(f.result())
        return out

    def rwmd_topk(self, queries: Sequence, k: int):
        """Bound-only ranking for the serving runtime's degraded tier:
        per-shard :func:`repro.runtime.serving.rwmd_topk` over each local
        engine, merged through the same single collective as
        :meth:`search`. Returns ``(indices, distances)`` exactly like the
        single-device free function (which delegates here when handed a
        sharded engine). Routed through the same deadline-bounded
        health-gated fan-out as :meth:`search`, so the last-resort tier
        degrades to a partial result (``last_coverage``) under shard
        failure instead of stalling on a hung shard."""
        from repro.runtime.serving import rwmd_topk as _local_rwmd
        queries = [np.asarray(q) for q in queries]
        nq = len(queries)
        k = min(int(k), self.n_docs)
        if nq == 0 or k <= 0:
            self.last_coverage = ShardCoverage(1.0, self.n_docs, (), {})
            return (np.full((nq, max(k, 0)), -1, np.int32),
                    np.full((nq, max(k, 0)), np.nan, self.dtype))
        results, _ = self._fan_out(
            lambda si: _local_rwmd(self.engines[si], queries, k),
            label="rwmd_topk")
        return self._merge_topk(dict(results), nq, k)

    # ----------------------------------------------------------- snapshots
    def snapshot(self, snapshot_dir=None) -> list:
        """Persist every shard's index (see :func:`snapshot_shards`) and
        remember the directory for :meth:`restore_shard`. Returns the
        written paths."""
        d = snapshot_dir if snapshot_dir is not None else self.snapshot_dir
        if d is None:
            raise ValueError("no snapshot directory: pass snapshot_dir "
                             "here or at engine construction")
        self.snapshot_dir = d
        return snapshot_shards(self.sindex, d)

    def restore_shard(self, shard_id: int, snapshot_dir=None) -> None:
        """Dead-shard recovery: reload one shard from its snapshot
        (:func:`restore_shard`), rebuild its :class:`WmdEngine` with the
        same hyperparameters, and reset its circuit breaker — the
        restored shard rejoins the mesh with a clean record and is
        admitted on the next fan-out. Post-restore search is
        bit-compatible with a never-failed engine."""
        d = snapshot_dir if snapshot_dir is not None else self.snapshot_dir
        if d is None:
            raise ValueError("no snapshot directory: pass snapshot_dir "
                             "here or at engine construction")
        si = int(shard_id)
        self.sindex = restore_shard(self.sindex, si, d)
        rebuilt = WmdEngine(self.sindex.shards[si], **self._engine_kwargs)
        self.engines = (self.engines[:si] + (rebuilt,)
                        + self.engines[si + 1:])
        self.health.reset(si)
