"""Pallas TPU kernels for the fused SDDMM_SpMM Sinkhorn iteration (paper §4).

Two kernels, in increasing fusion depth:

``sddmm_spmm_step``
    One Sinkhorn iteration: SDDMM (t = sum_k G u), sparse selection
    (w = val/t), SpMM (x' = sum_l (G/r) w) — the paper's Fig. 4 kernel in ELL
    form. G streams HBM->VMEM once per call; the intermediate ``w`` lives
    only in VREGs (that is the paper's fusion: "output values from SDDMM can
    be fed directly to the SpMM and would not need to be stored in memory").

``sinkhorn_fused_all``
    Beyond-paper: the ENTIRE solver (all iterations + the final distance
    line) for a block of documents with the G tile *resident in VMEM*. The
    paper's appendix notes the kernel remains memory-bound without tiling
    ("if we assume that all matrices can be loaded from cache, the runtime
    ... can be improved further"); on TPU the G tile (v_r x block_n x L
    ~ 1 MB) comfortably fits the ~16 MB VMEM, so HBM traffic drops from
    (2 reads of G per iteration) to (1 read of G total) and the iteration
    becomes compute-bound. This is the TPU analogue of the
    adaptive-sparse-tiling improvement the paper cites as future work [5].

    The distance line needs GM = (K*M) gathered at the doc words, but since
    K = exp(-lam*M) we have GM = -G*log(G)/lam: GM is *reconstructed in
    VMEM* from the already-resident G tile instead of being materialized in
    HBM — halving both the solver's HBM reads and the nnz-sized precompute
    footprint (G==0 pad entries are guarded to 0).

``sinkhorn_fused_all_batched``
    The multi-query engine kernel (:mod:`repro.core.index`): identical
    per-document schedule, with the grid extended by a leading query
    dimension. A bucket of Q shape-padded queries shares one ``val`` tile
    stream and one compiled executable, so per-query dispatch and
    recompilation cost is amortized across the batch.

Layout note (paper: "data could be transposed on the fly to ensure
unit-stride data accesses"): G is laid out (v_r, N, L) so both reductions —
over k (sublane) for SDDMM and over l (lane) for SpMM — are unit-stride in
VMEM; no transposes are materialized.

Padding contract (see ops.py): padded query rows carry G == 0 and padded
doc slots carry val == 0; the ``where`` guards make both inert, so kernel
results on padded problems equal the unpadded oracle exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# single source of truth for the GM = -G*log(G)/lam rebuild and the
# adaptive-exit machinery; pure jnp/lax, so they trace inside Pallas
# kernel bodies too
from repro.core.sinkhorn_sparse import (adaptive_loop, marginal_residual,
                                        reconstruct_gm)


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def _step_kernel(g_ref, gor_ref, val_ref, x_ref, xout_ref):
    g = g_ref[...]                        # (v_r, bn, L)
    gor = gor_ref[...]                    # (v_r, bn, L)
    val = val_ref[...]                    # (bn, L)
    x = x_ref[...]                        # (v_r, bn)
    u = _safe_inv(x)
    t = jnp.sum(g * u[:, :, None], axis=0)             # SDDMM   (bn, L)
    w = val * _safe_inv(t)                             # sparse selection
    xout_ref[...] = jnp.sum(gor * w[None, :, :], axis=2)  # SpMM  (v_r, bn)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sddmm_spmm_step(g: jax.Array, g_over_r: jax.Array, val: jax.Array,
                    x: jax.Array, block_n: int = 128,
                    interpret: bool = False) -> jax.Array:
    """One fused SDDMM_SpMM Sinkhorn iteration. g, g_over_r: (v_r, N, L);
    val: (N, L); x: (v_r, N) -> new x (v_r, N)."""
    v_r, n, length = g.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    g_spec = pl.BlockSpec((v_r, block_n, length), lambda i: (0, i, 0))
    return pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[g_spec, g_spec,
                  pl.BlockSpec((block_n, length), lambda i: (i, 0)),
                  pl.BlockSpec((v_r, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((v_r, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((v_r, n), g.dtype),
        interpret=interpret,
    )(g, g_over_r, val, x)


def _solve_block(g, val, r, n_iter: int, lam: float, tol=None,
                 check_every: int = 4, gemm: str = "fp32",
                 log_domain: bool = False, resmask=None):
    """Shared solver body: one (v_r, bn, L) G tile resident in VMEM.

    g (v_r, bn, L); val (bn, L); r (v_r, 1). Returns (wmd (bn,), iters).

    ``tol`` switches the fixed ``fori_loop`` to a ``lax.while_loop`` with
    a residual epilogue: the doc-marginal residual ``max|val/t - w_prev|``
    (relative to each doc's own marginal scale, live slots only) is
    checked every ``check_every`` iterations and each grid block exits
    independently — inert pad blocks (w == 0 throughout) exit at the
    first check. ``gemm="bf16"`` runs both reductions with bf16 operands
    and fp32 accumulation. ``log_domain=True`` takes ``g`` as
    UNexponentiated log K (pad rows -inf), column-stabilizes it in VMEM,
    and adds the exact shift correction to the distance line.

    ``resmask`` (bn,) scopes the exit test to the CALLER'S candidate docs
    (ISSUE 5's per-query residual scoping on the kernel path: in the
    batched kernel each grid block holds exactly one query's rows, so a
    block whose scope excludes its far docs exits — freezing that query's
    rows — as soon as the docs the query actually needs are stationary).
    Masked-out docs keep iterating while the block is live but cannot
    hold its exit open; a block with an empty scope exits at the first
    check like a pad block.
    """
    shift = None
    if log_domain:
        shift = jnp.max(g, axis=0)                     # (bn, L)
        shift = jnp.where(jnp.isfinite(shift), shift, 0.0)
        g = jnp.where(jnp.isfinite(g), jnp.exp(g - shift[None]), 0.0)
    gor = g * _safe_inv(r)[:, :, None]    # padded rows: r inv -> 0 is fine,
    # but r pad is 1.0 by contract; g pad rows are 0 so gor pad rows are 0.
    v_r = g.shape[0]
    bn = g.shape[1]
    live = (val > 0).astype(g.dtype)
    rowmask = (jnp.sum(jnp.abs(g), axis=(1, 2), keepdims=False) > 0)
    x0 = jnp.where(rowmask, 1.0 / jnp.sum(rowmask.astype(g.dtype)), 0.0)
    x = jnp.broadcast_to(x0[:, None], (v_r, bn)).astype(g.dtype)

    # bf16 policy = bf16-ROUNDED OPERANDS with fp32 products/accumulation
    # (cast through bf16, multiply in fp32 — matching the einsum paths'
    # preferred_element_type semantics; rounding each product to bf16
    # would drift further for long docs)
    gd = jnp.bfloat16 if gemm == "bf16" else None
    gb = g if gd is None else g.astype(gd).astype(jnp.float32)
    gorb = gor if gd is None else gor.astype(gd).astype(jnp.float32)

    def _rnd(a):
        return a if gd is None else a.astype(gd).astype(jnp.float32)

    def _sddmm(u):
        return jnp.sum(gb * _rnd(u)[:, :, None], axis=0)

    def _spmm(w):
        return jnp.sum(gorb * _rnd(w)[None, :, :], axis=2)

    def one(x):
        u = _safe_inv(x)
        t = _sddmm(u)
        w = val * _safe_inv(t) * live
        return _spmm(w), w

    resm = live > 0
    if resmask is not None:
        resm = resm & (resmask > 0)[:, None]
    if tol is None:
        x = jax.lax.fori_loop(0, n_iter, lambda _, x: one(x)[0], x)
        iters = jnp.asarray(n_iter, jnp.int32)
    else:
        x, iters = adaptive_loop(
            one, lambda w, wp: marginal_residual(w, wp, resm),
            x, n_iter, tol, check_every, use_fori=True)

    u = _safe_inv(x)
    t = _sddmm(u)
    w = val * _safe_inv(t) * live
    gm = reconstruct_gm(g, lam)           # in VMEM; never touches HBM
    # final line: wmd[j] = sum_k u[k,j] * sum_l GM[k,j,l] w[j,l]
    wmd = jnp.sum(u * jnp.sum(gm * w[None, :, :], axis=2), axis=0)  # (bn,)
    if log_domain:
        # exact rescale correction (t*w == val on live slots)
        wmd = wmd - jnp.sum(shift * val, axis=1) / lam
    return wmd, iters


def _fused_kernel(g_ref, val_ref, r_ref, *refs, n_iter: int,
                  lam: float, tol, check_every: int, gemm: str,
                  log_domain: bool, with_resmask: bool):
    if with_resmask:
        rm_ref, wmd_ref, it_ref = refs
        rm = rm_ref[0]
    else:
        (wmd_ref, it_ref), rm = refs, None
    wmd, iters = _solve_block(g_ref[...], val_ref[...], r_ref[...], n_iter,
                              lam, tol, check_every, gemm, log_domain,
                              resmask=rm)
    wmd_ref[...] = wmd[None, :]
    it_ref[...] = jnp.full((1, 1), iters, jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("lam", "n_iter", "block_n", "interpret",
                                    "tol", "check_every", "gemm",
                                    "log_domain"))
def sinkhorn_fused_all(g: jax.Array, val: jax.Array, r: jax.Array, lam: float,
                       n_iter: int, block_n: int = 128,
                       interpret: bool = False, tol=None,
                       check_every: int = 4, gemm: str = "fp32",
                       log_domain: bool = False, resmask=None):
    """Whole Sinkhorn solve + WMD for all docs; one HBM pass over G.

    g: (v_r, N, L); val: (N, L); r: (v_r,) with padded rows == 1.0 and
    padded G rows == 0 (or -inf when ``log_domain`` — ``g`` then holds
    log K); lam: the K = exp(-lam*M) strength (static; needed to
    reconstruct GM in VMEM). Returns (wmd (N,), iters (N // block_n,)) —
    realized iteration count per doc block (== ``n_iter`` for the fixed
    loop; see :func:`_solve_block` for the adaptive/precision knobs).
    ``resmask`` (N,) float/bool scopes each block's adaptive exit to the
    caller's candidate docs (ISSUE 5; ignored without ``tol``).
    """
    v_r, n, length = g.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    with_resmask = resmask is not None and tol is not None
    in_specs = [pl.BlockSpec((v_r, block_n, length), lambda i: (0, i, 0)),
                pl.BlockSpec((block_n, length), lambda i: (i, 0)),
                pl.BlockSpec((v_r, 1), lambda i: (0, 0))]
    args = [g, val, r.reshape(-1, 1)]
    if with_resmask:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i: (0, i)))
        args.append(jnp.asarray(resmask, g.dtype).reshape(1, n))
    wmd, iters = pl.pallas_call(
        functools.partial(_fused_kernel, n_iter=n_iter, lam=lam, tol=tol,
                          check_every=check_every, gemm=gemm,
                          log_domain=log_domain, with_resmask=with_resmask),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i)),
                   pl.BlockSpec((1, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, n), g.dtype),
                   jax.ShapeDtypeStruct((1, n // block_n), jnp.int32)],
        interpret=interpret,
    )(*args)
    return wmd[0], iters[0]


def _fused_batched_kernel(g_ref, val_ref, r_ref, *refs, n_iter: int,
                          lam: float, tol, check_every: int,
                          gemm: str, log_domain: bool, with_resmask: bool):
    if with_resmask:
        rm_ref, wmd_ref, it_ref = refs
        rm = rm_ref[0]
    else:
        (wmd_ref, it_ref), rm = refs, None
    wmd, iters = _solve_block(g_ref[0], val_ref[...], r_ref[0], n_iter, lam,
                              tol, check_every, gemm, log_domain,
                              resmask=rm)
    wmd_ref[...] = wmd[None, :]
    it_ref[...] = jnp.full((1, 1), iters, jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("lam", "n_iter", "block_n", "interpret",
                                    "tol", "check_every", "gemm",
                                    "log_domain"))
def sinkhorn_fused_all_batched(g: jax.Array, val: jax.Array, r: jax.Array,
                               lam: float, n_iter: int, block_n: int = 128,
                               interpret: bool = False, tol=None,
                               check_every: int = 4, gemm: str = "fp32",
                               log_domain: bool = False, resmask=None):
    """Batched solver: Q queries against one shared corpus in one launch.

    g: (Q, v_r, N, L) per-query gathered kernels (log K when
    ``log_domain``); val: (N, L) shared corpus frequencies; r: (Q, v_r)
    with the same padding contract as :func:`sinkhorn_fused_all` per query
    row. Returns (wmd (Q, N), iters (Q, N // block_n)) — each grid block
    records its own realized iteration count, and with ``tol`` set each
    block EXITS independently (per-block early exit; inert pad blocks exit
    at the first residual check).

    Per-query residual scoping (ISSUE 5): each grid block holds exactly
    one query's rows, so the per-block exit IS a per-query-row freeze —
    ``resmask`` (Q, N) narrows each query's exit test to its own
    candidate docs, letting a block stop burning iterations on far docs
    its ranking never reads (ignored without ``tol``).

    Grid is (Q, N // block_n): the doc axis varies fastest so each query's
    corpus sweep is contiguous; ``val`` blocks depend only on the doc index
    and are revisited per query (resident after the first pass on TPU).
    """
    q, v_r, n, length = g.shape
    assert n % block_n == 0, (n, block_n)
    grid = (q, n // block_n)
    with_resmask = resmask is not None and tol is not None
    in_specs = [pl.BlockSpec((1, v_r, block_n, length),
                             lambda qi, i: (qi, 0, i, 0)),
                pl.BlockSpec((block_n, length), lambda qi, i: (i, 0)),
                pl.BlockSpec((1, v_r, 1), lambda qi, i: (qi, 0, 0))]
    args = [g, val, r.reshape(q, v_r, 1)]
    if with_resmask:
        in_specs.append(pl.BlockSpec((1, block_n), lambda qi, i: (qi, i)))
        args.append(jnp.asarray(resmask, g.dtype))
    return pl.pallas_call(
        functools.partial(_fused_batched_kernel, n_iter=n_iter, lam=lam,
                          tol=tol, check_every=check_every, gemm=gemm,
                          log_domain=log_domain, with_resmask=with_resmask),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, block_n), lambda qi, i: (qi, i)),
                   pl.BlockSpec((1, 1), lambda qi, i: (qi, i))],
        out_shape=[jax.ShapeDtypeStruct((q, n), g.dtype),
                   jax.ShapeDtypeStruct((q, n // block_n), jnp.int32)],
        interpret=interpret,
    )(*args)
