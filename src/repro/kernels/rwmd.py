"""Pallas TPU kernel for the RWMD prune stage: query-grid masked min-cdist.

The staged retrieval pipeline (``WmdEngine.search``: prune -> solve -> rank)
needs, per query q, the distance from every vocabulary word v to the
*nearest* query word:

    minM[q, v] = min_{k : mask[q, k] > 0} ||a[q, k] - b[v]||

The doc-side relaxed WMD lower bound is then ``sum_l val[n, l] *
minM[q, idx[n, l]]`` — an O(nnz) gather the caller keeps in XLA (same
split as the solve stage: cdist-shaped work in Pallas, the gather at the
kernel boundary).

This is the same blocked GEMM-shaped schedule as :mod:`.cdist_exp` (the
``a @ b.T`` contraction on the MXU, the sqrt epilogue on the VPU while the
tile is in VMEM/VREGs) with two changes mirroring the multi-query engine:

  - a leading *query* grid dimension, so a whole shape-bucketed chunk of
    queries runs in one launch (one executable per bucket shape, like
    ``sinkhorn_fused_all_batched``);
  - the epilogue reduces over the support axis (masked min) instead of
    storing the full (B, block_v) tile, so the kernel's HBM output is the
    small (Q, V) bound matrix — the (Q*B, V) distance block never exists
    outside VMEM.

Padding contract: padded support rows carry ``mask == 0`` and are excluded
from the min via a +inf select; zero-padding the embedding width is exact
(zeros add nothing to the distance); padded vocabulary tiles produce
garbage columns the wrapper slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, mask_ref, b_ref, out_ref):
    a = a_ref[0]                          # (B, w)   this query's support
    mask = mask_ref[0]                    # (B, 1)
    b = b_ref[...]                        # (bv, w)  streamed vocab tile
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # MXU
    a2 = jnp.sum(a * a, axis=1, keepdims=True)       # (B, 1)
    b2 = jnp.sum(b * b, axis=1)[None, :]             # (1, bv)
    d = jnp.sqrt(jnp.maximum(a2 + b2 - 2.0 * ab, 0.0))
    d = jnp.where(mask > 0, d, jnp.inf)              # pad rows out of the min
    out_ref[...] = jnp.min(d, axis=0, keepdims=True)  # (1, bv)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def rwmd_min_cdist(a: jax.Array, mask: jax.Array, b: jax.Array,
                   block_v: int = 512, interpret: bool = False) -> jax.Array:
    """Masked min-over-support distances for a query chunk.

    ``a`` (Q, B, w) support embeddings, ``mask`` (Q, B) with 0 marking padded
    support rows, ``b`` (V, w) vocabulary embeddings. V must divide by
    ``block_v``; pad B/w via :func:`repro.kernels.ops.pad_to` (the ops
    wrapper does). Returns ``minM`` (Q, V); rows whose mask is all zero
    (inert filler queries) come out +inf.
    """
    q, bq, w = a.shape
    v = b.shape[0]
    assert v % block_v == 0, (v, block_v)
    grid = (q, v // block_v)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, w), lambda qi, i: (qi, 0, 0)),   # resident
            pl.BlockSpec((1, bq, 1), lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((block_v, w), lambda qi, i: (i, 0)),     # streamed
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda qi, i: (qi, i)),
        out_shape=jax.ShapeDtypeStruct((q, v), a.dtype),
        interpret=interpret,
    )(a, mask.reshape(q, bq, 1), b)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def rwmd_min_cdist_subset(a: jax.Array, mask: jax.Array, b: jax.Array,
                          vocab_ids: jax.Array, block_v: int = 512,
                          interpret: bool = False) -> jax.Array:
    """Candidate-vocab min-cdist: the cascade's RWMD stage only needs the
    words that actually appear in the surviving documents, so the caller
    passes their (padded) id array and the streamed vocab side shrinks from
    (V, w) to (Vc, w) — the (Q*B, V) distance block becomes (Q*B, Vc).

    The gather sits at the kernel boundary (XLA gather feeding the Pallas
    launch, same split as the solve stage's G gather). ``vocab_ids`` (Vc,)
    must be ``block_v``-aligned — pad with any valid id; padded columns are
    garbage the caller's compact gather never reads. Returns (Q, Vc).
    """
    return rwmd_min_cdist(a, mask, jnp.take(b, vocab_ids, axis=0),
                          block_v=block_v, interpret=interpret)
