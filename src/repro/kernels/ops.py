"""jit'd user-facing wrappers around the Pallas kernels.

Handles TPU-alignment padding (the kernels' shape contract) and exposes
``sinkhorn_wmd_kernel`` — the full WMD pipeline on the kernel path, result
bit-identical (up to fp reassociation) to ``repro.core`` oracles.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU the same call sites compile to Mosaic. ``INTERPRET`` flips the
default per-platform.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparse import PaddedDocs
from . import cdist_exp as _cdist_exp
from . import rwmd as _rwmd
from . import sddmm_spmm as _sddmm_spmm

INTERPRET = jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def cdist_exp(a, b, r, lam: float, block_v: int = 512,
              interpret: bool | None = None, k_only: bool = False,
              gemm: str = "fp32", log_k: bool = False):
    """Fused (M, K, K_over_r) with auto-padding. a (v_r, w), b (V, w).
    ``k_only=True`` returns just K and skips the two dead HBM stores;
    ``gemm``/``log_k`` plumb the SolvePrecision policy (bf16 MXU operands
    / unexponentiated log K for the log-domain solve)."""
    interpret = INTERPRET if interpret is None else interpret
    v_r, w = a.shape
    v = b.shape[0]
    ap = pad_to(pad_to(a, 1, 128), 0, 8)
    bp = pad_to(pad_to(b, 1, 128), 0, block_v)
    rp = pad_to(r, 0, 8, value=1.0)          # pad rows divide by 1
    if k_only:
        k = _cdist_exp.cdist_exp(ap, bp, rp, lam, block_v=block_v,
                                 interpret=interpret, k_only=True,
                                 gemm=gemm, log_k=log_k)
        return k[:v_r, :v]
    m, k, kr = _cdist_exp.cdist_exp(ap, bp, rp, lam,
                                    block_v=block_v, interpret=interpret)
    return m[:v_r, :v], k[:v_r, :v], kr[:v_r, :v]


def rwmd_min_cdist(a, mask, b, block_v: int = 512,
                   interpret: bool | None = None, vocab_ids=None):
    """Masked min-over-support cdist with auto-padding (the RWMD prune
    stage). a (Q, B, w), mask (Q, B), b (V, w) -> minM (Q, V).

    ``vocab_ids`` (Vc,) int32 switches to the candidate-subset kernel path:
    only those vocabulary rows are streamed (the cascade's
    RWMD-on-survivors stage) and the result is (Q, Vc) in ``vocab_ids``
    order. Ids are padded to the block size with id 0 — callers index the
    result by candidate position, never by the padded tail."""
    interpret = INTERPRET if interpret is None else interpret
    q, bq, w = a.shape
    ap = pad_to(pad_to(a, 2, 128), 1, 8)
    maskp = pad_to(mask, 1, 8)               # pad support rows masked out
    if vocab_ids is not None:
        vc = vocab_ids.shape[0]
        bp = pad_to(b, 1, 128)
        vidp = pad_to(jnp.asarray(vocab_ids, jnp.int32), 0, block_v)
        minm = _rwmd.rwmd_min_cdist_subset(ap, maskp, bp, vidp,
                                           block_v=block_v,
                                           interpret=interpret)
        return minm[:, :vc]
    v = b.shape[0]
    bp = pad_to(pad_to(b, 1, 128), 0, block_v)
    minm = _rwmd.rwmd_min_cdist(ap, maskp, bp, block_v=block_v,
                                interpret=interpret)
    return minm[:, :v]


def sddmm_spmm_step(g, g_over_r, val, x, block_n: int = 128,
                    interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    v_r, n, length = g.shape
    gp = pad_to(pad_to(pad_to(g, 2, 128), 1, block_n), 0, 8)
    gorp = pad_to(pad_to(pad_to(g_over_r, 2, 128), 1, block_n), 0, 8)
    valp = pad_to(pad_to(val, 1, 128), 0, block_n)
    xp = pad_to(pad_to(x, 1, block_n), 0, 8)
    out = _sddmm_spmm.sddmm_spmm_step(gp, gorp, valp, xp, block_n=block_n,
                                      interpret=interpret)
    return out[:v_r, :n]


def sinkhorn_fused_all(g, val, r, lam: float, n_iter: int, block_n: int = 128,
                       interpret: bool | None = None, tol=None,
                       check_every: int = 4, gemm: str = "fp32",
                       log_domain: bool = False, resmask=None,
                       with_iters: bool = False):
    """Fused solver with auto-padding; ``with_iters=True`` also returns the
    per-block realized iteration counts. ``log_domain`` pads query rows
    with -inf (a 0 would be a VALID log-K entry — distance 0 — and the
    pad row would stop being inert). ``resmask`` (N,) scopes each block's
    adaptive exit test to the caller's candidate docs (pad docs are
    masked out, matching the val padding)."""
    interpret = INTERPRET if interpret is None else interpret
    v_r, n, length = g.shape
    row_pad = -jnp.inf if log_domain else 0.0
    gp = pad_to(pad_to(pad_to(g, 2, 128), 1, block_n), 0, 8, value=row_pad)
    valp = pad_to(pad_to(val, 1, 128), 0, block_n)
    rp = pad_to(r, 0, 8, value=1.0)
    rmp = None
    if resmask is not None:
        rmp = pad_to(jnp.asarray(resmask, gp.dtype), 0, block_n)
    wmd, iters = _sddmm_spmm.sinkhorn_fused_all(
        gp, valp, rp, lam, n_iter, block_n=block_n, interpret=interpret,
        tol=tol, check_every=check_every, gemm=gemm, log_domain=log_domain,
        resmask=rmp)
    return (wmd[:n], iters) if with_iters else wmd[:n]


def sinkhorn_fused_all_batched(g, val, r, lam: float, n_iter: int,
                               block_n: int = 128,
                               interpret: bool | None = None, tol=None,
                               check_every: int = 4, gemm: str = "fp32",
                               log_domain: bool = False, resmask=None,
                               with_iters: bool = False):
    """Batched fused solver with auto-padding. g (Q, v_r, N, L); val (N, L);
    r (Q, v_r) -> wmd (Q, N). Padded query rows carry r == 1, G == 0
    (G == -inf under ``log_domain`` — see :func:`sinkhorn_fused_all`).
    ``with_iters=True`` also returns the (Q, N-blocks) realized iteration
    counts (per-block early exit under ``tol``). ``resmask`` (Q, N)
    scopes each query's exit test to its own candidate docs — each grid
    block holds one query's rows, so the per-block exit is a
    per-query-row freeze (ISSUE 5)."""
    interpret = INTERPRET if interpret is None else interpret
    q, v_r, n, length = g.shape
    row_pad = -jnp.inf if log_domain else 0.0
    gp = pad_to(pad_to(pad_to(g, 3, 128), 2, block_n), 1, 8, value=row_pad)
    valp = pad_to(pad_to(val, 1, 128), 0, block_n)
    rp = pad_to(r, 1, 8, value=1.0)
    rmp = None
    if resmask is not None:
        rmp = pad_to(jnp.asarray(resmask, gp.dtype), 1, block_n)
    wmd, iters = _sddmm_spmm.sinkhorn_fused_all_batched(
        gp, valp, rp, lam, n_iter, block_n=block_n, interpret=interpret,
        tol=tol, check_every=check_every, gemm=gemm, log_domain=log_domain,
        resmask=rmp)
    return (wmd[:, :n], iters) if with_iters else wmd[:, :n]


@functools.partial(jax.jit, static_argnames=("lam", "n_iter", "interpret",
                                             "tol", "check_every",
                                             "precision"))
def sinkhorn_wmd_kernel(r, vecs_sel, vecs, docs: PaddedDocs, lam: float,
                        n_iter: int, interpret: bool | None = None,
                        tol=None, check_every: int = 4, precision=None):
    """Full kernel-path WMD: cdist_exp -> gather (XLA) -> fused solver.

    The gather between the two kernels stays in XLA (TPU gather over the
    vocab axis); everything else runs in Pallas. GM is reconstructed from G
    inside the solver, so only one (v_r, N, L) array is ever materialized.

    ``tol``/``check_every`` select the convergence-adaptive loop;
    ``precision`` (a ``SolvePrecision`` or its string spelling) plumbs the
    bf16-GEMM and log-domain policies through ``cdist_exp``'s epilogue and
    the fused solver — under ``log_domain`` the kernel emits
    UNexponentiated log K, so no column can underflow at any lam.
    """
    from repro.core.sinkhorn_sparse import SolvePrecision
    precision = SolvePrecision.parse(precision)
    k = cdist_exp(vecs_sel, vecs, r, lam, interpret=interpret, k_only=True,
                  gemm=precision.gemm, log_k=precision.log_domain)
    g = jnp.take(k, docs.idx, axis=1)          # (v_r, N, L)
    return sinkhorn_fused_all(g, docs.val, r, lam, n_iter,
                              interpret=interpret, tol=tol,
                              check_every=check_every, gemm=precision.gemm,
                              log_domain=precision.log_domain)
