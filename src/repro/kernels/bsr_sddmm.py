"""Pallas TPU kernel: block-sparse (BSR) SDDMM — the DESIGN.md §4 tile-
granular adaptation of the paper's CSR kernel.

Work avoidance at MXU-tile granularity: only tiles of ``c`` containing at
least one nonzero are stored (``repro.core.sparse.BlockSparse``), and only
those tiles' dot products are computed — at the paper's density (0.0035%,
~35 words/doc) 128x128 tiles are ~4.4% occupied, a ~23x dense-work
reduction with every retained tile a full MXU matmul.

Pipeline: the per-block K^T row-panels and u column-panels are gathered by
XLA (``brow``/``bcol`` indexed — data-dependent indices stay outside the
kernel), then the kernel fuses the (bv x v_r) @ (v_r x bn) MXU matmul with
the elementwise c-mask per tile, one grid step per retained block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ktb_ref, ub_ref, cb_ref, w_ref):
    ktb = ktb_ref[...][0]                  # (bv, v_r)
    ub = ub_ref[...][0]                    # (v_r, bn)
    cb = cb_ref[...][0]                    # (bv, bn)
    prod = jax.lax.dot_general(ktb, ub, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)   # MXU
    # sparse selection fused in-register: w = c * (KT @ u) per tile
    w_ref[...] = (cb * prod)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_sddmm_blocks(ktb: jax.Array, ub: jax.Array, cblk: jax.Array,
                     interpret: bool = False) -> jax.Array:
    """Per-retained-block fused SDDMM. ktb (nb, bv, v_r); ub (nb, v_r, bn);
    cblk (nb, bv, bn) -> w blocks (nb, bv, bn)."""
    nb, bv, v_r = ktb.shape
    bn = ub.shape[2]
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bv, v_r), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, v_r, bn), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, bv, bn), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, bv, bn), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bv, bn), cblk.dtype),
        interpret=interpret,
    )(ktb, ub, cblk)


def bsr_sddmm(kt: jax.Array, u: jax.Array, c_bsr, interpret: bool = False):
    """Full BSR SDDMM: w = c .* (kt @ u) computed ONLY at retained tiles.

    kt (V, v_r) [K transposed]; u (v_r, N); c_bsr: BlockSparse over (V, N).
    Returns w blocks aligned with c_bsr (same brow/bcol).
    """
    bv, bn = c_bsr.block_shape
    # XLA gathers the per-block panels (data-dependent indices)
    ktb = kt.reshape(-1, bv, kt.shape[1])[c_bsr.brow]          # (nb, bv, v_r)
    ub = u.reshape(u.shape[0], -1, bn).transpose(1, 0, 2)[c_bsr.bcol]
    return bsr_sddmm_blocks(ktb, ub, c_bsr.blocks, interpret=interpret)


def bsr_sddmm_ref(kt: jax.Array, u: jax.Array, c_bsr):
    """Oracle: dense product masked by the BSR pattern, re-blocked."""
    full = kt @ u                                              # (V, N)
    bv, bn = c_bsr.block_shape
    out = []
    for b in range(c_bsr.blocks.shape[0]):
        i = int(c_bsr.brow[b])
        j = int(c_bsr.bcol[b])
        tile = full[i * bv:(i + 1) * bv, j * bn:(j + 1) * bn]
        out.append(c_bsr.blocks[b] * tile)
    return jnp.stack(out)

