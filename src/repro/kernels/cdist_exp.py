"""Pallas TPU kernel: fused GEMM-shaped Euclidean distance + exp + scale.

Paper §6: restructure ``cdist`` as a blocked matrix-multiplication-like
kernel and fuse the ``K = exp(-lam*M)`` and ``K_over_r = K / r`` follow-ups
so M, K, K_over_r are produced in ONE pass over the output tiles ("we use the
modified matrix-multiplication-like kernel to not only compute matrix M but
also K and K_over_r matrices at once"). On TPU this maps naturally:

  - the ``a @ b.T`` contraction runs on the MXU per (v_r, blockV) tile;
  - the sqrt/exp/divide epilogue runs on the VPU while the tile is still in
    VMEM/VREGs — the three outputs never round-trip HBM between stages;
  - ``b`` (the big V x w embedding matrix) is streamed tile-by-tile from HBM
    exactly once, which is the §6 bandwidth-reduction goal.

Grid: 1-D over V tiles. ``a`` (v_r x w, "tall-and-skinny" per the paper) and
``r`` stay resident in VMEM across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, r_ref, *out_refs, lam: float, k_only: bool,
            gemm: str, log_k: bool):
    a = a_ref[...]                       # (v_r, w)   resident
    b = b_ref[...]                       # (bv, w)    streamed tile
    r = r_ref[...]                       # (v_r, 1)
    if gemm == "bf16":                   # bf16 operands, fp32 accumulation
        ab = jax.lax.dot_general(a.astype(jnp.bfloat16),
                                 b.astype(jnp.bfloat16),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    else:
        ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # MXU
    a2 = jnp.sum(a * a, axis=1, keepdims=True)        # (v_r, 1)
    b2 = jnp.sum(b * b, axis=1)[None, :]              # (1, bv)
    d2 = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    m = jnp.sqrt(d2)
    # log_k: emit UNexponentiated log K = -lam*M (the log-domain solve
    # stabilizes per gathered column, so exp never underflows a column)
    k = -lam * m if log_k else jnp.exp(-lam * m)
    if k_only:
        (k_ref,) = out_refs
        k_ref[...] = k
        return
    m_ref, k_ref, kr_ref = out_refs
    m_ref[...] = m
    k_ref[...] = k
    kr_ref[...] = k / r


@functools.partial(jax.jit,
                   static_argnames=("lam", "block_v", "interpret", "k_only",
                                    "gemm", "log_k"))
def cdist_exp(a: jax.Array, b: jax.Array, r: jax.Array, lam: float,
              block_v: int = 512, interpret: bool = False,
              k_only: bool = False, gemm: str = "fp32",
              log_k: bool = False):
    """Fused (M, K, K_over_r) for query embeddings ``a`` (v_r, w), vocabulary
    embeddings ``b`` (V, w), query frequencies ``r`` (v_r,).

    V must divide by ``block_v``; pad ``w``/``v_r`` via
    :func:`repro.kernels.ops.pad_to` (zero-padding embedding width is exact —
    zeros add nothing to the distance).

    ``k_only=True`` writes ONLY the K output (returned alone): consumers
    that reconstruct GM from G (the fused solver path) would otherwise pay
    HBM stores for two dead (v_r, V) buffers — Pallas outputs can't be
    dead-code-eliminated by XLA.

    ``gemm="bf16"`` runs the MXU contraction with bf16 operands and fp32
    accumulation; ``log_k=True`` (with ``k_only``) emits ``-lam*M``
    unexponentiated for the log-domain solve.
    """
    v_r, w = a.shape
    v = b.shape[0]
    assert v % block_v == 0, (v, block_v)
    grid = (v // block_v,)
    out_spec = pl.BlockSpec((v_r, block_v), lambda i: (0, i))
    n_out = 1 if k_only else 3
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, k_only=k_only, gemm=gemm,
                          log_k=log_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_r, w), lambda i: (0, 0)),      # a resident
            pl.BlockSpec((block_v, w), lambda i: (i, 0)),  # b streamed
            pl.BlockSpec((v_r, 1), lambda i: (0, 0)),      # r resident
        ],
        out_specs=[out_spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((v_r, v), a.dtype)] * n_out,
        interpret=interpret,
    )(a, b, r.reshape(-1, 1))
    return out[0] if k_only else out
