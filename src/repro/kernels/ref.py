"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdist_exp_ref(a, b, r, lam: float):
    """Oracle for kernels.cdist_exp: (M, K, K_over_r)."""
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    d2 = jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)
    m = jnp.sqrt(d2)
    k = jnp.exp(-lam * m)
    return m, k, k / r[:, None]


def rwmd_min_cdist_ref(a, mask, b):
    """Oracle for kernels.rwmd.rwmd_min_cdist: masked min-over-support
    distances. a (Q, B, w), mask (Q, B), b (V, w) -> (Q, V)."""
    a2 = jnp.sum(a * a, axis=-1)[:, :, None]
    b2 = jnp.sum(b * b, axis=-1)[None, None, :]
    ab = jnp.einsum("qbw,vw->qbv", a, b)
    d = jnp.sqrt(jnp.maximum(a2 + b2 - 2.0 * ab, 0.0))
    return jnp.min(jnp.where(mask[:, :, None] > 0, d, jnp.inf), axis=1)


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


def sddmm_spmm_step_ref(g, g_over_r, val, x):
    """Oracle for kernels.sddmm_spmm_step (one fused iteration)."""
    u = _safe_inv(x)
    t = jnp.einsum("knl,kn->nl", g, u)
    w = val * _safe_inv(t)
    return jnp.einsum("knl,nl->kn", g_over_r, w)


def sinkhorn_fused_all_materialized_ref(g, gm, val, r, n_iter: int):
    """Explicit-GM oracle (the pre-reconstruction formulation): used to prove
    the in-VMEM GM reconstruction equals the materialized gather."""
    rowmask = jnp.sum(jnp.abs(g), axis=(1, 2)) > 0
    v_r_true = jnp.sum(rowmask.astype(g.dtype))
    x0 = jnp.where(rowmask, 1.0 / v_r_true, 0.0)
    x = jnp.broadcast_to(x0[:, None], (g.shape[0], g.shape[1]))
    gor = g * _safe_inv(r)[:, None, None]
    live = (val > 0).astype(g.dtype)

    def body(_, x):
        u = _safe_inv(x)
        t = jnp.einsum("knl,kn->nl", g, u)
        w = val * _safe_inv(t) * live
        return jnp.einsum("knl,nl->kn", gor, w)

    x = jax.lax.fori_loop(0, n_iter, body, x)
    u = _safe_inv(x)
    t = jnp.einsum("knl,kn->nl", g, u)
    w = val * _safe_inv(t) * live
    return jnp.einsum("kn,knl,nl->n", u, gm, w)


def reconstruct_gm_ref(g, lam: float):
    """Oracle for kernels.sddmm_spmm.reconstruct_gm: GM = -G*log(G)/lam."""
    safe = jnp.where(g > 0, g, 1.0)
    return jnp.where(g > 0, -g * jnp.log(safe) / lam, 0.0)


def sinkhorn_fused_all_ref(g, val, r, lam: float, n_iter: int):
    """Oracle for kernels.sinkhorn_fused_all (full solve + distance; GM
    reconstructed from G exactly as the kernel does)."""
    return sinkhorn_fused_all_materialized_ref(g, reconstruct_gm_ref(g, lam),
                                               val, r, n_iter)
