"""Sharding rules: params / optimizer state / activations / caches ->
PartitionSpecs for the production mesh (DESIGN.md §6).

Megatron-style TP over ``model``; DP over ``data`` (+ ``pod``); vocab-sharded
embeddings and logits; expert parallelism for MoE; sequence-sharded KV cache
for the long-context decode cells. A ``stage`` axis hook is reserved for PP
(unused at 512 chips — DP x TP covers every assigned arch).

Rules are name-based over the param pytree paths — one table instead of
per-module annotations, auditable in one screen.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex over 'a/b/c' path, spec builder(ndim) -> PartitionSpec)
# Specs are written for the LAST dims; leading stacked layer/group dims are
# replicated (None-padded on the left automatically).
_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                    ("model", None)),
    (r"lm_head$",                  (None, "model")),
    # attention
    (r"attn/w[qkv]$",              (None, "model")),
    (r"attn/wo$",                  ("model", None)),
    (r"attn/b[qkv]$",              ("model",)),
    # dense mlp / shared expert / rwkv channel-mix
    (r"(mlp|cmix|shared)/w_(gate|up|in)$", (None, "model")),
    (r"(mlp|cmix|shared)/w_(down|out)$",   ("model", None)),
    # moe: experts over model (EP); router replicated
    (r"moe/router$",               (None, None)),
    (r"moe/w_(gate|up)$",          ("model", None, None)),
    (r"moe/w_down$",               ("model", None, None)),
    # mamba2: heads/d_inner over model; B/C small -> replicated
    (r"mamba/w_(z|x)$",            (None, "model")),
    (r"mamba/w_bc$",               (None, None)),
    (r"mamba/w_dt$",               (None, "model")),
    (r"mamba/conv_x$",             (None, "model")),
    (r"mamba/conv_bias_x$",        ("model",)),
    (r"mamba/(conv_bc|conv_bias_bc)$", (None,)),
    (r"mamba/(a_log|d_skip|dt_bias)$", ("model",)),
    (r"mamba/norm_scale$",         ("model",)),
    (r"mamba/out_proj$",           ("model", None)),
    # rwkv6 time-mix
    (r"tmix/w[rkvg]$",             (None, "model")),
    (r"tmix/wo$",                  ("model", None)),
    (r"tmix/w0$",                  ("model",)),
    (r"tmix/w1$",                  (None, None)),
    (r"tmix/w2$",                  (None, "model")),
    (r"tmix/u$",                   ("model", None)),
    (r"tmix/ln_scale$",            ("model",)),
    (r"tmix/mu$",                  (None, None)),
    # norms & everything small
    (r".*",                        ()),
]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def param_spec_for(path: str, ndim: int) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            spec = tuple(spec)
            if len(spec) > ndim:          # scalar-ish leaf
                spec = spec[-ndim:] if ndim else ()
            pad = (None,) * (ndim - len(spec))
            return P(*(pad + spec))
    return P()


def param_specs(params, fsdp_axes: tuple = ()) -> Any:
    """Pytree of PartitionSpec matching the params pytree.

    ``fsdp_axes`` (e.g. ('data',) or ('pod','data')): additionally shard
    every large leaf over these axes on its first still-unsharded,
    divisible dim — ZeRO-3/FSDP. XLA all-gathers weights per layer inside
    the scan (the MaxText pattern); required for the >=14B archs where
    params+opt exceed HBM under TP-only sharding (DESIGN.md §6)."""
    import numpy as np

    def nshards(axes) -> int:
        n = 1
        for a in axes:
            n *= _AXIS_SIZES.get(a, 1)
        return n

    def spec_of(path, x):
        base = param_spec_for(_path_str(path), x.ndim)
        if not fsdp_axes or int(np.prod(x.shape)) < (1 << 20):
            return base
        need = nshards(fsdp_axes)
        entries = list(base) + [None] * (x.ndim - len(base))
        # search from the LAST dim: leading dims of stacked per-layer params
        # are the lax.scan axis — sharding the scan axis forces XLA to
        # re-gather the whole stack inside inner loops (measured 9.9 TB of
        # all-gathers on qwen2.5 before this fix; EXPERIMENTS.md §Perf #1)
        for i in reversed(range(len(entries))):
            if entries[i] is None and x.shape[i] % need == 0 \
                    and x.shape[i] >= need:
                entries[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                return P(*entries)
        return base

    return jax.tree_util.tree_map_with_path(spec_of, params)


# set by launchers before building specs (mesh axis name -> size)
_AXIS_SIZES: dict[str, int] = {"pod": 2, "data": 16, "model": 16}


def set_axis_sizes(mesh: Mesh) -> None:
    global _AXIS_SIZES
    _AXIS_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))


def opt_state_specs(params_specs, zero1: bool = False) -> Any:
    """AdamW state specs: step replicated; m/v mirror the params.

    ``zero1=True`` additionally shards any replicated-leading-dim moment
    over 'data' (ZeRO-1-style optimizer state partitioning, beyond-paper
    memory optimization; params stay as-is, update gathers are XLA's).
    """
    from repro.optim.adamw import AdamWState

    def z1(spec: P) -> P:
        if not zero1 or len(spec) == 0:
            return spec
        if spec[0] is None:
            return P(*(("data",) + tuple(spec[1:])))
        return spec

    mv = jax.tree.map(z1, params_specs,
                      is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), m=mv, v=mv)


def batch_spec(mesh: Mesh) -> P:
    """(B, T) token batches: batch over every data-ish axis."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return P(axes)


def activation_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return P(axes, None, None)


def cache_specs(cache, mesh: Mesh, seq_shard: bool = False) -> Any:
    """Serve-cache specs. KV caches (L, B, H_kv, S, D): batch over data,
    heads over model. ``seq_shard=True`` (long_500k, batch=1): shard the
    cache SEQUENCE dim over data instead (sequence parallelism)."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")

    def spec(path, x):
        name = _path_str(path)
        nd = x.ndim
        if name in ("k", "v"):
            if seq_shard:
                # (L?, B, H, S, D) -> S over data, H over model
                s = [None] * nd
                s[-2] = data_axes
                s[-3] = "model"
                return P(*s)
            s = [None] * nd
            s[-4] = data_axes
            s[-3] = "model"
            return P(*s)
        if name in ("wkv", "ssm", "ssm_rem"):
            # (..., B, H, N/D, P): B over data, H over model
            s = [None] * nd
            s[-4] = data_axes if not seq_shard else None
            s[-3] = "model"
            return P(*s)
        if name in ("conv", "conv_rem", "shift"):
            s = [None] * nd
            s[-3] = data_axes if not seq_shard else None
            return P(*s)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def shardings(mesh: Mesh, tree_of_specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------- corpus doc sharding
def ensure_host_devices(n: int) -> int:
    """Make at least ``n`` devices visible, forcing host-platform CPU
    devices when no real accelerators exist.

    XLA only honors ``--xla_force_host_platform_device_count`` if it is
    set BEFORE the backend initializes, so this merges the flag into
    ``XLA_FLAGS`` and then touches ``jax.devices()``; call it before the
    first jax array operation (``launch/serve.py --shards N`` and
    ``examples/wmd_search.py --shards N`` do). Raises if the backend was
    already initialized with too few devices — the flag cannot apply
    retroactively. Returns the visible device count.
    """
    import os

    n = int(n)
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    count = jax.device_count()
    if count < n:
        raise RuntimeError(
            f"need {n} devices but the jax backend initialized with "
            f"{count}; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} in the environment before the process does any "
            f"jax work")
    return count


def corpus_mesh(n_shards: int, devices=None) -> Mesh:
    """1-D mesh over the doc-shard axis for
    :class:`repro.core.shard_index.ShardedCorpusIndex` — distinct from
    the LM param mesh above: corpus serving shards DATA (docs), nothing
    model-parallel."""
    import numpy as np

    devs = (list(devices) if devices is not None
            else jax.devices()[:int(n_shards)])
    if len(devs) < int(n_shards):
        raise RuntimeError(f"corpus_mesh({n_shards}) needs {n_shards} "
                           f"devices, found {len(devs)}; see "
                           f"ensure_host_devices")
    return Mesh(np.asarray(devs[:int(n_shards)]), axis_names=("shard",))
