"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: before the cross-pod gradient reduction,
quantize each gradient tensor to int8 with per-block fp32 scales (block =
last axis), all-reduce the int8 payload (4x less DCN traffic — the pod axis
crosses data-center network, the expensive hop), dequantize, and keep the
quantization residual locally, adding it back into the NEXT step's gradient
(error feedback — keeps SGD/Adam convergence, Karimireddy et al. 2019).

Inside a pod (ICI) gradients stay fp32 — compression only pays where
bandwidth is scarce. Enabled with ``--grad-compression int8`` in the
trainer; the quantize/dequantize ops are pure jnp and fuse into the step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256):
    """x (...) -> (q int8, scales fp32). Per-block absmax scaling on the
    flattened tensor (padded to a block multiple)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = -(-n // block) * block
    flat = jnp.pad(flat, (0, npad - n))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_grads_with_feedback(grads: Any, residual: Any, block: int = 256):
    """(grads + residual) -> (quantize->dequantize round trip, new residual).

    The returned grads are what the optimizer consumes — identical on every
    chip, so the all-reduce can run on the int8 payload. New residual is the
    local quantization error (added into next step's grads)."""
    def one(g, r):
        x = g + r
        q, s = quantize_int8(x, block)
        deq = dequantize_int8(q, s, g.shape, g.dtype)
        return deq, x - deq

    pairs = jax.tree.map(one, grads, residual)
    new_grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_res


def zero_residual(params: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, params)
