"""Roofline accounting (EXPERIMENTS.md §Roofline methodology).

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE, which
under-reports any scanned program (layers, flash-attention blocks, loss
chunks) by orders of magnitude. Two complementary tools fix this:

``jaxpr_cost(fn, *args)``
    Walks the closed jaxpr of the TRACED program (backward pass included),
    multiplying through statically-known scan trip counts:
      * FLOPs — exact for dot_general/conv (2*M*N*K), 1 flop/element for
        elementwise — matmul-dominated programs are accounted to ~1%;
      * major-op HBM bytes — operands+results of dot/conv/gather/scatter/
        cumsum/sort plus scan carries; elementwise chains are assumed fused
        (XLA does). This is a principled *lower bound* used to pick the
        dominant roofline term.
    Counts are GLOBAL (logical program); per-chip = /n_chips, exact for the
    sharded dims (padding overhead is IN the jaxpr since models are built
    with their TP-padded shapes).

``hlo_collective_bytes(compiled_text, trip_hints)``
    Parses post-SPMD HLO: sums per-op payload bytes of every all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute, multiplies
    collectives inside while bodies by the loop trip count (parsed from the
    canonicalized loop condition; falls back to ``trip_hints`` patterns).
    Bytes are PER DEVICE (post-partitioning shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
import jax
import numpy as np

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_MAJOR_PRIMS = {"dot_general", "conv_general_dilated", "gather", "scatter",
                "scatter-add", "scatter_add", "cumsum", "sort", "top_k",
                "dynamic_slice", "dynamic_update_slice", "take"}


def _size(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def _bytes(aval) -> int:
    return _size(aval) * aval.dtype.itemsize


def _dot_flops(eqn) -> int:
    (lc, rc), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * _size(eqn.outvars[0].aval) * k


class Cost:
    def __init__(self):
        self.flops = 0
        self.major_bytes = 0
        self.by_prim = defaultdict(int)

    def as_dict(self):
        top = sorted(self.by_prim.items(), key=lambda kv: -kv[1])[:8]
        return {"flops": float(self.flops),
                "major_bytes": float(self.major_bytes),
                "top_flops_prims": {k: float(v) for k, v in top}}


def _walk(jaxpr, mult: int, cost: Cost) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            # carries + per-trip slices cross HBM each iteration
            cost.major_bytes += mult * length * sum(
                _bytes(v.aval) for v in inner.invars)
            _walk(inner, mult * length, cost)
        elif prim == "while":
            # bounded fori_loop lowered to while: find constant trip count
            body = eqn.params["body_jaxpr"].jaxpr
            trips = eqn.params.get("_trip_hint", 1)
            _walk(body, mult * trips, cost)
        elif prim == "shard_map":
            # body is traced at PER-SHARD shapes; every chip in the manual
            # mesh executes it -> multiply by mesh size so the global
            # accounting stays consistent with the pjit regions
            inner = eqn.params["jaxpr"]
            n_shards = 1
            for ax in eqn.params["manual_axes"]:
                n_shards *= dict(zip(eqn.params["mesh"].axis_names,
                                     eqn.params["mesh"].axis_sizes
                                     if hasattr(eqn.params["mesh"],
                                                "axis_sizes")
                                     else eqn.params["mesh"].shape_tuple
                                     if hasattr(eqn.params["mesh"],
                                                "shape_tuple")
                                     else eqn.params["mesh"].devices.shape)
                                 )[ax]
            _walk(getattr(inner, "jaxpr", inner), mult * n_shards, cost)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat2"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                _walk(getattr(inner, "jaxpr", inner), mult, cost)
        elif prim == "cond":
            for br in eqn.params["branches"]:
                _walk(br.jaxpr, mult, cost)       # upper bound: all branches
        elif prim == "dot_general":
            f = _dot_flops(eqn) * mult
            cost.flops += f
            cost.by_prim[prim] += f
            cost.major_bytes += mult * (sum(_bytes(v.aval) for v in eqn.invars)
                                        + _bytes(eqn.outvars[0].aval))
        elif prim == "conv_general_dilated":
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            f = 2 * _size(out) * int(np.prod(rhs.shape[1:])) * mult
            cost.flops += f
            cost.by_prim[prim] += f
            cost.major_bytes += mult * (sum(_bytes(v.aval) for v in eqn.invars)
                                        + _bytes(out))
        else:
            out_elems = sum(_size(v.aval) for v in eqn.outvars
                            if hasattr(v.aval, "shape"))
            f = out_elems * mult
            cost.flops += f
            cost.by_prim[prim] += f
            if prim in _MAJOR_PRIMS:
                cost.major_bytes += mult * (
                    sum(_bytes(v.aval) for v in eqn.invars)
                    + sum(_bytes(v.aval) for v in eqn.outvars))


def jaxpr_cost(fn, *args, **kwargs) -> dict:
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    cost = Cost()
    _walk(closed.jaxpr, 1, cost)
    # program inputs/outputs cross HBM once
    cost.major_bytes += sum(_bytes(v.aval) for v in closed.jaxpr.invars)
    cost.major_bytes += sum(_bytes(v.aval) for v in closed.jaxpr.outvars)
    return cost.as_dict()


# ------------------------------------------------------------------ HLO side
_COLL_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _computation_blocks(txt: str) -> dict[str, str]:
    """Split HLO module text into computation-name -> body.

    Headers are column-0 lines like ``%name (args...) -> type {`` (args may
    contain nested tuple parens, headers may wrap lines); bodies are the
    indented lines until the column-0 ``}``."""
    blocks = {}
    cur_name, cur = None, []
    pending_header = None
    for line in txt.splitlines():
        if not line.startswith(" ") and line.strip():
            if cur_name and line.startswith("}"):
                blocks[cur_name] = "\n".join(cur)
                cur_name, cur = None, []
                continue
            header = (pending_header + " " + line) if pending_header else line
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", header.strip())
            if "{" in header:
                pending_header = None
                if m:
                    cur_name = m.group(1)
                    cur = []
            else:
                pending_header = header        # wrapped header line
        elif cur_name:
            cur.append(line)
    return blocks


def _while_trips(txt: str, blocks: dict[str, str]) -> dict[str, int]:
    """Map while BODY computation name -> trip count (best-effort parse of
    the canonical `ivar < constant` condition)."""
    trips = {}
    for m in re.finditer(r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*"
                         r"body=%?([\w\.\-]+)", txt):
        cond, body = m.group(1), m.group(2)
        blk = blocks.get(cond, "")
        n = None
        cm = re.search(r"compare\(\s*%?([\w\.\-]+),\s*%?([\w\.\-]+)\s*\)"
                       r",\s*direction=LT", blk)
        if cm:
            for const in cm.groups():
                km = re.search("%" + re.escape(const) +
                               r"\s*=\s*s32\[\]\s*constant\((\d+)\)", blk)
                if km:
                    n = int(km.group(1))
                    break
        if n is None:
            # canonical loops keep the bound as the only s32 constant
            consts = re.findall(r"=\s*s32\[\]\s*constant\((\d+)\)", blk)
            if len(consts) == 1:
                n = int(consts[0])
        trips[body] = n if n else 1
    return trips


def _bf16_downcast_ids(txt: str) -> set[str]:
    """Collective op ids whose result is immediately converted to bf16.

    XLA-CPU promotes bf16 dots to f32 and the SPMD partitioner places the
    all-reduce BEFORE the convert-back; the TPU backend all-reduces in bf16
    (verified with a minimal row-sharded matmul probe — see EXPERIMENTS.md
    §Roofline methodology). Payload bytes for these ops are halved in the
    ``total_bytes_tpu`` figure."""
    ids = set()
    for m in re.finditer(r"=\s*bf16\[[^\]]*\]\S*\s+(?:fusion|convert)"
                         r"\(%((?:all-reduce|all-gather|reduce-scatter)"
                         r"[\w\.\-]*)", txt):
        ids.add(m.group(1))
    return ids


def hlo_collective_bytes(txt: str) -> dict:
    """Per-device collective payload bytes by op type, while-trip adjusted."""
    blocks = _computation_blocks(txt)
    trips = _while_trips(txt, blocks)
    downcast = _bf16_downcast_ids(txt)

    # computation -> multiplier: bodies of whiles get their trip count;
    # nested whiles multiply (computed via fixpoint over call edges)
    mult = {name: 1 for name in blocks}
    for body, n in trips.items():
        if body in mult:
            mult[body] = n
    # propagate: a while body called from another while body
    calls = {name: re.findall(r"body=%?([\w\.\-]+)", body_txt)
             for name, body_txt in blocks.items()}
    for _ in range(4):                               # small nesting depth
        for name, callees in calls.items():
            for c in callees:
                if c in mult and c in trips:
                    mult[c] = trips[c] * mult.get(name, 1)

    out: dict[str, float] = defaultdict(float)
    out_tpu: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    op_id_re = re.compile(r"%((?:all-reduce|all-gather|reduce-scatter|"
                          r"all-to-all|collective-permute)[\w\.\-]*)\s*=")
    for name, body_txt in blocks.items():
        m = mult.get(name, 1)
        for line in body_txt.splitlines():
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            dtype, dims, kind = cm.group(1), cm.group(2), cm.group(3)
            b = _shape_bytes(dtype, dims) * m
            out[kind] += b
            im = op_id_re.search(line)
            halve = (dtype == "f32" and im is not None
                     and im.group(1) in downcast)
            out_tpu[kind] += b / 2 if halve else b
            counts[kind] += m
    total = float(sum(out.values()))
    return {"per_type_bytes": dict(out), "counts": dict(counts),
            "total_bytes": total,
            "total_bytes_tpu": float(sum(out_tpu.values()))}


# ---------------------------------------------------- analytic HBM model
def analytic_hbm_bytes(cfg, kind: str, gb: int, seq: int, n_chips: int,
                       tp: int, dtype_bytes: int = 2,
                       act_io_per_block: int = 16) -> float:
    """Per-chip HBM traffic model (the roofline memory term).

    Sharding-aware where the jaxpr walker cannot be: WEIGHTS are read in
    full by every data shard (traffic = P/tp per chip), while ACTIVATIONS
    divide across all chips. Components:

      train:   weights (fwd + remat-refwd + bwd dgrad reads, grad write)
               + AdamW fp32 state (read m,v,p + write m,v,p)
               + residual-stream activations: act_io_per_block tensor
                 passes of (tokens_loc x d) per block, x3 for fwd/refwd/bwd
               + loss logits slab (fp32 read+write, chunked)
      prefill: weights once + activations x1
      decode:  weights once (the classic decode floor) + KV/state cache
               read+write + small activations

    act_io_per_block=16 ~ residual + norms + qkv/attn + mlp intermediate
    reads/writes after XLA fusion (validated against the jaxpr major-bytes
    column at small configs).
    """
    p_chip = cfg.n_params() / tp * dtype_bytes
    d = cfg.d_model
    tok_loc = gb * seq / max(n_chips / tp, 1)    # tokens per data shard
    layer_w = max(cfg.num_layers, 1)

    # residual-stream activations are replicated across TP (only weights and
    # heads shard over 'model'), so act traffic does NOT divide by tp
    act = act_io_per_block * layer_w * tok_loc * d * dtype_bytes
    vp = -(-cfg.vocab_size // tp) * tp
    logits_io = 2 * tok_loc * (vp / tp) * 4      # fp32 slab r+w, V sharded

    if kind == "train":
        weights = p_chip * (3 + 1)               # fwd, refwd, dgrad + gwrite
        opt = cfg.n_params() / tp * 4 * 6        # m,v,p fp32 r+w
        return weights + opt + 3 * act + logits_io
    if kind == "prefill":
        return p_chip + act + logits_io
    # decode: one token; cache dominates
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "ssm":
            n_heads = -(-(d // cfg.ssm.head_dim) // tp) * tp
            state = (cfg.num_layers * gb * n_heads * cfg.ssm.head_dim ** 2
                     + cfg.num_layers * gb * 2 * d)
        else:
            d_in = cfg.ssm.expand * d
            n_heads = d_in // cfg.ssm.head_dim
            state = cfg.num_layers * gb * (
                n_heads * cfg.ssm.d_state * cfg.ssm.head_dim
                + (cfg.ssm.conv_width - 1) * (d_in + 2 * cfg.ssm.d_state))
            n_groups = cfg.num_layers // cfg.attn_every
            _, n_kv = cfg.tp_heads(tp)
            state += n_groups * gb * n_kv * seq * cfg.head_dim / tp * 2
        cache_io = 2 * state * dtype_bytes / max(n_chips / tp, 1)
    else:
        _, n_kv = cfg.tp_heads(tp)
        kv = cfg.num_layers * gb * n_kv * seq * cfg.head_dim * 2
        # read the whole (chip-resident) cache slice + write one slot
        cache_io = kv * dtype_bytes / n_chips
    return p_chip + cache_io + 2 * gb * d * cfg.num_layers * dtype_bytes


# ------------------------------------------------------------- roofline
HW = {
    "peak_flops_bf16": 197e12,     # TPU v5e per chip
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
}


def roofline_terms(global_flops: float, global_major_bytes: float,
                   per_dev_collective_bytes: float, n_chips: int,
                   model_flops: float) -> dict:
    compute_s = global_flops / n_chips / HW["peak_flops_bf16"]
    memory_s = global_major_bytes / n_chips / HW["hbm_bw"]
    coll_s = per_dev_collective_bytes / HW["ici_bw"]
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    step_s = max(compute_s, memory_s, coll_s)
    mfu = (model_flops / n_chips / HW["peak_flops_bf16"]) / step_s \
        if step_s > 0 else 0.0
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / global_flops if global_flops else 0.0,
        "roofline_mfu": mfu,
    }
