"""Fault tolerance & elasticity runtime (host-side control plane).

At 1000+ nodes the failure model is: hosts die, hosts straggle, and the
job must (a) never lose more than checkpoint_interval steps, (b) detect and
route around stragglers, (c) restart on a DIFFERENT device count without
manual intervention. The pieces:

``StepGuard``     — wraps the train step with retry-on-transient-failure and
                    poison classification: a deterministic failure (NaN /
                    non-finite output, assertion) raises ``PoisonStep``
                    immediately instead of burning ``max_retries`` on a
                    result that cannot change. Backoff is exponential with
                    seeded jitter (a fleet of guards restarting in lockstep
                    re-stampedes whatever fell over).
``DispatchGuard`` — the serving-side extension (ISSUE 6): StepGuard's
                    retry/backoff plus a wall-clock watchdog per dispatch
                    (stragglers are counted and flagged, not silently
                    absorbed into the latency tail), per-attempt hooks for
                    fault injection, and poison-REQUEST classification —
                    ``LamUnderflowError`` and ``PoisonStep`` subclasses are
                    deterministic per-request failures the serving runtime
                    isolates into structured error responses rather than
                    retrying or letting them kill the coalesced batch.
``ShardHealth``   — per-shard circuit breaker for the sharded fan-out
                    (ISSUE 9): consecutive-failure counts open a shard's
                    circuit, a deterministic probe cadence re-admits it,
                    and per-shard service-time EMAs feed the coverage
                    accounting. No randomness anywhere — a chaos drill
                    replays the same skip/probe/re-admit sequence from
                    the same fault schedule.
``Heartbeat``     — per-host step-time EMA; quorum straggler detection (a
                    host slower than median * threshold for N consecutive
                    steps is flagged for eviction — on real fleets this feeds
                    the cluster scheduler; here it feeds logs + the elastic
                    re-mesh hook). The serving runtime reuses the EMA lanes
                    as per-TIER service-time estimates (``ema()``).
``elastic_mesh``  — mesh shapes as a function of the LIVE host count:
                    checkpoint save/restore is mesh-independent
                    (repro.checkpoint), so recovery is: detect -> rebuild
                    mesh from survivors -> restore -> continue.
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

import jax


class PoisonStep(Exception):
    """Deterministic failure (NaN loss, assertion) — do NOT retry."""


class DispatchFailed(Exception):
    """Transient-failure retries exhausted for one dispatch.

    Deliberately NOT a RuntimeError: outer guards classify RuntimeError as
    transient-and-retryable, and a dispatch that already consumed its own
    retry budget must not be retried again upstream."""


def _nonfinite_leaves(out) -> list[str]:
    """Names/indices of float pytree leaves with any non-finite entry.

    Forces a device sync per float leaf — callers guarding large pytrees
    (full parameter trees) should leave ``check_finite`` off and check a
    cheap scalar themselves; serving dispatches return small host arrays
    where the sync is free."""
    bad = []
    for i, leaf in enumerate(jax.tree_util.tree_leaves(out)):
        try:
            arr = np.asarray(leaf)
        except (TypeError, ValueError):
            continue
        if np.issubdtype(arr.dtype, np.floating) \
                and not np.isfinite(arr).all():
            bad.append(f"leaf[{i}]")
    return bad


@dataclass
class StepGuard:
    """Retry-on-transient-failure wrapper with poison classification.

    ``check_finite=True`` additionally classifies a step whose OUTPUT
    contains NaN/inf float leaves as :class:`PoisonStep` — a deterministic
    NaN re-runs identically, so retrying it ``max_retries`` times only
    delays the inevitable (and previously surfaced as a generic
    ``RuntimeError`` after the full backoff schedule). Off by default:
    the finite check syncs every float leaf (see :func:`_nonfinite_leaves`).

    Backoff is ``backoff_s * 2**attempt * (1 + jitter * U[0,1))`` with the
    uniform draw from a ``seed``-deterministic stream — reproducible in
    tests, desynchronized across a fleet.
    """

    max_retries: int = 3
    backoff_s: float = 1.0
    jitter: float = 0.25
    seed: int = 0
    check_finite: bool = False

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def _sleep(self, attempt: int) -> None:
        time.sleep(self.backoff_s * (2 ** attempt)
                   * (1.0 + self.jitter * self._rng.random()))

    def run(self, step_fn, *args):
        """Run step_fn; retry transient failures with jittered backoff;
        re-raise deterministic poison immediately."""
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                out = step_fn(*args)
                if self.check_finite:
                    bad = _nonfinite_leaves(out)
                    if bad:
                        raise PoisonStep(
                            f"non-finite step output ({', '.join(bad)}): "
                            "deterministic failure, not retried")
                return out
            except PoisonStep:
                raise
            except (jax.errors.JaxRuntimeError, RuntimeError, OSError) as e:
                last = e
                if attempt < self.max_retries:
                    self._sleep(attempt)
        raise RuntimeError(
            f"step failed after {self.max_retries + 1} attempts") from last


@dataclass
class DispatchGuard(StepGuard):
    """Serving dispatch guard (ISSUE 6): retry/timeout/backoff around ONE
    engine dispatch.

    Extends :class:`StepGuard` with:

    - *poison-request classification*: ``PoisonStep`` subclasses AND
      ``FloatingPointError`` (``repro.core.sinkhorn.LamUnderflowError``)
      are deterministic per-request failures — re-raised immediately so
      the serving runtime can fall back to per-request isolation and
      return a structured error for the poisoned request while its
      batchmates still get answers;
    - *wall-clock watchdog*: a dispatch (successful or not) that exceeds
      ``watchdog_s`` increments ``watchdog_trips`` — the runtime tags the
      affected responses as straggler-served. Cooperative: a running XLA
      dispatch cannot be preempted from Python, so the watchdog classifies
      and accounts rather than kills (the bound it enforces is on the
      RETRY budget: a straggling attempt still counts against it);
    - *per-attempt hook* ``before_attempt(tag, attempt)``: the fault
      injector's entry point (latency/transient injection runs inside the
      guarded region so the retry path is exercised, not simulated).

    Counters (``retries``, ``watchdog_trips``) accumulate across calls —
    one guard instance per runtime, read by ``stats()``.
    """

    watchdog_s: float = 5.0
    before_attempt: Callable | None = None
    retries: int = field(default=0, init=False)
    watchdog_trips: int = field(default=0, init=False)

    def run(self, fn, *args, tag: int = 0):
        last = None
        for attempt in range(self.max_retries + 1):
            t0 = time.monotonic()
            try:
                if self.before_attempt is not None:
                    self.before_attempt(tag, attempt)
                out = fn(*args)
                if time.monotonic() - t0 > self.watchdog_s:
                    self.watchdog_trips += 1
                return out
            except (PoisonStep, FloatingPointError):
                raise          # deterministic: isolate, never retry
            except (jax.errors.JaxRuntimeError, RuntimeError, OSError) as e:
                last = e
                if time.monotonic() - t0 > self.watchdog_s:
                    self.watchdog_trips += 1
                self.retries += 1
                if attempt < self.max_retries:
                    self._sleep(attempt)
        raise DispatchFailed(
            f"dispatch failed after {self.max_retries + 1} attempts "
            f"({type(last).__name__}: {last})") from last


@dataclass
class Heartbeat:
    """Step-time tracking + straggler flagging (host-local view of the
    fleet; on multi-host deployments the timings are all-gathered through
    the coordination service once per interval)."""
    threshold: float = 1.5
    patience: int = 5
    ema_alpha: float = 0.2
    _ema: dict = field(default_factory=dict)
    _strikes: dict = field(default_factory=dict)

    def record(self, host_id: int, step_time_s: float) -> None:
        prev = self._ema.get(host_id, step_time_s)
        self._ema[host_id] = (1 - self.ema_alpha) * prev \
            + self.ema_alpha * step_time_s

    def ema(self, host_id: int) -> float | None:
        """Current smoothed step time for one lane (``None`` before the
        first record). The serving runtime keys lanes by degradation TIER
        and reads this as the tier's expected service time when deciding
        whether a request's remaining deadline budget still affords it."""
        return self._ema.get(host_id)

    def stragglers(self) -> list[int]:
        if len(self._ema) < 2:
            return []
        times = sorted(self._ema.values())
        median = times[len(times) // 2]
        out = []
        for host, t in self._ema.items():
            if t > self.threshold * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.patience:
                    out.append(host)
            else:
                self._strikes[host] = 0
        return out


@dataclass
class ShardHealth:
    """Deterministic per-shard circuit breaker (ISSUE 9).

    Drives the sharded engine's fan-out admission: a shard that fails
    ``fail_threshold`` consecutive dispatches has its circuit OPENED and
    is skipped (its docs drop out of coverage); every ``probe_every``-th
    skipped fan-out the shard is probed — one real dispatch — and a
    successful probe closes the circuit and re-admits it. The cadence is
    a pure counter, not a timer or a random draw, so a chaos drill with
    a fixed fault schedule replays the identical skip/probe/re-admit
    sequence every run.

    Also keeps a service-time EMA per shard (successful dispatches only)
    — the fan-out's analogue of :class:`Heartbeat` lanes — exposed via
    :meth:`stats` for the serving runtime's observability surface.
    """

    n_shards: int
    fail_threshold: int = 3
    probe_every: int = 4
    ema_alpha: float = 0.3

    def __post_init__(self):
        n = self.n_shards
        self._consecutive = [0] * n
        self._open = [False] * n
        self._skips = [0] * n
        self._ema: dict = {}
        self.failures = [0] * n      # total failed dispatches per shard
        self.successes = [0] * n
        self.probes = [0] * n        # dispatches admitted through an open circuit
        self.opened = [0] * n        # times the circuit tripped open

    def admit(self, shard: int) -> bool:
        """Should this fan-out dispatch to ``shard``? Closed circuit:
        always. Open circuit: every ``probe_every``-th call (a probe)."""
        if not self._open[shard]:
            return True
        self._skips[shard] += 1
        if self._skips[shard] % self.probe_every == 0:
            self.probes[shard] += 1
            return True
        return False

    def record_success(self, shard: int, service_s: float) -> None:
        """A dispatch answered: reset strikes, close the circuit (a
        successful probe re-admits the shard), update the EMA."""
        self.successes[shard] += 1
        self._consecutive[shard] = 0
        self._open[shard] = False
        self._skips[shard] = 0
        prev = self._ema.get(shard, service_s)
        self._ema[shard] = (1 - self.ema_alpha) * prev \
            + self.ema_alpha * service_s

    def record_failure(self, shard: int) -> None:
        """A dispatch timed out or errored: one strike; at
        ``fail_threshold`` consecutive strikes the circuit opens."""
        self.failures[shard] += 1
        self._consecutive[shard] += 1
        if self._consecutive[shard] >= self.fail_threshold \
                and not self._open[shard]:
            self._open[shard] = True
            self._skips[shard] = 0
            self.opened[shard] += 1

    def reset(self, shard: int) -> None:
        """Forget a shard's history — called after snapshot restore
        rejoins it to the mesh (the restored shard is a new process;
        its predecessor's strikes are not its own)."""
        self._consecutive[shard] = 0
        self._open[shard] = False
        self._skips[shard] = 0
        self._ema.pop(shard, None)

    def is_open(self, shard: int) -> bool:
        return self._open[shard]

    @property
    def open_shards(self) -> tuple:
        return tuple(i for i in range(self.n_shards) if self._open[i])

    def ema(self, shard: int) -> float | None:
        """Smoothed service time for one shard (None before first success)."""
        return self._ema.get(shard)

    def stats(self) -> dict:
        return {
            "open": list(self.open_shards),
            "failures": list(self.failures),
            "successes": list(self.successes),
            "probes": list(self.probes),
            "opened": list(self.opened),
            "ema_s": {s: round(v, 6) for s, v in sorted(self._ema.items())},
        }


def elastic_mesh(n_devices: int, model_parallel: int = 16,
                 pod_size: int = 256):
    """Best mesh for the LIVE device count (survivor set after failures).

    Keeps TP fixed (=16: weights are sharded that way and resharding TP is
    the expensive path) and absorbs device loss in the data/pod axes —
    standard elastic-DP. n_devices must be a multiple of model_parallel."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"TP={model_parallel}")
    rest = n_devices // model_parallel
    if n_devices > pod_size and rest % (pod_size // model_parallel) == 0:
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((rest, model_parallel), ("data", "model"))


def scaled_global_batch(base_batch: int, base_hosts: int,
                        live_hosts: int, keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-host batch grows) or
    scale it with the fleet (exact per-host batch, LR rescaled by caller)."""
    if keep_global:
        per = math.ceil(base_batch / live_hosts)
        return per * live_hosts
    return (base_batch // base_hosts) * live_hosts
