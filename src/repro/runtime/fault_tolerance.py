"""Fault tolerance & elasticity runtime (host-side control plane).

At 1000+ nodes the failure model is: hosts die, hosts straggle, and the
job must (a) never lose more than checkpoint_interval steps, (b) detect and
route around stragglers, (c) restart on a DIFFERENT device count without
manual intervention. The pieces:

``StepGuard``     — wraps the train step with retry-on-transient-failure and
                    wall-time watchdog; classifies exceptions (preemption vs
                    poison step) so a deterministic NaN doesn't retry forever.
``Heartbeat``     — per-host step-time EMA; quorum straggler detection (a
                    host slower than median * threshold for N consecutive
                    steps is flagged for eviction — on real fleets this feeds
                    the cluster scheduler; here it feeds logs + the elastic
                    re-mesh hook).
``elastic_mesh``  — mesh shapes as a function of the LIVE host count:
                    checkpoint save/restore is mesh-independent
                    (repro.checkpoint), so recovery is: detect -> rebuild
                    mesh from survivors -> restore -> continue.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import jax


class PoisonStep(Exception):
    """Deterministic failure (NaN loss, assertion) — do NOT retry."""


@dataclass
class StepGuard:
    max_retries: int = 3
    backoff_s: float = 1.0

    def run(self, step_fn, *args):
        """Run step_fn; retry transient failures with backoff; re-raise
        deterministic poison immediately."""
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                out = step_fn(*args)
                return out
            except PoisonStep:
                raise
            except (jax.errors.JaxRuntimeError, RuntimeError, OSError) as e:
                last = e
                if attempt < self.max_retries:
                    time.sleep(self.backoff_s * (2 ** attempt))
        raise RuntimeError(
            f"step failed after {self.max_retries + 1} attempts") from last


@dataclass
class Heartbeat:
    """Step-time tracking + straggler flagging (host-local view of the
    fleet; on multi-host deployments the timings are all-gathered through
    the coordination service once per interval)."""
    threshold: float = 1.5
    patience: int = 5
    ema_alpha: float = 0.2
    _ema: dict = field(default_factory=dict)
    _strikes: dict = field(default_factory=dict)

    def record(self, host_id: int, step_time_s: float) -> None:
        prev = self._ema.get(host_id, step_time_s)
        self._ema[host_id] = (1 - self.ema_alpha) * prev \
            + self.ema_alpha * step_time_s

    def stragglers(self) -> list[int]:
        if len(self._ema) < 2:
            return []
        times = sorted(self._ema.values())
        median = times[len(times) // 2]
        out = []
        for host, t in self._ema.items():
            if t > self.threshold * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.patience:
                    out.append(host)
            else:
                self._strikes[host] = 0
        return out


def elastic_mesh(n_devices: int, model_parallel: int = 16,
                 pod_size: int = 256):
    """Best mesh for the LIVE device count (survivor set after failures).

    Keeps TP fixed (=16: weights are sharded that way and resharding TP is
    the expensive path) and absorbs device loss in the data/pod axes —
    standard elastic-DP. n_devices must be a multiple of model_parallel."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"TP={model_parallel}")
    rest = n_devices // model_parallel
    if n_devices > pod_size and rest % (pod_size // model_parallel) == 0:
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((rest, model_parallel), ("data", "model"))


def scaled_global_batch(base_batch: int, base_hosts: int,
                        live_hosts: int, keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-host batch grows) or
    scale it with the fleet (exact per-host batch, LR rescaled by caller)."""
    if keep_global:
        per = math.ceil(base_batch / live_hosts)
        return per * live_hosts
    return (base_batch // base_hosts) * live_hosts
