"""Fault-tolerant async serving runtime around :class:`WmdEngine` (ISSUE 6).

``launch/serve.py`` was a one-shot CLI: any ``LamUnderflowError``, device
hiccup, or straggler killed the whole process and nothing bounded latency
under load. This module is the long-lived front-end the ROADMAP's "real
serving front-end" item asks for:

``ServingRuntime``
    asyncio request queue + micro-batch coalescer. Incoming requests are
    grouped by the engine's existing pow2 ``v_r`` buckets
    (:func:`repro.core.index.bucket_size` — one dispatch is one solver
    chunk shape, so coalescing never widens an executable) and a bucket
    dispatches under the DEADLINE-OR-FULL rule: as soon as it holds
    ``max_batch`` requests, or when its oldest member has waited
    ``window_s``. Dispatches run on a single worker thread (one device,
    serialized), so the event loop keeps admitting and coalescing while
    the solver runs.

Admission control & backpressure
    The queue is bounded (``max_queue`` counts queued + coalescing +
    in-flight). An arrival over the bound gets an immediate structured
    ``rejected_overload`` response (with a ``retry_after_s`` hint) — the
    only case that is ever *refused*. Under pressure the runtime DEGRADES
    instead of dropping: the dispatch tier falls back queue-depth-wise
    (``degrade_depth`` watermarks) and deadline-wise (a batch whose
    tightest remaining budget cannot afford a tier's measured service-time
    EMA falls to the next tier; a blown deadline serves the cheapest tier
    rather than nothing). Every response is tagged with the tier that
    served it and that tier's measured-recall caveat.

Degradation ladder (cheapest-last)
    1. ``exact``          — full cascade, ``nprobe = all``: exact top-k.
    2. ``reduced_nprobe`` — same cascade, fewer probed clusters:
       approximate, recall measured monotone in nprobe (fig9). Exists
       only when the engine's prune spec is an IVF cascade.
    3. ``refine``         — rank-then-refine (``mode="refine"``): rank
       every candidate by the cascade's tightest lower bound, Sinkhorn
       -solve only each query's top ``refine_factor * k`` picks.
       Distances returned for the reported top-k ARE exact truncated
       -Sinkhorn scores; only membership is approximate, with recall
       measured monotone in ``refine_factor`` (fig13).
    4. ``rwmd``           — rank by the already-computed RWMD lower bound
       with NO Sinkhorn solve (LC-RWMD, Atasu et al. arXiv 1711.07227:
       the relaxed bound is a usable *score*, not just a prune): one
       min-cdist + O(nnz) gather per chunk, returns bound values as
       distances.

Cross-request K-column cache (ISSUE 10)
    Serving traffic is Zipfian over the vocabulary, so the runtime
    enables the engine's cross-request cdist-row cache by default
    (``ServeConfig.kcache_slots``; ``core/kcache.py``): hot query words'
    ``(V,)`` corpus-distance rows stay device-resident across dispatches
    and each staged chunk recomputes only its missing rows. Results are
    bit-exact against the uncached path; hit/miss/eviction counters land
    in :meth:`ServingRuntime.stats` and each response carries its own
    dispatch's hit/miss delta (``ServeResponse.kcache``).

Fault tolerance
    Each dispatch runs under a
    :class:`~repro.runtime.fault_tolerance.DispatchGuard`: transient
    failures (``JaxRuntimeError``/``RuntimeError``/``OSError``) retry
    with jittered exponential backoff; a wall-clock watchdog counts
    straggler dispatches; DETERMINISTIC failures (``LamUnderflowError``,
    ``PoisonStep``) trigger per-request isolation — the batch re-solves
    one request at a time, poisoned requests get a structured error
    response (underflow diagnostics attached) and their batchmates still
    get answers. Retries exhausted => structured ``retries_exhausted``
    errors, never an unhandled exception: every submitted request's
    future resolves to a :class:`ServeResponse`.

Shard-level fault tolerance (ISSUE 9)
    A sharded engine additionally degrades ACROSS SHARDS: its fan-out is
    deadline-bounded and circuit-broken
    (:class:`~repro.core.shard_index.ShardedWmdEngine`), and when a shard
    misses its deadline or is open-circuited the dispatch still answers —
    a PARTIAL result over the responding shards, tagged on the response
    with ``partial``/``coverage``/``missing_shards``, its caveat extended
    with the covered fraction, and ``exact`` forced ``False`` (an
    exact-mode response must never silently claim exactness when
    coverage < 1). Only an all-shards failure becomes a structured
    ``shard_failed`` error. :meth:`ServingRuntime.request_shutdown`
    drains gracefully on SIGTERM/SIGINT: admitted requests resolve, the
    rest get structured ``shutting_down`` rejections.

``FaultInjector``
    Seeded, deterministic chaos hooks so the degradation/retry paths are
    *tested*, not just written: stage latency, transient dispatch faults,
    and per-request poison, each an order-independent pure function of
    ``(seed, site)`` (counter-based RNG streams, same construction as the
    data pipeline's restart-exact batches) so a chaos run replays
    identically from its seed.

Typical use::

    runtime = ServingRuntime(engine, ServeConfig(max_batch=8,
                                                 window_s=0.01))
    responses, stats = run_open_loop(runtime, queries,
                                     arrivals_s=poisson_arrivals(...))

or inside an event loop::

    await runtime.start()
    fut = runtime.submit(query, k=10, deadline_s=0.25)
    resp = await fut          # always resolves; resp.ok or resp.error
    await runtime.stop()

The ladder an engine gets by default (runnable — the CI ``docs`` job
executes this as a doctest)::

    >>> from repro.core import WmdEngine, build_index
    >>> from repro.data.corpus import make_corpus
    >>> from repro.runtime.serving import default_tiers
    >>> c = make_corpus(vocab_size=64, embed_dim=8, n_docs=12,
    ...                 n_queries=1, words_per_doc=(3, 8), seed=0)
    >>> eng = WmdEngine(build_index(c.docs, c.vecs, n_clusters=4),
    ...                 lam=2.0, n_iter=8)
    >>> [t.name for t in default_tiers(eng, "ivf+wcd+rwmd")]
    ['exact', 'reduced_nprobe', 'refine', 'rwmd']
    >>> [t.name for t in default_tiers(eng, "rwmd")]  # no nprobe knob
    ['exact', 'refine', 'rwmd']
"""
from __future__ import annotations

import asyncio
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import NamedTuple, Sequence

import numpy as np
import jax

from repro.core.index import WmdEngine, bucket_size
from repro.core.shard_index import ShardSearchError
from repro.core.sinkhorn import LamUnderflowError
from repro.runtime.fault_tolerance import (DispatchFailed, DispatchGuard,
                                           Heartbeat, PoisonStep)


class PoisonRequest(PoisonStep):
    """Deterministic per-request failure (injected or diagnosed): the
    request must be structured-errored, never retried."""

    def __init__(self, rid: int, message: str):
        super().__init__(message)
        self.rid = rid


# ----------------------------------------------------------------- tiers
def _ivf_cluster_count(engine) -> int | None:
    """IVF cluster count backing the nprobe ladder — the engine's own
    count, or the SMALLEST per-shard count for a sharded engine (nprobe
    clamps per shard, so sizing against the minimum keeps the reduced
    tier a genuine reduction on every shard). None when un-clustered."""
    counts = getattr(engine, "cluster_counts", None)
    if counts:
        return int(min(counts))
    clusters = getattr(getattr(engine, "index", None), "clusters", None)
    return None if clusters is None else int(clusters.n_clusters)


class Tier(NamedTuple):
    """One rung of the degradation ladder."""

    name: str
    nprobe: int | None   # None = all probed clusters (exact cascade)
    solve: bool          # False: rank by the RWMD bound, no Sinkhorn
    caveat: str          # recall semantics, attached to every response
    mode: str = "exact"  # engine search mode ("exact" | "refine")
    refine_factor: int | None = None  # solve budget multiple (refine)


def default_tiers(engine: WmdEngine, prune: str,
                  nprobe: int | None = None,
                  nprobe_degraded: int | None = None,
                  refine_factor: int = 4) -> tuple[Tier, ...]:
    """The exact -> reduced-nprobe -> refine -> rwmd ladder for this
    engine/prune.

    ``nprobe`` is the TOP tier's probe count (``None`` = all = exact — a
    caller already serving approximate retrieval starts the ladder
    there); ``nprobe_degraded`` defaults to a quarter of it. Non-IVF
    prune specs have no nprobe knob, so their ladder is
    exact -> refine -> rwmd. ``refine_factor`` sizes the refine tier's
    solve budget (``refine_factor * k`` Sinkhorn-solved candidates per
    query).

    Works for both the single-device :class:`WmdEngine` and the sharded
    engine (``nprobe`` applies PER SHARD there; the reduced tier's probe
    count is sized against the smallest shard's cluster count so every
    shard's clamp leaves a real reduction).
    """
    per_shard = getattr(engine, "n_shards", 1) > 1
    tiers = [Tier(
        "exact", nprobe, True,
        "exact top-k" if nprobe is None else
        f"approximate: probes {nprobe} IVF clusters per query"
        + (" per shard" if per_shard else "") + "; recall "
        "measured monotone in nprobe (fig9)")]
    is_ivf = isinstance(prune, str) and prune.startswith("ivf") \
        and _ivf_cluster_count(engine) is not None
    if is_ivf:
        c = _ivf_cluster_count(engine)
        top = nprobe if nprobe is not None else c
        red = nprobe_degraded if nprobe_degraded is not None \
            else max(1, top // 4)
        if red < top:
            tiers.append(Tier(
                "reduced_nprobe", red, True,
                f"degraded: probes {red}/{c} IVF clusters per query"
                + (" per shard" if per_shard else "") + " — "
                "approximate top-k, recall monotone in nprobe (fig9); "
                "un-probed clusters are unreachable"))
    rf = max(1, int(refine_factor))
    tiers.append(Tier(
        "refine", nprobe, True,
        f"degraded: rank-then-refine — candidates ranked by the "
        f"cascade's lower bound, only the top {rf}*k Sinkhorn-solved "
        "per query; reported distances are exact truncated-Sinkhorn "
        "scores but membership is approximate, recall measured "
        "monotone in refine_factor (fig13)",
        mode="refine", refine_factor=rf))
    tiers.append(Tier(
        "rwmd", None, False,
        "degraded: ranked by the LC-RWMD lower bound, no Sinkhorn solve "
        "— ordering approximates the exact WMD ranking and reported "
        "distances are admissible lower bounds, not WMD values"))
    return tuple(tiers)


# -------------------------------------------------------------- requests
@dataclass
class ServeRequest:
    rid: int
    query: np.ndarray
    k: int
    deadline: float | None        # absolute time.monotonic() budget
    enqueue_t: float
    v_r: int
    future: asyncio.Future = None


@dataclass
class ServeResponse:
    """One request's terminal state — a result (tagged with its serving
    tier + recall caveat) or a structured error; never an exception."""

    rid: int
    ok: bool
    tier: str | None = None
    exact: bool = False
    caveat: str | None = None
    indices: list | None = None
    distances: list | None = None
    error: dict | None = None     # {"code", "message", ["diagnostics"]}
    queue_ms: float = 0.0
    service_ms: float = 0.0
    batch_size: int = 0
    dispatch_id: int = -1
    attempts: int = 1
    deadline_missed: bool = False
    straggler: bool = False       # dispatch tripped the watchdog
    solve_iters: dict | None = None   # per-stage mean realized iterations
    iter_stats_dropped: int = 0   # engine ring discards, cumulative
    partial: bool = False         # a shard missed: result covers < 100%
    coverage: float | None = None     # covered corpus fraction if partial
    missing_shards: list | None = None  # shard ids absent from the merge
    kcache: dict | None = None    # this dispatch's cdist-row cache hits/
    #                               misses/hit_rate (ISSUE 10), when the
    #                               engine carries a cache

    def to_json(self) -> dict:
        d = {"rid": self.rid, "ok": self.ok, "tier": self.tier,
             "exact": self.exact, "queue_ms": round(self.queue_ms, 3),
             "service_ms": round(self.service_ms, 3),
             "batch_size": self.batch_size,
             "deadline_missed": self.deadline_missed}
        if self.ok:
            d["indices"] = self.indices
            d["distances"] = self.distances
            d["caveat"] = self.caveat
            if self.solve_iters:
                d["solve_iters"] = self.solve_iters
        else:
            d["error"] = self.error
        if self.straggler:
            d["straggler"] = True
        if self.iter_stats_dropped:
            d["iter_stats_dropped"] = self.iter_stats_dropped
        if self.partial:
            d["partial"] = True
            d["coverage"] = self.coverage
            d["missing_shards"] = self.missing_shards
        if self.kcache is not None:
            d["kcache"] = self.kcache
        return d


def _error_response(req: ServeRequest, code: str, message: str,
                    diagnostics: str | None = None, **kw) -> ServeResponse:
    err = {"code": code, "message": message}
    if diagnostics:
        err["diagnostics"] = diagnostics
    return ServeResponse(rid=req.rid, ok=False, error=err, **kw)


def _validate_query(q: np.ndarray) -> str | None:
    """Admission-time shape/dtype/finiteness check (ISSUE 10 bugfix):
    the reason string for a structured ``invalid_query`` rejection, or
    ``None`` for a well-formed query. Runs BEFORE the request can reach
    the worker thread — a NaN histogram must not burn a dispatch and
    trip the poison-isolation path for its batchmates."""
    if q.dtype == object or not (np.issubdtype(q.dtype, np.number)
                                 or q.dtype == np.bool_):
        return (f"query must be a numeric histogram over the "
                f"vocabulary, got dtype {q.dtype}")
    if q.ndim != 1:
        return (f"query must be a 1-D vocabulary histogram, got shape "
                f"{q.shape}")
    if not np.isfinite(q).all():
        return ("query weights must be finite: NaN/Inf in the "
                "histogram (WMD marginals are undefined)")
    return None


# -------------------------------------------------------- fault injection
def _unit_draw(seed: int, *site: int) -> float:
    """Deterministic U[0,1) as a pure function of (seed, site) — counter
    -mode, so injection decisions are independent of call ORDER and a
    chaos run replays identically from its seed."""
    return float(np.random.default_rng((seed,) + tuple(site)).random())


class InjectedFault(RuntimeError):
    """Injected transient dispatch failure (classified retryable)."""


class ShardCrashed(RuntimeError):
    """Injected shard crash: the shard 'process' is down, so EVERY
    attempt against it fails (a RuntimeError, so the shard-level retry
    loop burns its budget and the circuit opens) until the injector's
    :meth:`FaultInjector.revive_shard` ends the outage — the chaos
    drill's stand-in for kill + snapshot-restore."""


@dataclass
class FaultInjector:
    """Seeded, deterministic chaos hooks for the serving runtime.

    ``before_attempt(dispatch_id, attempt)`` runs INSIDE the guarded
    dispatch region: with probability ``latency_rate`` it sleeps
    ``latency_s`` (stage latency / straggler injection — trips the
    watchdog when it exceeds it), and with probability
    ``transient_rate`` it raises :class:`InjectedFault` on attempts
    below ``transient_attempts`` (default 1: only the first attempt can
    fault, so the retry path is exercised and recovers; raise it toward
    ``max_retries + 1`` to exercise retry exhaustion). ``poison(rid)``
    deterministically marks requests as poison — the dispatch raises
    :class:`PoisonRequest` for them, driving the per-request isolation
    path. All decisions are pure functions of ``(seed, site)``; ``trace``
    records them for the replay-determinism test.

    Shard-granular sites (ISSUE 9): ``before_shard_attempt(shard, seq,
    attempt)`` runs inside the sharded engine's per-shard retry region
    (wired automatically by :class:`ServingRuntime` when the engine
    exposes ``shard_fault_hook``) — shard latency/hang (site 4; sized
    above the shard timeout it becomes a hang that the fan-out deadline
    converts to a ``"timeout"`` exclusion), shard transients (site 5),
    and a deterministic CRASH WINDOW: ``crash_shard`` fails every
    attempt from fan-out ``crash_after`` for ``crash_for`` fan-outs
    (``0`` = until :meth:`revive_shard`). The crash is keyed on the
    engine's fan-out sequence counter, so "kill shard 1 two dispatches
    in" replays exactly.
    """

    latency_rate: float = 0.0
    latency_s: float = 0.05
    transient_rate: float = 0.0
    transient_attempts: int = 1
    poison_rate: float = 0.0
    shard_latency_rate: float = 0.0
    shard_latency_s: float = 0.05
    shard_transient_rate: float = 0.0
    shard_transient_attempts: int = 1
    crash_shard: int = -1         # shard id to crash (-1 = none)
    crash_after: int = 0          # fan-out sequence where the crash begins
    crash_for: int = 0            # crashed fan-outs (0 = until revive)
    seed: int = 0
    trace: list = field(default_factory=list)

    def poison(self, rid: int) -> bool:
        if self.poison_rate <= 0:
            return False
        hit = _unit_draw(self.seed, 3, rid) < self.poison_rate
        if hit:
            self.trace.append(("poison", rid))
        return hit

    def before_attempt(self, dispatch_id: int, attempt: int) -> None:
        if self.latency_rate > 0 and \
                _unit_draw(self.seed, 1, dispatch_id, attempt) \
                < self.latency_rate:
            self.trace.append(("latency", dispatch_id, attempt))
            time.sleep(self.latency_s)
        if self.transient_rate > 0 and attempt < self.transient_attempts \
                and _unit_draw(self.seed, 2, dispatch_id, attempt) \
                < self.transient_rate:
            self.trace.append(("transient", dispatch_id, attempt))
            raise InjectedFault(
                f"injected transient fault (dispatch {dispatch_id} "
                f"attempt {attempt})")

    def before_shard_attempt(self, shard: int, seq: int,
                             attempt: int) -> None:
        """Shard-granular chaos entry point (see class docstring); runs
        on the shard's fan-out worker thread, inside its retry loop."""
        if shard == self.crash_shard and seq >= self.crash_after and (
                self.crash_for <= 0
                or seq < self.crash_after + self.crash_for):
            self.trace.append(("shard_crash", shard, seq, attempt))
            raise ShardCrashed(
                f"injected crash: shard {shard} is down "
                f"(fan-out {seq} attempt {attempt})")
        if self.shard_latency_rate > 0 and \
                _unit_draw(self.seed, 4, shard, seq, attempt) \
                < self.shard_latency_rate:
            self.trace.append(("shard_latency", shard, seq, attempt))
            time.sleep(self.shard_latency_s)
        if self.shard_transient_rate > 0 \
                and attempt < self.shard_transient_attempts \
                and _unit_draw(self.seed, 5, shard, seq, attempt) \
                < self.shard_transient_rate:
            self.trace.append(("shard_transient", shard, seq, attempt))
            raise InjectedFault(
                f"injected shard transient (shard {shard} "
                f"fan-out {seq} attempt {attempt})")

    def revive_shard(self) -> None:
        """End the crash window — the drill's 'shard host came back'
        moment (snapshot restore then rejoins it to the mesh)."""
        if self.crash_shard >= 0:
            self.trace.append(("shard_revive", self.crash_shard))
        self.crash_shard = -1


# ----------------------------------------------------------- degraded tier
def rwmd_topk(engine: WmdEngine, queries: Sequence, k: int):
    """LC-RWMD scoring tier: rank every doc by the doc-side relaxed-WMD
    lower bound, NO Sinkhorn solve — the cheapest rung of the ladder.

    Reuses the engine's staging (pow2 v_r buckets) and the full-sweep
    :class:`~repro.core.prune.RwmdPruner`; one min-cdist dispatch +
    O(nnz) gather per chunk. Returns caller-order ``(indices, bounds)``
    arrays shaped like :meth:`WmdEngine.search` output; empty queries get
    ``-1`` / NaN rows. The bound is admissible w.r.t. the computed
    Sinkhorn score (see ``core/prune.py``), so reported values never
    exceed the distance the exact tiers would have returned.

    A sharded engine ranks per shard and merges through its single
    top-k collective — delegate so the ladder's cheapest rung stays one
    collective too.
    """
    from repro.core.prune import RwmdPruner
    if hasattr(engine, "rwmd_topk"):
        return engine.rwmd_topk(queries, k)
    queries = [np.asarray(q) for q in queries]
    n = engine.index.n_docs
    k = min(int(k), n)
    out_i = np.full((len(queries), k), -1, np.int32)
    out_d = np.full((len(queries), k), np.nan, engine.dtype)
    if not queries or n == 0 or k == 0:
        return out_i, out_d
    pruner = RwmdPruner(use_kernel=(engine.impl == "kernel"),
                        interpret=engine.interpret)
    _, chunks = engine._plan(queries)
    for chunk, width in chunks:
        sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk],
                                          width)
        lb = pruner.lower_bounds(engine.index, sup, r, mask)
        neg, pos = jax.lax.top_k(-lb[:len(chunk)], k)
        pos = np.asarray(pos)
        d = -np.asarray(neg)
        ext = engine._ext(pos.reshape(-1)).reshape(pos.shape)
        for ci, qi in enumerate(chunk):
            out_i[qi], out_d[qi] = ext[ci], d[ci]
    return out_i, out_d


# --------------------------------------------------------------- runtime
@dataclass
class ServeConfig:
    max_batch: int = 8            # full-dispatch trigger per v_r bucket
    window_s: float = 0.01        # deadline-dispatch trigger (oldest wait)
    max_queue: int = 64           # admission bound: queued + in flight
    deadline_s: float | None = 0.5   # default per-request budget
    degrade_depth: tuple = (0.5, 0.75, 0.9)  # queue-depth watermarks
    #                         (fracs of max_queue) for tiers 1, 2, ...
    prune: str = "ivf+wcd+rwmd"   # solve tiers' prune spec
    nprobe: int | None = None     # top tier (None = all = exact)
    nprobe_degraded: int | None = None  # tier-1 probe count (default /4)
    refine_factor: int = 4        # refine tier's solve budget multiple
    max_retries: int = 2
    backoff_s: float = 0.02
    jitter: float = 0.25
    watchdog_s: float = 5.0
    seed: int = 0
    ema_alpha: float = 0.3        # per-tier service-time EMA smoothing
    kcache_slots: int = 512       # cross-request cdist-row cache (ISSUE
    #                               10), enabled by default in serving —
    #                               Zipfian traffic is where the reuse
    #                               lives; 0 disables. Bit-exact either
    #                               way (core/kcache.py); a no-op when
    #                               the engine already carries a cache
    #                               or its impl can't host one


class ServingRuntime:
    """Long-lived async serving front-end over one :class:`WmdEngine`.

    Owns the engine's iteration-stats ring (it is reset per dispatch for
    per-request attribution); dispatches are serialized on one worker
    thread (one device). See the module docstring for the full contract;
    the invariant that matters: EVERY admitted request's future resolves
    to a :class:`ServeResponse` — results and errors are data, only
    runtime bugs raise.
    """

    def __init__(self, engine: WmdEngine, config: ServeConfig | None = None,
                 injector: FaultInjector | None = None,
                 tiers: Sequence[Tier] | None = None):
        self.engine = engine
        self.cfg = config or ServeConfig()
        self.injector = injector
        self.tiers = tuple(tiers) if tiers is not None else default_tiers(
            engine, self.cfg.prune, self.cfg.nprobe,
            self.cfg.nprobe_degraded, self.cfg.refine_factor)
        self.guard = DispatchGuard(
            max_retries=self.cfg.max_retries, backoff_s=self.cfg.backoff_s,
            jitter=self.cfg.jitter, seed=self.cfg.seed,
            watchdog_s=self.cfg.watchdog_s,
            before_attempt=(injector.before_attempt if injector else None))
        self._ema = Heartbeat(ema_alpha=self.cfg.ema_alpha)
        self._queue: asyncio.Queue | None = None
        self._pool: ThreadPoolExecutor | None = None
        self._coalescer: asyncio.Task | None = None
        self._tasks: set = set()
        self._depth = 0               # queued + coalescing + in flight
        self._next_rid = 0
        self._next_dispatch = 0
        self._iters_dropped = 0       # engine ring discards, accumulated
        self._closing = False         # graceful-drain flag (ISSUE 9)
        self.counters = {
            "submitted": 0, "rejected": 0, "invalid_query": 0,
            "dispatches": 0, "errors": 0,
            "isolations": 0, "deadline_missed": 0, "partial": 0,
            "shutdown_rejected": 0,
            "tiers": {t.name: 0 for t in self.tiers}}
        # wire the injector's shard-granular sites into a sharded
        # engine's fan-out (duck-typed: any engine exposing the hook)
        if injector is not None \
                and getattr(engine, "shard_fault_hook", ...) is None:
            engine.shard_fault_hook = injector.before_shard_attempt
        # cross-request K-column cache (ISSUE 10): serving is where the
        # Zipfian word reuse lives, so the runtime enables it by default
        # on any engine that can host one and doesn't already
        if self.cfg.kcache_slots > 0 \
                and getattr(engine, "kcache_stats", lambda: None)() is None:
            enable = getattr(engine, "enable_kcache", None)
            if enable is not None:
                enable(self.cfg.kcache_slots)

    # ------------------------------------------------------------ control
    async def start(self) -> None:
        assert self._coalescer is None, "runtime already started"
        self._queue = asyncio.Queue()
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="wmd-dispatch")
        self._coalescer = asyncio.create_task(self._coalesce_loop())

    async def stop(self) -> None:
        """Graceful shutdown: flush the coalescer, wait for in-flight
        dispatches, then tear down the worker."""
        if self._coalescer is None:
            return
        self._queue.put_nowait(None)          # flush sentinel
        await self._coalescer
        if self._tasks:     # coalescer launches before returning: snapshot
            await asyncio.gather(*list(self._tasks))
        self._pool.shutdown(wait=True)
        self._coalescer = None

    def request_shutdown(self) -> None:
        """Begin a graceful drain (SIGTERM/SIGINT handler): everything
        already admitted still coalesces, dispatches, and resolves;
        every LATER :meth:`submit` gets an immediate structured
        ``shutting_down`` rejection instead of being admitted.
        Synchronous and idempotent — safe to install directly as an
        asyncio signal handler. The actual teardown stays with
        :meth:`stop` (the driver calls it after the drained futures
        resolve and then emits the final stats JSON)."""
        self._closing = True

    @property
    def closing(self) -> bool:
        return self._closing

    # ------------------------------------------------------------- submit
    def submit(self, query, k: int = 10,
               deadline_s: float | None = ...) -> asyncio.Future:
        """Admit one request; returns a future resolving to a
        :class:`ServeResponse`. Admission control runs HERE: a full queue
        rejects immediately with a structured ``rejected_overload``
        response (backpressure — the caller should retry after
        ``retry_after_s``); an empty query is a structured
        ``empty_query`` error (deterministic, never dispatched).

        Exactness contract: the response's ``tier``/``exact``/``caveat``
        fields say what was served. Only the ``exact`` tier guarantees
        exact top-k; ``reduced_nprobe`` and ``refine`` return exact
        truncated-Sinkhorn distances over an approximate candidate set
        (recall measured in fig9 / fig13 respectively); ``rwmd`` returns
        admissible lower bounds, not WMD values.

        Failure modes — ``resp.ok == False`` with ``error["code"]`` one
        of (the future itself NEVER raises):

        - ``rejected_overload``: queue full, retry later (only refusal).
          ``error["retry_after_s"]`` is the backpressure hint — the
          measured service-time EMA of the tier the degradation
          watermarks would serve at the CURRENT depth (under sustained
          overload that is a degraded tier; tier 0's EMA would be stale).
        - ``shutting_down``: the runtime is draining after
          :meth:`request_shutdown`; already-admitted requests still
          resolve, this one was not admitted.
        - ``invalid_query``: the query is not a finite 1-D numeric
          histogram (NaN/Inf weights, wrong rank, non-numeric dtype) —
          rejected at admission, never dispatched, so it cannot burn a
          dispatch or trip the poison-isolation path for its batchmates.
        - ``empty_query``: query has no support; WMD is undefined.
        - ``lam_underflow``: deterministic per-request
          :class:`LamUnderflowError` — K = exp(-lam*M) underflowed for
          this query; lower ``lam`` or build the engine with
          ``precision="log"`` (diagnostics attached).
        - ``poison``: deterministic per-request failure pinned by the
          isolation path (batchmates still get answers).
        - ``retries_exhausted``: transient dispatch faults exceeded
          ``max_retries``.
        - ``shard_failed``: every responding shard of a sharded engine
          failed this dispatch (per-shard reasons in diagnostics). A
          PARTIAL shard failure is not an error: the response is served
          with ``partial=True``, its covered-fraction/missing-shard
          tags, and ``exact=False``.
        - ``internal``: anything else, as data rather than a crash."""
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        rid = self._next_rid
        self._next_rid += 1
        self.counters["submitted"] += 1
        now = time.monotonic()
        if deadline_s is ...:
            deadline_s = self.cfg.deadline_s
        # admission validation (ISSUE 10 bugfix): malformed queries must
        # be structured-rejected HERE — admitted, a NaN query burned a
        # dispatch and tripped per-request isolation (re-solving its
        # batchmates solo), and a ragged/2-D one died as `internal`
        try:
            q = np.asarray(query)
            invalid = _validate_query(q)
        except Exception as e:          # noqa: BLE001 — admission boundary
            q = np.zeros(0)
            invalid = f"query is not array-like: {type(e).__name__}: {e}"
        req = ServeRequest(
            rid=rid, query=q, k=int(k),
            deadline=None if deadline_s is None else now + deadline_s,
            enqueue_t=now,
            v_r=0 if invalid else int((q > 0).sum()), future=fut)
        if self._closing:
            self.counters["shutdown_rejected"] += 1
            fut.set_result(_error_response(
                req, "shutting_down",
                "runtime is draining for shutdown; request not admitted "
                "(already-admitted requests still resolve)"))
            return fut
        if invalid:
            self.counters["invalid_query"] += 1
            fut.set_result(_error_response(req, "invalid_query", invalid))
            return fut
        if req.v_r == 0:
            fut.set_result(_error_response(
                req, "empty_query",
                "query has no support (WMD undefined for an empty "
                "marginal)"))
            return fut
        if self._depth >= self.cfg.max_queue:
            self.counters["rejected"] += 1
            # backpressure hint (ISSUE 10 bugfix): estimate from the tier
            # the watermark logic would serve RIGHT NOW — at full depth
            # that is a degraded tier, and tier 0's EMA is stale or None
            # under sustained overload
            est = self._retry_after()
            resp = _error_response(
                req, "rejected_overload",
                f"queue full ({self.cfg.max_queue}); backpressure — "
                f"retry after ~{round(est + self.cfg.window_s, 4)}s")
            resp.error["retry_after_s"] = round(est + self.cfg.window_s, 4)
            fut.set_result(resp)
            return fut
        self._depth += 1
        self._queue.put_nowait(req)
        return fut

    # --------------------------------------------------------- coalescing
    async def _coalesce_loop(self) -> None:
        """Deadline-or-full micro-batching, grouped by pow2 v_r bucket.

        A bucket dispatches the moment it holds ``max_batch`` requests
        (FULL — the solver chunk is filled) or when its OLDEST member has
        waited ``window_s`` (DEADLINE — latency is bounded even at low
        offered load). Distinct buckets never share a dispatch: one
        dispatch is one compiled chunk shape."""
        pending: dict[int, list[ServeRequest]] = {}
        flush = False
        while True:
            timeout = None
            if pending:
                now = time.monotonic()
                timeout = max(0.0, min(
                    reqs[0].enqueue_t + self.cfg.window_s - now
                    for reqs in pending.values()))
            try:
                req = await asyncio.wait_for(self._queue.get(), timeout)
                if req is None:
                    flush = True
                else:
                    b = bucket_size(req.v_r, self.engine.min_bucket)
                    pending.setdefault(b, []).append(req)
                    if len(pending[b]) >= self.cfg.max_batch:
                        self._launch(pending.pop(b))
            except asyncio.TimeoutError:
                pass
            now = time.monotonic()
            for b in list(pending):
                if flush or (pending[b][0].enqueue_t + self.cfg.window_s
                             <= now):
                    self._launch(pending.pop(b))
            if flush and not pending:
                return

    def _launch(self, batch: list[ServeRequest]) -> None:
        tier_i = self._choose_tier(batch, time.monotonic())
        task = asyncio.create_task(self._run_dispatch(batch, tier_i))
        self._tasks.add(task)
        task.add_done_callback(self._tasks.discard)

    # ------------------------------------------------------ tier selection
    def _choose_tier(self, batch: list[ServeRequest], now: float) -> int:
        """Degrade-don't-drop policy, applied per coalesced dispatch:

        - queue depth over a ``degrade_depth`` watermark forces at least
          that many rungs down (load shedding into cheaper tiers);
        - the batch's TIGHTEST remaining deadline budget must afford the
          chosen tier's measured service-time EMA, else fall further;
        - an already-blown budget serves the cheapest tier: a degraded
          answer now beats an exact answer nobody is waiting for.
        """
        last = len(self.tiers) - 1
        tier = self._depth_tier()
        budgets = [r.deadline - now for r in batch
                   if r.deadline is not None]
        if budgets:
            b = min(budgets)
            if b <= 0:
                return last
            while tier < last:
                est = self._ema.ema(tier)
                if est is None or est <= b:
                    break
                tier += 1
        return tier

    def _depth_tier(self) -> int:
        """Tier the queue-depth watermarks force at the CURRENT depth —
        the load-shedding half of :meth:`_choose_tier`, shared with the
        backpressure hint so both report the same ladder position."""
        last = len(self.tiers) - 1
        tier = 0
        for i, frac in enumerate(self.cfg.degrade_depth, start=1):
            if self._depth >= frac * self.cfg.max_queue:
                tier = min(i, last)
        return tier

    def _retry_after(self) -> float:
        """Backpressure hint: the service-time EMA of the tier the
        watermark logic would serve right now, falling back across the
        ladder (cheaper tiers first — under overload those are the ones
        actually being exercised, so their EMAs are fresh) and then back
        up toward exact; 0 before any dispatch has been measured."""
        t = self._depth_tier()
        for i in list(range(t, len(self.tiers))) + list(range(t - 1, -1, -1)):
            est = self._ema.ema(i)
            if est is not None:
                return est
        return 0.0

    # ----------------------------------------------------------- dispatch
    async def _run_dispatch(self, batch: list[ServeRequest],
                            tier_i: int) -> None:
        loop = asyncio.get_running_loop()
        results = await loop.run_in_executor(
            self._pool, self._dispatch, batch, tier_i)
        for req in batch:
            resp = results[req.rid]
            self.counters["errors"] += 0 if resp.ok else 1
            if resp.deadline_missed:
                self.counters["deadline_missed"] += 1
            if resp.ok:
                self.counters["tiers"][resp.tier] += 1
                if resp.partial:
                    self.counters["partial"] += 1
            self._depth -= 1
            if not req.future.done():
                req.future.set_result(resp)

    def _dispatch(self, batch: list[ServeRequest], tier_i: int) -> dict:
        """Worker-thread body: guarded solve with per-request isolation.

        Never raises — every request maps to a response. The first
        deterministic failure (injected poison, lam underflow) switches
        to one-request-at-a-time isolation so the poison is pinned to its
        request and batchmates still get answers; transient failures
        retry inside the guard and exhaust into structured errors."""
        did = self._next_dispatch
        self._next_dispatch += 1
        self.counters["dispatches"] += 1
        t0 = time.monotonic()
        trips0 = self.guard.watchdog_trips
        try:
            results = self._guarded_solve(batch, tier_i, did)
        except (PoisonStep, FloatingPointError):
            self.counters["isolations"] += 1
            results = {}
            for req in batch:
                try:
                    results.update(self._guarded_solve([req], tier_i, did))
                except Exception as e:          # noqa: BLE001 — boundary
                    results[req.rid] = self._classify_error(req, e)
        except Exception as e:                  # noqa: BLE001 — boundary
            results = {req.rid: self._classify_error(req, e)
                       for req in batch}
        dt = time.monotonic() - t0
        if any(results[r.rid].ok for r in batch):
            self._ema.record(tier_i, dt)
        straggler = self.guard.watchdog_trips > trips0
        now = time.monotonic()
        for req in batch:
            resp = results[req.rid]
            resp.queue_ms = (t0 - req.enqueue_t) * 1e3
            resp.service_ms = dt * 1e3
            resp.batch_size = len(batch)
            resp.dispatch_id = did
            resp.straggler = straggler
            resp.deadline_missed = (req.deadline is not None
                                    and now > req.deadline)
            resp.iter_stats_dropped = self._iters_dropped
        return results

    def _guarded_solve(self, reqs: list[ServeRequest], tier_i: int,
                       did: int) -> dict:
        tier = self.tiers[tier_i]

        def body():
            if self.injector is not None:
                for req in reqs:
                    if self.injector.poison(req.rid):
                        raise PoisonRequest(
                            req.rid, f"injected poison request "
                            f"(rid {req.rid})")
            return self._score(reqs, tier)

        try:
            return self.guard.run(body, tag=did)
        except PoisonRequest as e:
            if len(reqs) == 1:          # isolated: pin it to the request
                return {reqs[0].rid: _error_response(
                    reqs[0], "poison", str(e))}
            raise                        # batch path: isolate upstream

    def _classify_error(self, req: ServeRequest, e: Exception) \
            -> ServeResponse:
        """Exception -> structured error response (the server's last
        line: anything reaching here is data, not a crash)."""
        if isinstance(e, LamUnderflowError):
            return _error_response(
                req, "lam_underflow",
                "deterministic per-request failure: K = exp(-lam*M) "
                "underflowed for this query's support; lower lam or use "
                "precision='log'", diagnostics=str(e))
        if isinstance(e, PoisonStep):
            return _error_response(req, "poison", str(e))
        if isinstance(e, DispatchFailed):
            return _error_response(req, "retries_exhausted", str(e))
        if isinstance(e, ShardSearchError):
            return _error_response(
                req, "shard_failed",
                "sharded fan-out failed on every responding shard "
                "(shard-level retries already exhausted; not retried "
                "upstream)", diagnostics=str(e))
        return _error_response(req, "internal",
                               f"{type(e).__name__}: {e}")

    def _score(self, reqs: list[ServeRequest], tier: Tier) -> dict:
        """One engine call for a coalesced batch at one tier; slices the
        per-request rows out and attaches per-dispatch observability
        (realized solve iterations by stage, ring-drop counter)."""
        queries = [r.query for r in reqs]
        kmax = max(r.k for r in reqs)
        self._iters_dropped += self.engine.iter_stats_dropped
        self.engine.reset_iter_stats()    # per-dispatch attribution
        kc0 = getattr(self.engine, "kcache_stats", lambda: None)()
        if tier.solve:
            kw = {}
            if tier.mode != "exact":
                kw = {"mode": tier.mode,
                      "refine_factor": tier.refine_factor or 4}
            res = self.engine.search(queries, kmax, prune=self.cfg.prune,
                                     nprobe=tier.nprobe, **kw)
            indices, dists = res.indices, res.distances
        else:
            indices, dists = rwmd_topk(self.engine, queries, kmax)
        # coverage accounting (ISSUE 9): a sharded engine reports how
        # much of the corpus this call actually touched. Race-free read:
        # dispatches are serialized on ONE worker thread, so the
        # attribute handoff pairs with the search that just ran.
        cov = getattr(self.engine, "last_coverage", None)
        partial = bool(cov is not None and cov.missing_shards)
        caveat = tier.caveat
        if partial:
            detail = ", ".join(f"{s}: {r}" for s, r
                               in sorted(cov.reasons.items()))
            caveat = (
                f"{caveat}; PARTIAL: shard(s) "
                f"{list(cov.missing_shards)} missing ({detail}) — "
                f"covers {cov.fraction:.2%} of the corpus; recall vs "
                f"the full corpus is bounded above by that fraction")
        iters = {st: round(float(arr.mean()), 2)
                 for st, arr in self.engine.iter_stats_by_stage().items()
                 if arr.size}
        # per-dispatch cache observability (ISSUE 10): the delta this
        # dispatch contributed to the cross-request cache's counters —
        # race-free for the same single-worker-thread reason as coverage
        kc = None
        kc1 = getattr(self.engine, "kcache_stats", lambda: None)()
        if kc1 is not None:
            dh = kc1["hits"] - (kc0["hits"] if kc0 else 0)
            dm = kc1["misses"] - (kc0["misses"] if kc0 else 0)
            kc = {"hits": dh, "misses": dm,
                  "hit_rate": round(dh / (dh + dm), 4) if dh + dm else 0.0}
        out = {}
        for i, req in enumerate(reqs):
            kk = min(req.k, indices.shape[1])
            out[req.rid] = ServeResponse(
                rid=req.rid, ok=True, tier=tier.name,
                # a partial result must NEVER claim exactness, whatever
                # the tier says: coverage < 1 caps recall below 1
                exact=(tier.solve and tier.nprobe is None
                       and tier.mode == "exact" and not partial),
                caveat=caveat,
                indices=np.asarray(indices[i][:kk]).tolist(),
                distances=[round(float(v), 6)
                           for v in np.asarray(dists[i][:kk])],
                solve_iters=iters or None,
                partial=partial,
                coverage=(round(float(cov.fraction), 4) if partial
                          else None),
                missing_shards=(list(cov.missing_shards) if partial
                                else None),
                kcache=kc)
        return out

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Runtime-level counters for the serve JSON / load generator."""
        c = dict(self.counters)
        c["tiers"] = dict(self.counters["tiers"])
        total = sum(c["tiers"].values())
        degraded = total - c["tiers"].get(self.tiers[0].name, 0)
        c["degraded_frac"] = round(degraded / total, 4) if total else 0.0
        c["retries"] = self.guard.retries
        c["watchdog_trips"] = self.guard.watchdog_trips
        c["iter_stats_dropped"] = (self._iters_dropped
                                   + self.engine.iter_stats_dropped)
        c["tier_ema_s"] = {self.tiers[i].name: round(v, 4)
                           for i, v in self._ema._ema.items()}
        kc = getattr(self.engine, "kcache_stats", lambda: None)()
        if kc is not None:
            c["kcache"] = kc
        shards = getattr(self.engine, "n_shards", None)
        if shards:
            c["shards"] = int(shards)
            c["docs_per_shard"] = [int(n) for n in
                                   self.engine.docs_per_shard]
        health = getattr(self.engine, "health", None)
        if health is not None:
            c["shard_health"] = health.stats()
        return c


# ------------------------------------------------------------ load driving
def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """Open-loop arrival offsets (seconds): exponential inter-arrivals at
    ``rate_per_s``, deterministic in ``seed``."""
    rng = np.random.default_rng((seed, zlib.crc32(b"arrivals")))
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))


def run_open_loop(runtime: ServingRuntime, queries: Sequence,
                  arrivals_s: Sequence[float], k: int = 10,
                  deadline_s: float | None = ...,
                  handle_signals: bool = False):
    """Drive the runtime open-loop: request ``i`` is submitted at offset
    ``arrivals_s[i]`` REGARDLESS of completions (offered load is the
    independent variable — queueing delay shows up in the latency tail,
    exactly what the fig12 sweep measures). Returns ``(responses,
    stats)`` with responses in submission order; every submission
    resolves (result or structured error) — an unhandled exception here
    is a runtime bug, and the chaos gate treats it as such.

    ``handle_signals=True`` installs SIGTERM/SIGINT handlers that call
    :meth:`ServingRuntime.request_shutdown` (graceful drain): the
    remaining arrivals submit immediately — resolving as structured
    ``shutting_down`` rejections — already-admitted requests dispatch
    and resolve normally, and the function still returns ``(responses,
    stats)`` so the driver can emit its final stats JSON instead of
    dying mid-dispatch. No-op on platforms without
    ``loop.add_signal_handler``."""
    async def _go():
        await runtime.start()
        loop = asyncio.get_running_loop()
        installed = []
        if handle_signals:
            import signal as _signal
            for sig in (_signal.SIGINT, _signal.SIGTERM):
                try:
                    loop.add_signal_handler(sig, runtime.request_shutdown)
                    installed.append(sig)
                except (NotImplementedError, RuntimeError):
                    pass
        try:
            t0 = time.monotonic()
            futs = []
            for q, at in zip(queries, arrivals_s):
                if not runtime.closing:
                    delay = t0 + float(at) - time.monotonic()
                    if delay > 0:
                        await asyncio.sleep(delay)
                futs.append(runtime.submit(q, k=k, deadline_s=deadline_s))
            out = await asyncio.gather(*futs)
            await runtime.stop()
        finally:
            for sig in installed:
                loop.remove_signal_handler(sig)
        return list(out), runtime.stats()
    return asyncio.run(_go())
