"""Mixture-of-Experts layer: shared + routed experts, top-k dispatch with
capacity, expert parallelism over the ``model`` mesh axis.

Router options: ``topk`` (standard softmax) or ``sinkhorn`` — the paper's
Sinkhorn-Knopp solver as a balanced-assignment router (repro.core.router).

Dispatch is scatter-based (Megatron/MaxText-style capacity buffers): tokens
are scattered into an (E, C, d) buffer by (expert, rank-within-expert),
experts run as one batched einsum over the E dim (shardable over ``model``),
and results gather back. Tokens past capacity are dropped (standard); with
the Sinkhorn router drops are rare because assignment is balanced by
construction — this is the measurable benefit of the paper's technique here
(see benchmarks/moe_router.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.router import route

Params = dict[str, Any]


def padded_experts(n_experts: int, tp: int) -> int:
    """Experts shard over 'model' (EP): pad count up to a tp multiple
    (qwen2-moe: 60 -> 64 at TP=16). Padded experts are router-masked and
    carry zero Sinkhorn column marginal -> never receive tokens."""
    return -(-n_experts // tp) * tp


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int,
             top_k: int, tp: int = 1, dtype=jnp.float32) -> Params:
    n_experts = padded_experts(n_experts, tp)
    kr, ke, ks = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": jax.random.normal(kr, (d_model, n_experts), dtype) * s_in,
        "w_gate": jax.random.normal(k1, (n_experts, d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (n_experts, d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (n_experts, d_ff, d_model), dtype) * s_out,
    }
    if n_shared > 0:
        ff_sh = n_shared * d_ff
        s1, s2, s3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": jax.random.normal(s1, (d_model, ff_sh), dtype) * s_in,
            "w_up": jax.random.normal(s2, (d_model, ff_sh), dtype) * s_in,
            "w_down": jax.random.normal(s3, (ff_sh, d_model), dtype) * (ff_sh ** -0.5),
        }
    return p


def moe_apply(p: Params, x: jax.Array, top_k: int, router_kind: str = "topk",
              capacity_factor: float = 1.25, router_iters: int = 6,
              n_real: int | None = None) -> tuple[jax.Array, jax.Array]:
    """x (B, T, d) -> (out (B, T, d), aux load-balance loss scalar)."""
    b, t, d = x.shape
    flat = x.reshape(b * t, d)
    n = b * t
    e = p["router"].shape[1]
    cap = int(capacity_factor * top_k * n / (n_real or e) + 1)

    logits = (flat @ p["router"]).astype(jnp.float32)
    probs = route(logits, router_kind, n_iter=router_iters,
                  n_real=n_real)                                # (n, E)
    topw, topi = lax.top_k(probs, top_k)                        # (n, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # rank of each assignment within its expert (static-shape scatter dispatch)
    eid = topi.reshape(-1)                                      # (n*k,)
    oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)                # (n*k, E)
    rank = (jnp.cumsum(oh, axis=0) - oh)
    rank = jnp.take_along_axis(rank, eid[:, None], axis=1)[:, 0]
    keep = (rank < cap).astype(x.dtype)
    rankc = jnp.minimum(rank, cap - 1)

    tok = jnp.arange(n).repeat(top_k)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[eid, rankc].add(flat[tok] * keep[:, None])     # (E, C, d)

    # expert FFN (swiglu), batched over E — shard E over 'model'
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    hu = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * hu, p["w_down"])

    gathered = out_buf[eid, rankc] \
        * (keep * topw.reshape(-1).astype(x.dtype))[:, None]
    out = gathered.reshape(n, top_k, d).sum(axis=1)

    if "shared" in p:
        sp = p["shared"]
        out = out + (jax.nn.silu(flat @ sp["w_gate"]) * (flat @ sp["w_up"])) \
            @ sp["w_down"]

    # switch-style aux loss: E * sum_e fraction_tokens_e * mean_prob_e
    frac = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return out.reshape(b, t, d), aux.astype(x.dtype)


def moe_apply_ep(p: Params, x: jax.Array, top_k: int,
                 router_kind: str, capacity_factor: float,
                 router_iters: int, n_real: int, mesh, dp_axes: tuple,
                 tp_axis: str) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map (the production path).

    The pjit scatter formulation computes token ranks with a GLOBAL cumsum
    and all-reduces the whole (E, C, d) buffer across data shards (measured
    966 GB + 773 GB of per-layer ARs on qwen3-moe; EXPERIMENTS.md §Perf #4).
    Here instead, per (data x model) chip:

      - route + rank LOCALLY (tokens are data-sharded; activations are
        replicated over the model axis, so every model chip sees the same
        tokens and routes identically). NOTE: the Sinkhorn router therefore
        balances load PER DATA SHARD rather than globally — the scalable
        semantics (global balancing would need a cross-shard solve); top-k
        routing is bitwise identical to the single-device layer;
      - scatter into a LOCAL (E, C_loc, d) buffer (C_loc = capacity of the
        shard's own tokens — the paper's per-thread disjoint-nnz ownership);
      - each model chip slices ITS E/tp experts and runs their FFNs with
        its local expert weights;
      - combine with ONE psum of (n_loc, d) over the model axis — the same
        collective a dense TP layer pays. No global cumsum, no buffer AR.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, t, d = x.shape
    e = p["router"].shape[1]
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))[tp_axis]
    e_loc = e // tp_size

    x_spec = P(dp_axes, None, None)
    w_specs = {
        "router": P(), "w_gate": P(tp_axis, None, None),
        "w_up": P(tp_axis, None, None), "w_down": P(tp_axis, None, None),
    }
    if "shared" in p:
        w_specs["shared"] = {"w_gate": P(None, tp_axis),
                             "w_up": P(None, tp_axis),
                             "w_down": P(tp_axis, None)}
    p_specs = {k: w_specs[k] for k in p}

    def body(p_loc, x_loc):
        bl, tl, _ = x_loc.shape
        n = bl * tl
        flat = x_loc.reshape(n, d)
        cap = int(capacity_factor * top_k * n / n_real + 1)
        logits = (flat @ p_loc["router"]).astype(jnp.float32)
        probs = route(logits, router_kind, n_iter=router_iters,
                      n_real=n_real)
        topw, topi = lax.top_k(probs, top_k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        eid = topi.reshape(-1)
        oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)
        rank = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - oh,
                                   eid[:, None], axis=1)[:, 0]
        keep = (rank < cap).astype(x_loc.dtype)
        rankc = jnp.minimum(rank, cap - 1)
        tok = jnp.arange(n).repeat(top_k)
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        buf = buf.at[eid, rankc].add(flat[tok] * keep[:, None])

        midx = lax.axis_index(tp_axis)
        my = lax.dynamic_slice_in_dim(buf, midx * e_loc, e_loc, axis=0)
        h = jnp.einsum("ecd,edf->ecf", my, p_loc["w_gate"])
        hu = jnp.einsum("ecd,edf->ecf", my, p_loc["w_up"])
        outb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * hu,
                          p_loc["w_down"])

        rel = eid - midx * e_loc
        mine = (rel >= 0) & (rel < e_loc)
        relc = jnp.where(mine, rel, 0)
        gathered = jnp.where(
            mine[:, None], outb[relc, rankc], 0.0) \
            * (keep * topw.reshape(-1).astype(x_loc.dtype))[:, None]
        out = gathered.reshape(n, top_k, d).sum(axis=1)

        if "shared" in p_loc:
            sp = p_loc["shared"]       # ff dim tp-sharded -> partial sums
            out = out + (jax.nn.silu(flat @ sp["w_gate"])
                         * (flat @ sp["w_up"])) @ sp["w_down"]
        out = lax.psum(out, tp_axis)   # ONE collective per MoE layer

        frac = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32),
                        axis=0)
        aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
        aux = lax.pmean(aux, dp_axes)    # identical across tp already
        return out.reshape(bl, tl, d), aux.astype(x_loc.dtype)

    out, aux = shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                         out_specs=(x_spec, P()))(p, x)
    return out, aux


def moe_dropped_fraction(p: Params, x: jax.Array, top_k: int,
                         router_kind: str, capacity_factor: float = 1.25,
                         router_iters: int = 6) -> jax.Array:
    """Fraction of (token, expert) assignments dropped at capacity — the
    router-quality metric the Sinkhorn router improves."""
    b, t, d = x.shape
    flat = x.reshape(b * t, d)
    n = b * t
    e = p["router"].shape[1]
    cap = int(capacity_factor * top_k * n / e + 1)
    logits = (flat @ p["router"]).astype(jnp.float32)
    probs = route(logits, router_kind, n_iter=router_iters)
    _, topi = lax.top_k(probs, top_k)
    eid = topi.reshape(-1)
    oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)
    rank = (jnp.cumsum(oh, axis=0) - oh)
    rank = jnp.take_along_axis(rank, eid[:, None], axis=1)[:, 0]
    return jnp.mean((rank >= cap).astype(jnp.float32))
