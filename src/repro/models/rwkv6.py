"""RWKV-6 "Finch" block — data-dependent decay linear attention, chunked.

Per head (key/value dim D), with data-dependent diagonal decay w_t in (0,1)^D
and per-head bonus u:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t            S: (D, D)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Chunked evaluation: all pairwise decays are exp(lw[t-1] - lw[s]) with
lw = inclusive cumsum(log w) DECREASING, so every exponent is <= 0 — the
computation is numerically safe by construction (no exp(+x) factorization;
we pay a (c, c, D) einsum per chunk instead, which the MXU amortizes).

Simplifications vs the released RWKV-6 (noted per DESIGN.md): static
token-shift mixing coefficients (RWKV-5 style) instead of the ddlerp LoRA
for r/k/v/g; the *decay* LoRA — the defining Finch feature — is kept
data-dependent. Channel-mix FFN is the standard d_ff squared-ReLU variant.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]


def init_rwkv6(key, d_model: int, head_dim: int = 64, decay_lora: int = 64,
               n_heads: int | None = None, dtype=jnp.float32) -> Params:
    # n_heads may exceed d_model // head_dim (TP padding — see configs.base)
    n_heads = (d_model // head_dim) if n_heads is None else n_heads
    d_attn = n_heads * head_dim
    ks = jax.random.split(key, 8)
    s = d_model ** -0.5
    return {
        "mu": 0.5 * jnp.ones((5, d_model), dtype),   # shift mix for r,k,v,w,g
        "wr": jax.random.normal(ks[0], (d_model, d_attn), dtype) * s,
        "wk": jax.random.normal(ks[1], (d_model, d_attn), dtype) * s,
        "wv": jax.random.normal(ks[2], (d_model, d_attn), dtype) * s,
        "wg": jax.random.normal(ks[3], (d_model, d_attn), dtype) * s,
        "wo": jax.random.normal(ks[4], (d_attn, d_model), dtype)
              * (d_attn ** -0.5),
        # decay LoRA: w = exp(-exp(w0 + tanh(x @ w1) @ w2))
        "w0": jnp.full((d_attn,), -1.0, dtype),
        "w1": jax.random.normal(ks[5], (d_model, decay_lora), dtype) * s,
        "w2": jax.random.normal(ks[6], (decay_lora, d_attn), dtype)
              * (decay_lora ** -0.5),
        "u": jax.random.normal(ks[7], (n_heads, head_dim), dtype) * 0.1,
        "ln_scale": jnp.ones((d_attn,), dtype),      # per-head group norm
    }


def _mix(x, x_shift, mu):
    return x + mu * (x_shift - x)


def _proj_rkvwg(p, x, x_shift, n_heads, head_dim):
    b, t, d = x.shape
    r = _mix(x, x_shift, p["mu"][0]) @ p["wr"]
    k = _mix(x, x_shift, p["mu"][1]) @ p["wk"]
    v = _mix(x, x_shift, p["mu"][2]) @ p["wv"]
    xw = _mix(x, x_shift, p["mu"][3])
    g = jax.nn.silu(_mix(x, x_shift, p["mu"][4]) @ p["wg"])
    logw = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["w1"]) @ p["w2"])  # < 0
    shp = (b, t, n_heads, head_dim)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            logw.reshape(shp), g)


def _out(p, o, g, b, t, d_model):
    of = o.reshape(b, t, -1)
    var = jnp.mean(jnp.square(of.astype(jnp.float32)), -1, keepdims=True)
    of = of * lax.rsqrt(var + 1e-6).astype(of.dtype) * p["ln_scale"]
    return (of * g) @ p["wo"]


def rwkv6_train(p: Params, x: jax.Array, head_dim: int = 64,
                chunk: int = 64) -> jax.Array:
    """Full-sequence chunked WKV6. x (B, T, d); T % chunk == 0."""
    b, t, d_model = x.shape
    chunk = min(chunk, t)
    n_heads = p["wo"].shape[0] // head_dim
    x_shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, logw, g = _proj_rkvwg(p, x, x_shift, n_heads, head_dim)
    u = p["u"]

    nc = t // chunk
    rs = r.reshape(b, nc, chunk, n_heads, head_dim).transpose(1, 0, 3, 2, 4)
    ks = k.reshape(b, nc, chunk, n_heads, head_dim).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nc, chunk, n_heads, head_dim).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(b, nc, chunk, n_heads, head_dim).transpose(1, 0, 3, 2, 4)
    # shapes now (nc, B, H, c, D)

    def chunk_body(s0, inp):
        rc, kc, vc, lwc = inp                       # (B,H,c,D)
        cum = jnp.cumsum(lwc, axis=2)               # inclusive, decreasing
        cum_excl = cum - lwc                        # lw up to t-1
        # inter-chunk: o_t += (r_t * exp(cum_excl[t])) @ S0
        q_t = rc * jnp.exp(cum_excl)
        o = jnp.einsum("bhtd,bhde->bhte", q_t, s0)
        # intra-chunk: A[t,s] = sum_d r[t,d] k[s,d] exp(cum_excl[t]-cum[s]),
        # s < t (exponent <= 0 since cum decreasing); diagonal uses bonus u.
        ddiff = cum_excl[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,t,s,D)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, None, :, :, None]
        # clamp BEFORE exp (masked entries have ddiff >= 0; 0*inf VJP poison)
        dec = jnp.where(tri, jnp.exp(jnp.where(tri, ddiff, 0.0)), 0.0)
        amat = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc, kc, dec)
        diag = jnp.einsum("bhtd,bhtd->bht", rc * u[None, :, None, :], kc)
        amat = amat + diag[..., None] * jnp.eye(chunk, dtype=amat.dtype)
        o = o + jnp.einsum("bhts,bhsd->bhtd", amat, vc)
        # state update: S = exp(cum[-1]) S0 + sum_s exp(cum[-1]-cum[s]) k_s v_s
        dec_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,H,c,D) <= 1
        s_new = jnp.exp(cum[:, :, -1])[..., None] * s0 + jnp.einsum(
            "bhsd,bhse->bhde", kc * dec_end, vc)
        return s_new, o

    s0 = jnp.zeros((b, n_heads, head_dim, head_dim), x.dtype)
    _, os_ = lax.scan(chunk_body, s0, (rs, ks, vs, lw))
    o = os_.transpose(1, 0, 3, 2, 4).reshape(b, t, n_heads, head_dim)
    return _out(p, o, g, b, t, d_model)


def rwkv6_decode(p: Params, x: jax.Array, shift_state: jax.Array,
                 wkv_state: jax.Array, head_dim: int = 64):
    """One token. x (B,1,d); shift_state (B,1,d) previous token's input;
    wkv_state (B,H,D,D). Returns (out, new_shift, new_wkv)."""
    b, _, d_model = x.shape
    n_heads = p["wo"].shape[0] // head_dim
    r, k, v, logw, g = _proj_rkvwg(p, x, shift_state, n_heads, head_dim)
    r1, k1, v1, lw1 = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]   # (B,H,D)
    u = p["u"]
    kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
    o = jnp.einsum("bhd,bhde->bhe", r1, wkv_state + u[None, :, :, None] * kv)
    s_new = jnp.exp(lw1)[..., None] * wkv_state + kv
    out = _out(p, o[:, None], g, b, 1, d_model)
    return out, x, s_new


def rwkv6_ref(p: Params, x: jax.Array, head_dim: int = 64) -> jax.Array:
    """Step-by-step oracle."""
    b, t, d_model = x.shape
    n_heads = p["wo"].shape[0] // head_dim
    x_shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, logw, g = _proj_rkvwg(p, x, x_shift, n_heads, head_dim)
    u = p["u"]

    def step(s, inp):
        rt, kt, vt, lwt = inp                       # (B,H,D)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        o = jnp.einsum("bhd,bhde->bhe", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, o

    s0 = jnp.zeros((b, n_heads, head_dim, head_dim), x.dtype)
    _, os_ = lax.scan(step, s0, (r.transpose(1, 0, 2, 3),
                                 k.transpose(1, 0, 2, 3),
                                 v.transpose(1, 0, 2, 3),
                                 logw.transpose(1, 0, 2, 3)))
    o = os_.transpose(1, 0, 2, 3)
    return _out(p, o, g, b, t, d_model)
