"""Model bundle: config -> jit-able train_step / serve_step + input specs.

This is the seam between the model zoo and the launchers: everything the
dry-run, trainer, and server need for an architecture comes from
``build_bundle(cfg)``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.optim import adamw
from repro.optim.schedules import cosine_with_warmup
from . import transformer as T

Params = dict[str, Any]


@dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    aux_loss_weight: float = 0.01    # MoE load-balance term
    remat: bool = True
    microbatch: int | None = None    # grad-accumulation microbatch size


def make_train_step(cfg: ArchConfig, tp: int = 1,
                    hp: TrainHParams = TrainHParams(),
                    batch_axes: tuple | None = None) -> Callable:
    """(params, opt_state, batch{tokens, labels}) -> (params, opt_state,
    metrics). Pure; jit/pjit at the call site.

    With ``hp.microbatch`` set, gradients accumulate in fp32 over a scan of
    microbatches (bounds live activation memory to one microbatch — together
    with sqrt-remat this is what fits the 340B train cells in HBM)."""

    def loss_fn(params, tokens, labels):
        hidden, aux = T.forward(cfg, params, tokens, tp=tp, remat=hp.remat)
        ce = T.lm_loss(cfg, params, hidden, labels)
        return ce + hp.aux_loss_weight * aux.astype(jnp.float32), (ce, aux)

    def grads_of(params, batch):
        gb = batch["tokens"].shape[0]
        if hp.microbatch and hp.microbatch < gb:
            nmb = gb // hp.microbatch
            mbs = jax.tree.map(
                lambda x: x.reshape((nmb, hp.microbatch) + x.shape[1:]),
                batch)
            if batch_axes:
                # keep microbatches sharded over the data axes — without
                # this constraint SPMD loses the batch sharding through the
                # reshape and replicates activations (measured: 6.4 GB
                # f32 all-gathers x192 on qwen2.5; EXPERIMENTS.md §Perf #1)
                from jax.sharding import PartitionSpec as _P
                spec = _P(None, batch_axes, None)
                mbs = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(x, spec), mbs)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def mb_body(acc, mb):
                g_acc, loss_a, ce_a, aux_a = acc
                (loss, (ce, aux)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb["tokens"],
                                           mb["labels"])
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_a + loss, ce_a + ce,
                        aux_a + aux.astype(jnp.float32)), None

            (g, loss, ce, aux), _ = jax.lax.scan(
                mb_body, (g0, 0.0, 0.0, 0.0), mbs)
            inv = 1.0 / nmb
            grads = jax.tree.map(lambda x: x * inv, g)
            return (loss * inv, (ce * inv, aux * inv)), grads
        return jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch["tokens"], batch["labels"])

    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = grads_of(params, batch)
        lr = cosine_with_warmup(opt_state.step + 1, peak_lr=hp.peak_lr,
                                warmup_steps=hp.warmup_steps,
                                total_steps=hp.total_steps)
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr, weight_decay=hp.weight_decay,
            clip_norm=hp.clip_norm)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm,
                   "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, tp: int = 1) -> Callable:
    """(params, cache, tokens (B,1)) -> (next_tokens (B,1), logits, cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = T.decode_step(cfg, params, cache, tokens, tp=tp)
        nxt = jnp.argmax(logits, axis=-1).astype(tokens.dtype)[:, None]
        return nxt, logits, cache

    return serve_step


def make_prefill(cfg: ArchConfig, tp: int = 1,
                 block_k: int = 512) -> Callable:
    """(params, tokens (B,T)) -> logits (B, T_last only) — inference-prefill
    forward (no loss, no grads); used by the prefill_* dry-run cells."""

    def prefill(params, tokens):
        hidden, _ = T.forward(cfg, params, tokens, tp=tp, remat=False,
                              block_k=block_k)
        head = T.lm_head_matrix(cfg, params)
        return (hidden[:, -1] @ head).astype(jnp.float32)

    return prefill


# ----------------------------------------------------------- input specs
def train_input_specs(cfg: ArchConfig, global_batch: int, seq_len: int):
    """ShapeDtypeStructs for one train step's batch."""
    return {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }


def decode_input_specs(cfg: ArchConfig, global_batch: int):
    return jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)


def abstract_params(cfg: ArchConfig, tp: int = 1, dtype=jnp.float32):
    """Parameter ShapeDtypeStructs WITHOUT allocating (for the dry-run)."""
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, tp=tp, dtype=dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int, tp: int = 1,
                   dtype=jnp.float32):
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, batch, max_len, tp=tp,
                          dtype=dtype))


def abstract_opt_state(abstract_p):
    return jax.eval_shape(adamw.init, abstract_p)
