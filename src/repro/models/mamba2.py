"""Mamba2 (SSD) block — chunked parallel scan, JAX-native.

State-space recurrence per head h (scalar decay a_t = exp(dt_t * A_h)):
    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T          S: (N, P)
    y_t = C_t . S_t + D_h * x_t

Chunked (SSD) evaluation: within a chunk of length c the pairwise decay
matrix L[t, s] = exp(cum[t] - cum[s]) (s <= t, bounded <= 1 — numerically
safe by construction) gives the intra-chunk term as two small einsums; the
inter-chunk term carries S through a lax.scan over T/c chunks. This is the
standard Mamba2 "chunkwise" algorithm mapped onto MXU-friendly einsums.

TP note: projections are SPLIT (w_z/w_x/w_dt head-sharded; w_bc replicated —
B/C are shared across heads, n_groups=1) so heads shard cleanly over the
``model`` mesh axis without slicing through a fused in_proj. The gated
RMSNorm reduces over the sharded d_inner axis; XLA inserts the (scalar-sized)
cross-shard reduction automatically.

Used by zamba2-7b (the [hybrid] assigned arch). Decode is the one-step
recurrence with (conv window, S) carried in the serve cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]


def init_mamba2(key, d_model: int, d_state: int = 64, head_dim: int = 64,
                expand: int = 2, conv_width: int = 4,
                dtype=jnp.float32) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d_model, d_inner), dtype) * s,
        "w_x": jax.random.normal(ks[1], (d_model, d_inner), dtype) * s,
        "w_bc": jax.random.normal(ks[2], (d_model, 2 * d_state), dtype) * s,
        "w_dt": jax.random.normal(ks[3], (d_model, n_heads), dtype) * s,
        "conv_x": jax.random.normal(ks[4], (conv_width, d_inner), dtype) * 0.2,
        "conv_bc": jax.random.normal(ks[5], (conv_width, 2 * d_state),
                                     dtype) * 0.2,
        "conv_bias_x": jnp.zeros((d_inner,), dtype),
        "conv_bias_bc": jnp.zeros((2 * d_state,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
        "d_skip": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": jax.random.normal(key, (d_inner, d_model), dtype)
                    * (d_inner ** -0.5),
    }


def _causal_conv(x, w, bias):
    """Depthwise causal conv, width W: (B, T, C), (W, C) -> (B, T, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + bias)


def _gated_out(p, y, z):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * lax.rsqrt(var + 1e-6).astype(y.dtype)) * p["norm_scale"]
    return y @ p["out_proj"]


def mamba2_train(p: Params, xin: jax.Array, d_state: int = 64,
                 head_dim: int = 64, chunk: int = 128) -> jax.Array:
    """Full-sequence chunked SSD. xin (B, T, d). T % chunk == 0."""
    b, t, _ = xin.shape
    chunk = min(chunk, t)
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // head_dim

    z = xin @ p["w_z"]
    xs = _causal_conv(xin @ p["w_x"], p["conv_x"], p["conv_bias_x"])
    bc = _causal_conv(xin @ p["w_bc"], p["conv_bc"], p["conv_bias_bc"])
    xs = xs.reshape(b, t, n_heads, head_dim)
    bmat, cmat = bc[..., :d_state], bc[..., d_state:]          # (B,T,N)
    dt = jax.nn.softplus(xin @ p["w_dt"] + p["dt_bias"])       # (B,T,H)
    a = -jnp.exp(p["a_log"])                                   # (H,) < 0
    da = dt * a                                                # (B,T,H) <= 0

    nc = t // chunk
    xs_c = xs.reshape(b, nc, chunk, n_heads, head_dim).transpose(1, 0, 2, 3, 4)
    b_c = bmat.reshape(b, nc, chunk, d_state).transpose(1, 0, 2, 3)
    c_c = cmat.reshape(b, nc, chunk, d_state).transpose(1, 0, 2, 3)
    dt_c = dt.reshape(b, nc, chunk, n_heads).transpose(1, 0, 2, 3)
    da_c = da.reshape(b, nc, chunk, n_heads).transpose(1, 0, 2, 3)

    def chunk_body(s0, inp):
        xc, bcv, ccv, dtc, dac = inp        # (B,c,H,P),(B,c,N),(B,c,N),(B,c,H)
        cum = jnp.cumsum(dac, axis=1)                          # (B,c,H)
        # intra: L[t,s] = exp(cum[t]-cum[s]) for s<=t  (all exponents <= 0)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]        # (B,c,c,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        # clamp BEFORE exp: masked (s > t) entries have ldiff >= 0 and would
        # overflow; 0*inf in the VJP poisons gradients otherwise
        l_mat = jnp.where(tri, jnp.exp(jnp.where(tri, ldiff, 0.0)), 0.0)
        cb = jnp.einsum("btn,bsn->bts", ccv, bcv)              # (B,c,c)
        y = jnp.einsum("bts,btsh,bsh,bshp->bthp", cb, l_mat, dtc, xc)
        # inter: y += exp(cum[t]) * C_t . S0
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "btn,bhnp->bthp", ccv, s0)
        # state: S = exp(cum[-1]) S0 + sum_s exp(cum[-1]-cum[s]) dt_s B_s x_s^T
        dec = jnp.exp(cum[:, -1:, :] - cum)                    # (B,c,H) <= 1
        s_new = jnp.exp(cum[:, -1])[:, :, None, None] * s0 + jnp.einsum(
            "bsh,bsn,bshp->bhnp", dec * dtc, bcv, xc)
        return s_new, y

    s0 = jnp.zeros((b, n_heads, d_state, head_dim), xin.dtype)
    _, ys = lax.scan(chunk_body, s0, (xs_c, b_c, c_c, dt_c, da_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, n_heads, head_dim)
    y = y + p["d_skip"][None, None, :, None] * xs
    return _gated_out(p, y.reshape(b, t, d_inner), z)


def mamba2_decode(p: Params, xin: jax.Array, conv_state: jax.Array,
                  ssm_state: jax.Array, d_state: int = 64,
                  head_dim: int = 64):
    """One-step recurrence. xin (B, 1, d); conv_state (B, W-1, C_x + C_bc);
    ssm_state (B, H, N, P). Returns (y (B,1,d), conv_state', ssm_state')."""
    b = xin.shape[0]
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // head_dim

    z = xin @ p["w_z"]
    xbc_new = jnp.concatenate([xin @ p["w_x"], xin @ p["w_bc"]], axis=-1)
    win = jnp.concatenate([conv_state, xbc_new], axis=1)       # (B, W, C)
    w_cat = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=1)
    bias = jnp.concatenate([p["conv_bias_x"], p["conv_bias_bc"]])
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, w_cat) + bias)
    new_conv_state = win[:, 1:]

    xs = conv[:, :d_inner].reshape(b, n_heads, head_dim)
    bvec = conv[:, d_inner:d_inner + d_state]                  # (B,N)
    cvec = conv[:, d_inner + d_state:]
    dt1 = jax.nn.softplus((xin @ p["w_dt"])[:, 0] + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dt1 * -jnp.exp(p["a_log"]))                # (B,H)

    s_new = decay[:, :, None, None] * ssm_state + jnp.einsum(
        "bh,bn,bhp->bhnp", dt1, bvec, xs)
    y = jnp.einsum("bn,bhnp->bhp", cvec, s_new)
    y = y + p["d_skip"][None, :, None] * xs
    return _gated_out(p, y.reshape(b, 1, d_inner), z[:, :1]), \
        new_conv_state, s_new


def mamba2_ref(p: Params, xin: jax.Array, d_state: int = 64,
               head_dim: int = 64) -> jax.Array:
    """Step-by-step oracle (lax.scan over single timesteps)."""
    b, t, _ = xin.shape
    d_inner = p["out_proj"].shape[0]
    n_heads = d_inner // head_dim
    z = xin @ p["w_z"]
    xs = _causal_conv(xin @ p["w_x"], p["conv_x"], p["conv_bias_x"])
    bc = _causal_conv(xin @ p["w_bc"], p["conv_bc"], p["conv_bias_bc"])
    xs = xs.reshape(b, t, n_heads, head_dim)
    bmat, cmat = bc[..., :d_state], bc[..., d_state:]
    dtv = jax.nn.softplus(xin @ p["w_dt"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    def step(s, inp):
        xt, bt, ct, dtt = inp
        decay = jnp.exp(dtt * a)                               # (B,H)
        s = decay[:, :, None, None] * s + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((b, n_heads, d_state, head_dim), xin.dtype)
    _, ys = lax.scan(step, s0, (xs.transpose(1, 0, 2, 3),
                                bmat.transpose(1, 0, 2),
                                cmat.transpose(1, 0, 2),
                                dtv.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3) + p["d_skip"][None, None, :, None] * xs
    return _gated_out(p, y.reshape(b, t, d_inner), z)