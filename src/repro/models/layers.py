"""Transformer substrate layers: norms, RoPE, GQA flash attention (custom-vjp
online-softmax — O(T) memory in both passes), MLP variants.

Everything is pure-function + param-dict (no framework dependency); params
are created by ``init_*`` functions and consumed by the matching ``apply``
functions. Layouts are chosen for Megatron-style tensor parallelism: QKV and
MLP-in are column-sharded on the output feature dim, out-proj and MLP-out are
row-sharded on the input dim (see repro.runtime.sharding for the rules).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]

NEG_INF = -1e30

# Tensor-parallel axis name for activation sharding constraints. Set by the
# launchers (dryrun/train) when running under a mesh; None (default, smoke
# tests / single device) makes the constraints no-ops. Without the explicit
# head-axis constraints SPMD resolves the flash-attention scan carry as
# REPLICATED and all-gathers q/k/v per layer (measured 9.9 TB of
# all-gathers on qwen2.5 train_4k; EXPERIMENTS.md §Perf #1).
TP_AXIS: str | None = None
DP_AXES: tuple = ()          # data-parallel axes (batch dim sharding)
MESH = None                  # concrete mesh (enables shard_map EP for MoE)


def _constrain(x: jax.Array, *spec) -> jax.Array:
    """spec entries: "tp" -> TP_AXIS, "dp" -> DP_AXES, None -> replicated.
    None here really means replicated — forgetting "dp" on the batch dim
    forces batch replication (measured as f32 full-batch all-gathers x36 on
    granite; EXPERIMENTS.md §Perf #2)."""
    if TP_AXIS is None:
        return x
    from jax.sharding import PartitionSpec as P
    ent = [TP_AXIS if s == "tp" else (DP_AXES or None) if s == "dp" else None
           for s in spec]
    return lax.with_sharding_constraint(x, P(*ent))



# ----------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps).astype(x.dtype)
    return out * p["scale"].astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = ((xf - mu) * lax.rsqrt(var + eps)).astype(x.dtype)
    return out * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def init_norm(kind: str, d: int, dtype=jnp.float32) -> Params:
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


# ----------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float, positions: jax.Array):
    """positions (T,) -> cos/sin (T, head_dim/2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., T, D); cos/sin (T, D/2). Rotate-half convention."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    shape = (1,) * (x.ndim - 2) + cos.shape
    c = cos.reshape(shape).astype(x.dtype)
    s = sin.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ------------------------------------------------- flash attention (GQA)
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_k: int = 512):
    """Online-softmax attention, O(T*block_k) live memory fwd AND bwd.

    q: (B, G, Hkv, Tq, D) — Hq = G*Hkv query heads grouped by kv head.
    k, v: (B, Hkv, Tk, D).
    Returns (B, G, Hkv, Tq, D).

    Tk must divide by block_k. ``q_offset`` is the absolute position of
    q[..., 0, :] (for chunked prefill).
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, block_k)
    return out


def _mask(s, causal, q_offset, kstart, tq, bk):
    if not causal:
        return s
    q_pos = q_offset + jnp.arange(tq)
    k_pos = kstart + jnp.arange(bk)
    ok = k_pos[None, :] <= q_pos[:, None]
    return jnp.where(ok, s, NEG_INF)


def _flash_fwd_impl(q, k, v, causal, q_offset, block_k):
    b, g, hkv, tq, d = q.shape
    tk = k.shape[2]
    nb = tk // block_k
    scale = 1.0 / (d ** 0.5)
    acc_t = jnp.float32

    def body(carry, i):
        o, m, denom = carry
        kb = lax.dynamic_slice_in_dim(k, i * block_k, block_k, axis=2)
        vb = lax.dynamic_slice_in_dim(v, i * block_k, block_k, axis=2)
        s = jnp.einsum("bghqd,bhkd->bghqk", q, kb,
                       preferred_element_type=acc_t) * scale
        s = _mask(s, causal, q_offset, i * block_k, tq, block_k)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(-1)
        o = o * corr[..., None] + jnp.einsum(
            "bghqk,bhkd->bghqd", p.astype(v.dtype), vb,
            preferred_element_type=acc_t)
        return (o, m_new, denom), None

    o0 = jnp.zeros((b, g, hkv, tq, d), acc_t)
    m0 = jnp.full((b, g, hkv, tq), NEG_INF, acc_t)
    l0 = jnp.zeros((b, g, hkv, tq), acc_t)
    (o, m, denom), _ = lax.scan(body, (o0, m0, l0), jnp.arange(nb))
    out = (o / denom[..., None]).astype(q.dtype)
    lse = m + jnp.log(denom)
    return out, lse


def _flash_fwd(q, k, v, causal, q_offset, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, block_k, res, dout):
    q, k, v, out, lse = res
    b, g, hkv, tq, d = q.shape
    tk = k.shape[2]
    nb = tk // block_k
    scale = 1.0 / (d ** 0.5)
    acc_t = jnp.float32
    delta = jnp.sum(dout.astype(acc_t) * out.astype(acc_t), axis=-1)  # (b,g,h,q)

    def body(dq, i):
        kb = lax.dynamic_slice_in_dim(k, i * block_k, block_k, axis=2)
        vb = lax.dynamic_slice_in_dim(v, i * block_k, block_k, axis=2)
        s = jnp.einsum("bghqd,bhkd->bghqk", q, kb,
                       preferred_element_type=acc_t) * scale
        s = _mask(s, causal, q_offset, i * block_k, tq, block_k)
        p = jnp.exp(s - lse[..., None])                      # recompute
        dp = jnp.einsum("bghqd,bhkd->bghqk", dout.astype(acc_t),
                        vb.astype(acc_t), preferred_element_type=acc_t)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bghqk,bhkd->bghqd", ds.astype(q.dtype), kb,
                             preferred_element_type=acc_t)
        dkb = jnp.einsum("bghqk,bghqd->bhkd", ds.astype(q.dtype), q,
                         preferred_element_type=acc_t)
        dvb = jnp.einsum("bghqk,bghqd->bhkd", p.astype(dout.dtype), dout,
                         preferred_element_type=acc_t)
        return dq, (dkb, dvb)

    dq0 = jnp.zeros(q.shape, acc_t)
    dq, (dks, dvs) = lax.scan(body, dq0, jnp.arange(nb))
    # dks: (nb, b, hkv, bk, d) -> (b, hkv, tk, d)
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(k.shape)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(v.shape)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention_ref(q, k, v, causal=True, q_offset=0):
    """Oracle for flash_attention (materializes the score matrix)."""
    d = q.shape[-1]
    s = jnp.einsum("bghqd,bhkd->bghqk", q, k,
                   preferred_element_type=jnp.float32) / (d ** 0.5)
    if causal:
        tq, tk = q.shape[-2], k.shape[-2]
        q_pos = q_offset + jnp.arange(tq)
        ok = jnp.arange(tk)[None, :] <= q_pos[:, None]
        s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bghqk,bhkd->bghqd", p.astype(v.dtype), v)


# ---------------------------------------------------------------- attention
def init_attention(key, d_model: int, n_q: int, n_kv: int, head_dim: int,
                   qkv_bias: bool, dtype=jnp.float32) -> Params:
    """n_q, n_kv are the TP-adjusted (padded/replicated) head counts."""
    kq, kk, kv_, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(kq, (d_model, n_q * head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(kv_, (d_model, n_kv * head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (n_q * head_dim, d_model), dtype) * s,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_q * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attention_train(p: Params, x: jax.Array, n_q: int, n_kv: int,
                    head_dim: int, rope_theta: float | None,
                    block_k: int = 512) -> jax.Array:
    """Causal self-attention over a full sequence (training / prefill).

    x: (B, T, d). Uses flash attention; GQA grouping n_q = G * n_kv.
    """
    b, t, _ = x.shape
    g = n_q // n_kv
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # kv-MAJOR head layout: the flattened (n_q*head_dim) projection shards
    # contiguously over TP, and kv-major makes the shard boundary land on
    # the kv-head dim -> pure dim sharding, no resharding gathers
    q = q.reshape(b, t, n_kv, g, head_dim).transpose(0, 3, 2, 1, 4)
    k = k.reshape(b, t, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, n_kv, head_dim).transpose(0, 2, 1, 3)
    # pin batch+head sharding across the flash scan (see TP_AXIS note)
    q = _constrain(q, "dp", None, "tp", None, None)
    k = _constrain(k, "dp", "tp", None, None)
    v = _constrain(v, "dp", "tp", None, None)
    if rope_theta is not None:
        cos, sin = rope_frequencies(head_dim, rope_theta, jnp.arange(t))
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    bk = min(block_k, t)
    o = flash_attention(q, k, v, True, 0, bk)          # (B,G,Hkv,T,D)
    o = _constrain(o, "dp", None, "tp", None, None)
    # back to kv-major flat layout (matches wo row order)
    o = o.transpose(0, 3, 2, 1, 4).reshape(b, t, n_q * head_dim)
    return o @ p["wo"]


def attention_decode(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, n_q: int,
                     n_kv: int, head_dim: int, rope_theta: float | None):
    """Single-token decode with a KV cache.

    x: (B, 1, d); cache_k/v: (B, n_kv, S, D); pos: () int32 — number of valid
    cache entries == absolute position of this token.
    Returns (out (B, 1, d), new_cache_k, new_cache_v).
    """
    b, _, _ = x.shape
    g = n_q // n_kv
    s_len = cache_k.shape[2]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, n_kv, g, head_dim).transpose(0, 3, 2, 1, 4)
    k = k.reshape(b, 1, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, 1, n_kv, head_dim).transpose(0, 2, 1, 3)
    if rope_theta is not None:
        posv = pos[None] if pos.ndim == 0 else pos
        cos, sin = rope_frequencies(head_dim, rope_theta, posv)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ck = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                         pos, axis=2)
    cv = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                         pos, axis=2)
    scores = jnp.einsum("bghqd,bhkd->bghqk", q, ck,
                        preferred_element_type=jnp.float32) / (head_dim ** 0.5)
    valid = jnp.arange(s_len)[None] <= pos          # positions 0..pos live
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bghqk,bhkd->bghqd", pr.astype(cv.dtype), cv)
    o = o.transpose(0, 3, 2, 1, 4).reshape(b, 1, n_q * head_dim)
    return o @ p["wo"], ck, cv


# ----------------------------------------------------------------- MLPs
def init_mlp(key, d_model: int, d_ff: int, kind: str,
             dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    if kind == "swiglu":
        return {"w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
                "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
                "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out}
    return {"w_in": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
            "w_out": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}


def mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "squared_relu":                       # nemotron-4
        h = jax.nn.relu(x @ p["w_in"])
        return (h * h) @ p["w_out"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
    raise ValueError(kind)
