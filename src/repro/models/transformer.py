"""Decoder-only LM assembly: config -> init / forward / loss / decode.

One code path covers all 10 assigned architectures:

  dense / vlm / audio  — [attn + mlp] x L, scan-over-layers (+ remat)
  moe                  — [attn + moe] x L (Sinkhorn or top-k router)
  ssm (rwkv6)          — [time-mix + channel-mix] x L
  hybrid (zamba2)      — groups of ``attn_every`` mamba2 layers, each group
                         followed by ONE weight-shared (attn + mlp) block;
                         two-level scan (groups x layers-in-group) keeps the
                         HLO size depth-independent

Params are plain pytrees with per-layer leaves STACKED on a leading dim so
the layer stack is a single lax.scan (depth-independent compile time and
HLO — essential for the 512-device dry-run). jax.checkpoint around the block
body gives activation rematerialization in the backward pass.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from . import layers as L
from . import mamba2 as M2
from . import moe as MOE
from . import rwkv6 as R6

Params = dict[str, Any]


# ---------------------------------------------------------------- helpers
def _stack_init(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _largest_pow2_divisor_leq(t: int, cap: int) -> int:
    c = 1
    while c * 2 <= cap and t % (c * 2) == 0:
        c *= 2
    return c


def loss_chunk_len(seq_len: int, vocab: int, budget: int = 1 << 25) -> int:
    """Tokens per loss chunk so the logits slab stays ~budget elements."""
    return _largest_pow2_divisor_leq(seq_len, max(1, budget // vocab))


def _sqrt_factor(n: int) -> tuple[int, int, int]:
    """n ~ g*k + rem with g ~ sqrt(n): two-level remat grouping."""
    g = max(1, int(n ** 0.5))
    while n // g == 0:
        g -= 1
    k = n // g
    return g, k, n - g * k


def two_level_scan(body_fn, h, stacked, n_layers: int, remat: bool):
    """sqrt(L)-memory remat: outer scan over g groups of k checkpointed
    layers, each group itself checkpointed -> live residuals O(g + k)
    instead of O(L) (the classic sqrt-remat schedule; essential for the
    96-layer/18k-width cells to fit v5e HBM)."""
    g, k, rem = _sqrt_factor(n_layers)
    inner_fn = jax.checkpoint(body_fn) if remat else body_fn

    grouped = jax.tree.map(
        lambda x: x[:g * k].reshape((g, k) + x.shape[1:]), stacked)

    def group_body(h, glp):
        h, auxs = lax.scan(inner_fn, h, glp)
        return h, auxs.sum()
    group_fn = jax.checkpoint(group_body) if remat else group_body
    h, aux = lax.scan(group_fn, h, grouped)
    aux = aux.sum()
    if rem:
        tail = jax.tree.map(lambda x: x[g * k:], stacked)
        h, aux2 = lax.scan(inner_fn, h, tail)
        aux = aux + aux2.sum()
    return h, aux


# ---------------------------------------------------------------- init
def padded_vocab(cfg: ArchConfig, tp: int) -> int:
    """Megatron-style vocab padding: embeddings/logits shard over 'model'."""
    return -(-cfg.vocab_size // tp) * tp


def init_params(cfg: ArchConfig, key, tp: int = 1,
                dtype=jnp.float32) -> Params:
    ke, kl, ks, kh = jax.random.split(key, 4)
    d = cfg.d_model
    vp = padded_vocab(cfg, tp)
    n_q, n_kv = cfg.tp_heads(tp)
    p: Params = {
        "embed": jax.random.normal(ke, (vp, d), dtype) * 0.02,
        "final_norm": L.init_norm(cfg.norm, d, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(kh, (d, vp), dtype) \
            * (d ** -0.5)

    def init_attn_mlp_block(k):
        k1, k2 = jax.random.split(k)
        blk = {
            "norm1": L.init_norm(cfg.norm, d, dtype),
            "norm2": L.init_norm(cfg.norm, d, dtype),
            "attn": L.init_attention(k1, d, n_q, n_kv, cfg.head_dim,
                                     cfg.qkv_bias, dtype),
        }
        if cfg.moe:
            blk["moe"] = MOE.init_moe(k2, d, cfg.moe.d_ff, cfg.moe.n_experts,
                                      cfg.moe.n_shared, cfg.moe.top_k,
                                      tp=tp, dtype=dtype)
        else:
            blk["mlp"] = L.init_mlp(k2, d, cfg.d_ff, cfg.mlp, dtype)
        return blk

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        p["layers"] = _stack_init(kl, cfg.num_layers, init_attn_mlp_block)
    elif cfg.family == "ssm":                        # rwkv6
        s = cfg.ssm
        n_heads = -(-(d // s.head_dim) // tp) * tp   # pad heads to tp

        def init_rwkv_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": L.init_norm(cfg.norm, d, dtype),
                "norm2": L.init_norm(cfg.norm, d, dtype),
                "tmix": R6.init_rwkv6(k1, d, s.head_dim, s.decay_lora,
                                      n_heads, dtype),
                "cmix": L.init_mlp(k2, d, cfg.d_ff, cfg.mlp, dtype),
            }
        p["layers"] = _stack_init(kl, cfg.num_layers, init_rwkv_block)
    elif cfg.family == "hybrid":                     # zamba2
        s = cfg.ssm
        n_groups = cfg.num_layers // cfg.attn_every
        n_rem = cfg.num_layers - n_groups * cfg.attn_every

        def init_mamba_block(k):
            return {
                "norm": L.init_norm(cfg.norm, d, dtype),
                "mamba": M2.init_mamba2(k, d, s.d_state, s.head_dim,
                                        s.expand, s.conv_width, dtype),
            }
        kg, kr = jax.random.split(kl)
        grouped = _stack_init(kg, n_groups * cfg.attn_every, init_mamba_block)
        p["layers"] = jax.tree.map(
            lambda x: x.reshape((n_groups, cfg.attn_every) + x.shape[1:]),
            grouped)
        if n_rem:
            p["layers_rem"] = _stack_init(kr, n_rem, init_mamba_block)
        p["shared_block"] = init_attn_mlp_block(ks)  # ONE set of weights
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------- forward
def _attn_mlp_block(cfg: ArchConfig, n_q: int, n_kv: int, lp: Params,
                    h: jax.Array, block_k: int):
    hn = L.apply_norm(cfg.norm, lp["norm1"], h)
    h = h + L.attention_train(lp["attn"], hn, n_q, n_kv, cfg.head_dim,
                              cfg.rope_theta, block_k)
    hn = L.apply_norm(cfg.norm, lp["norm2"], h)
    if cfg.moe:
        if L.MESH is not None:        # shard_map expert parallelism
            out, aux = MOE.moe_apply_ep(
                lp["moe"], hn, cfg.moe.top_k, cfg.moe.router,
                cfg.moe.capacity_factor, cfg.moe.router_iters,
                cfg.moe.n_experts, L.MESH, L.DP_AXES, L.TP_AXIS)
        else:
            out, aux = MOE.moe_apply(lp["moe"], hn, cfg.moe.top_k,
                                     cfg.moe.router, cfg.moe.capacity_factor,
                                     cfg.moe.router_iters,
                                     n_real=cfg.moe.n_experts)
        return h + out, aux
    return h + L.mlp(lp["mlp"], hn, cfg.mlp), jnp.zeros((), h.dtype)


def forward(cfg: ArchConfig, params: Params, tokens: jax.Array,
            tp: int = 1, remat: bool = True,
            block_k: int = 512) -> tuple[jax.Array, jax.Array]:
    """tokens (B, T) -> (hidden (B, T, d), aux_loss scalar)."""
    n_q, n_kv = cfg.tp_heads(tp)
    h = jnp.take(params["embed"], tokens, axis=0)
    bk = min(block_k, tokens.shape[1])

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(h, lp):
            h, aux = _attn_mlp_block(cfg, n_q, n_kv, lp, h, bk)
            return h, aux
        h, aux = two_level_scan(body, h, params["layers"], cfg.num_layers,
                                remat)
    elif cfg.family == "ssm":
        s = cfg.ssm
        def body(h, lp):
            hn = L.apply_norm(cfg.norm, lp["norm1"], h)
            h = h + R6.rwkv6_train(lp["tmix"], hn, s.head_dim, s.chunk)
            hn = L.apply_norm(cfg.norm, lp["norm2"], h)
            h = h + L.mlp(lp["cmix"], hn, cfg.mlp)
            return h, jnp.zeros((), h.dtype)
        h, _ = two_level_scan(body, h, params["layers"], cfg.num_layers,
                              remat)
        aux = jnp.zeros((), h.dtype)
    elif cfg.family == "hybrid":
        s = cfg.ssm

        def mamba_body(h, lp):
            hn = L.apply_norm(cfg.norm, lp["norm"], h)
            return h + M2.mamba2_train(lp["mamba"], hn, s.d_state,
                                       s.head_dim, s.chunk), None
        mamba_fn = jax.checkpoint(mamba_body) if remat else mamba_body

        def group_body(h, glp):
            h, _ = lax.scan(mamba_fn, h, glp)
            h, _ = _attn_mlp_block(cfg, n_q, n_kv, params["shared_block"],
                                   h, bk)
            return h, None
        group_fn = jax.checkpoint(group_body) if remat else group_body
        h, _ = lax.scan(group_fn, h, params["layers"])
        if "layers_rem" in params:
            h, _ = lax.scan(mamba_fn, h, params["layers_rem"])
        aux = jnp.zeros((), h.dtype)
    else:
        raise ValueError(cfg.family)
    return L.apply_norm(cfg.norm, params["final_norm"], h), aux


def lm_head_matrix(cfg: ArchConfig, params: Params) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def lm_loss(cfg: ArchConfig, params: Params, hidden: jax.Array,
            labels: jax.Array) -> jax.Array:
    """Chunked softmax cross-entropy (bounded logits slab; DESIGN.md §6)."""
    b, t, d = hidden.shape
    head = lm_head_matrix(cfg, params)
    vp = head.shape[1]
    ct = loss_chunk_len(t, cfg.vocab_size)
    nch = t // ct
    h_c = hidden.reshape(b, nch, ct, d).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, nch, ct).transpose(1, 0, 2)
    pad_mask = (jnp.arange(vp) >= cfg.vocab_size) * (-1e30) \
        if vp != cfg.vocab_size else None

    def body(acc, inp):
        hc, lc = inp
        z = (hc @ head).astype(jnp.float32)          # (B, ct, Vp)
        if pad_mask is not None:
            z = z + pad_mask                         # mask padded vocab rows
        lse = jax.nn.logsumexp(z, axis=-1)
        gold = jnp.take_along_axis(z, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (h_c, l_c))
    return tot / (b * t)


# ---------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int, tp: int = 1,
               dtype=jnp.float32) -> Params:
    """Concrete zero-filled serve cache (use jax.eval_shape for specs)."""
    n_q, n_kv = cfg.tp_heads(tp)
    d = cfg.d_model
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        shp = (cfg.num_layers, batch, n_kv, max_len, cfg.head_dim)
        cache["k"] = jnp.zeros(shp, dtype)
        cache["v"] = jnp.zeros(shp, dtype)
    elif cfg.family == "ssm":
        s = cfg.ssm
        n_heads = -(-(d // s.head_dim) // tp) * tp
        cache["shift"] = jnp.zeros((cfg.num_layers, 2, batch, 1, d), dtype)
        cache["wkv"] = jnp.zeros((cfg.num_layers, batch, n_heads,
                                  s.head_dim, s.head_dim), dtype)
    elif cfg.family == "hybrid":
        s = cfg.ssm
        n_groups = cfg.num_layers // cfg.attn_every
        n_rem = cfg.num_layers - n_groups * cfg.attn_every
        d_in = s.expand * d
        n_heads = d_in // s.head_dim
        c_conv = d_in + 2 * s.d_state
        cache["conv"] = jnp.zeros((n_groups, cfg.attn_every, batch,
                                   s.conv_width - 1, c_conv), dtype)
        cache["ssm"] = jnp.zeros((n_groups, cfg.attn_every, batch, n_heads,
                                  s.d_state, s.head_dim), dtype)
        if n_rem:
            cache["conv_rem"] = jnp.zeros((n_rem, batch, s.conv_width - 1,
                                           c_conv), dtype)
            cache["ssm_rem"] = jnp.zeros((n_rem, batch, n_heads, s.d_state,
                                          s.head_dim), dtype)
        # each of the n_groups shared-block applications has its own KV cache
        cache["k"] = jnp.zeros((n_groups, batch, n_kv, max_len,
                                cfg.head_dim), dtype)
        cache["v"] = jnp.zeros((n_groups, batch, n_kv, max_len,
                                cfg.head_dim), dtype)
    return cache


def _attn_block_decode(cfg, n_q, n_kv, lp, h, ck, cv, pos):
    hn = L.apply_norm(cfg.norm, lp["norm1"], h)
    a, ck, cv = L.attention_decode(lp["attn"], hn, ck, cv, pos, n_q, n_kv,
                                   cfg.head_dim, cfg.rope_theta)
    h = h + a
    hn = L.apply_norm(cfg.norm, lp["norm2"], h)
    if cfg.moe:
        if L.MESH is not None:
            out, _ = MOE.moe_apply_ep(
                lp["moe"], hn, cfg.moe.top_k, cfg.moe.router,
                cfg.moe.capacity_factor, cfg.moe.router_iters,
                cfg.moe.n_experts, L.MESH, L.DP_AXES, L.TP_AXIS)
        else:
            out, _ = MOE.moe_apply(lp["moe"], hn, cfg.moe.top_k,
                                   cfg.moe.router, cfg.moe.capacity_factor,
                                   cfg.moe.router_iters,
                                   n_real=cfg.moe.n_experts)
        h = h + out
    else:
        h = h + L.mlp(lp["mlp"], hn, cfg.mlp)
    return h, ck, cv


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jax.Array, tp: int = 1):
    """One-token decode. tokens (B, 1) -> (logits (B, V), new cache)."""
    n_q, n_kv = cfg.tp_heads(tp)
    h = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["pos"]
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(h, xs):
            lp, ck, cv = xs
            h, ck, cv = _attn_block_decode(cfg, n_q, n_kv, lp, h, ck, cv, pos)
            return h, (ck, cv)
        h, (ks, vs) = lax.scan(body, h, (params["layers"], cache["k"],
                                         cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs
    elif cfg.family == "ssm":
        s = cfg.ssm
        def body(h, xs):
            lp, sh, wkv = xs
            hn = L.apply_norm(cfg.norm, lp["norm1"], h)
            o, sh1, wkv = R6.rwkv6_decode(lp["tmix"], hn, sh[0], wkv,
                                          s.head_dim)
            h = h + o
            hn2 = L.apply_norm(cfg.norm, lp["norm2"], h)
            # channel-mix token shift state (slot 1)
            h = h + L.mlp(lp["cmix"], hn2, cfg.mlp)
            return h, (jnp.stack([sh1, hn2]), wkv)
        h, (shs, wkvs) = lax.scan(body, h, (params["layers"],
                                            cache["shift"], cache["wkv"]))
        new_cache["shift"], new_cache["wkv"] = shs, wkvs
    elif cfg.family == "hybrid":
        s = cfg.ssm

        def mamba_body(h, xs):
            lp, conv, ssm = xs
            hn = L.apply_norm(cfg.norm, lp["norm"], h)
            o, conv, ssm = M2.mamba2_decode(lp["mamba"], hn, conv, ssm,
                                            s.d_state, s.head_dim)
            return h + o, (conv, ssm)

        def group_body(h, xs):
            glp, conv, ssm, ck, cv = xs
            h, (convs, ssms) = lax.scan(mamba_body, h, (glp, conv, ssm))
            h, ck, cv = _attn_block_decode(cfg, n_q, n_kv,
                                           params["shared_block"], h, ck,
                                           cv, pos)
            return h, (convs, ssms, ck, cv)

        h, (convs, ssms, ks, vs) = lax.scan(
            group_body, h, (params["layers"], cache["conv"], cache["ssm"],
                            cache["k"], cache["v"]))
        new_cache.update(conv=convs, ssm=ssms, k=ks, v=vs)
        if "layers_rem" in params:
            h, (cr, sr) = lax.scan(mamba_body, h,
                                   (params["layers_rem"], cache["conv_rem"],
                                    cache["ssm_rem"]))
            new_cache.update(conv_rem=cr, ssm_rem=sr)
    else:
        raise ValueError(cfg.family)

    h = L.apply_norm(cfg.norm, params["final_norm"], h)
    logits = (h[:, 0] @ lm_head_matrix(cfg, params)).astype(jnp.float32)
    logits = logits[:, :cfg.vocab_size]              # drop padded vocab rows
    new_cache["pos"] = pos + 1
    return logits, new_cache
