"""Atomic, mesh-independent checkpointing (fault tolerance substrate).

Guarantees (DESIGN.md §6):
  * atomicity — write to ``step_K.tmp/``, fsync, rename to ``step_K/``; a
    crash mid-write never corrupts the latest checkpoint;
  * mesh independence — arrays are saved LOGICAL (unsharded, gathered via
    jax.device_get); a job restarted on a different mesh/host count reshards
    on load (elastic re-mesh);
  * resume — ``latest_step()`` scans for the newest COMPLETE checkpoint
    (manifest present), so ``--resume auto`` skips partial writes;
  * restart-exactness — the data pipeline is stateless (step-keyed), so
    (params, opt_state, step) is the ENTIRE job state.

Format: one .npz per top-level pytree group + a JSON manifest with the
treedef, shapes, dtypes and a content checksum.
"""
from __future__ import annotations

import json
import hashlib
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        out.append((key, np.asarray(jax.device_get(leaf))))
    return out, treedef


def save(ckpt_dir: str, step: int, state: dict) -> str:
    """state: {'params': ..., 'opt_state': ..., 'extra': {...}}"""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: dict = {"step": step, "groups": {}}
    for group, tree in state.items():
        leaves, _ = _flatten(tree)
        arrs = {k.replace("/", "__"): v for k, v in leaves}
        path = os.path.join(tmp, f"{group}.npz")
        np.savez(path, **arrs)
        h = hashlib.sha256()
        for k in sorted(arrs):
            h.update(k.encode())
            h.update(arrs[k].tobytes())
        manifest["groups"][group] = {
            "keys": sorted(arrs), "sha256": h.hexdigest(),
            "shapes": {k: list(v.shape) for k, v in arrs.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrs.items()},
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: dict,
            shardings: dict | None = None, verify: bool = True) -> dict:
    """Restore into the structure of ``template`` (a matching pytree of
    arrays or ShapeDtypeStructs). ``shardings`` optionally maps group ->
    pytree of NamedSharding for direct sharded placement (elastic re-mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    out = {}
    for group, tree in template.items():
        data = np.load(os.path.join(path, f"{group}.npz"))
        if verify:
            h = hashlib.sha256()
            for k in sorted(data.files):
                h.update(k.encode())
                h.update(data[k].tobytes())
            want = manifest["groups"][group]["sha256"]
            if h.hexdigest() != want:
                raise IOError(f"checkpoint corruption in {group} at {path}")
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        keys = ["/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                         for e in p).replace("/", "__") for p, _ in flat]
        leaves = [data[k] for k in keys]
        if shardings is not None and group in shardings:
            sflat = jax.tree_util.tree_leaves(
                shardings[group],
                is_leaf=lambda x: hasattr(x, "addressable_devices"))
            leaves = [jax.device_put(x, s) for x, s in zip(leaves, sflat)]
        out[group] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_step(ckpt_dir),) if s is not None)
    names = sorted(n for n in os.listdir(ckpt_dir)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    for name in names[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, name))
