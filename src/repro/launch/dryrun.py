"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions, and compiles for the production meshes, and emit
the roofline raw data (memory analysis, FLOPs, HBM bytes, collective bytes).

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep

Results are cached as JSON under experiments/dryrun/. ``--all`` runs each
cell in a SUBPROCESS (fresh XLA state; a failing cell doesn't kill the
sweep). See EXPERIMENTS.md §Dry-run.
"""
# The 512 placeholder devices MUST be configured before any jax import.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.models import layers as LAYERS
LAYERS.TP_AXIS = "model"     # activation sharding constraints live
# DP_AXES set per-mesh in run_cell
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.runtime import sharding as SH
from repro.runtime.analysis import (analytic_hbm_bytes, hlo_collective_bytes,
                                    jaxpr_cost, roofline_terms)

SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,    gb=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   gb=32),
    "decode_32k":  dict(kind="decode",  seq=32768,   gb=128),
    "long_500k":   dict(kind="decode",  seq=524288,  gb=1, seq_shard=True,
                        subquad_only=True),
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
OUT_DIR = os.path.abspath(os.path.join(os.getcwd(), "experiments", "dryrun"))

DTYPE = jnp.bfloat16
TP = 16


def cell_is_applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.get("subquad_only") and not cfg.sub_quadratic:
        return False, ("SKIP: long_500k requires sub-quadratic attention; "
                       f"{arch} is pure full-attention (DESIGN.md §5)")
    return True, ""


def needs_fsdp(cfg) -> bool:
    """params(bf16) + grads(fp32) + AdamW(fp32 m,v) under TP-only sharding
    must fit ~8 GiB of the 16 GiB v5e HBM, else shard over the data axes."""
    return cfg.n_params() * (2 + 4 + 8) / TP > 8e9


def pick_microbatch(cfg, gb: int, seq: int, data_shards: int,
                    budget_bytes: float = 3e9) -> int | None:
    """Largest microbatch whose sqrt-remat residuals fit the budget."""
    import math
    nl = cfg.num_layers
    g = max(1, int(math.sqrt(nl)))
    live = g + nl // g
    full_tok = gb * seq / data_shards
    h_bytes = full_tok * cfg.d_model * 2 * live
    if h_bytes <= budget_bytes:
        return None                                  # no accumulation needed
    mb = gb
    while mb > data_shards:
        cand = mb // 2
        if gb % cand or cand < data_shards:
            break
        mb = cand
        if (mb * seq / data_shards) * cfg.d_model * 2 * live <= budget_bytes:
            return mb
    return mb


def model_flops_for(cfg, kind: str, gb: int, seq: int) -> float:
    n_active = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n_active * gb * seq
    if kind == "prefill":
        return 2.0 * n_active * gb * seq
    return 2.0 * n_active * gb          # decode: one token per sequence


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    kind, seq, gb = sh["kind"], sh["seq"], sh["gb"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    res: dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": kind, "n_chips": int(n_chips)}

    SH.set_axis_sizes(mesh)
    LAYERS.DP_AXES = tuple(a for a in mesh.axis_names if a != "model")
    LAYERS.MESH = mesh
    data_shards = n_chips // TP
    fsdp_axes = tuple(a for a in mesh.axis_names if a != "model") \
        if needs_fsdp(cfg) else ()
    res["fsdp"] = bool(fsdp_axes)

    ap = M.abstract_params(cfg, tp=TP, dtype=DTYPE)
    pspecs = SH.param_specs(ap, fsdp_axes)
    p_shard = SH.shardings(mesh, pspecs)
    t0 = time.time()

    if kind == "train":
        mb = pick_microbatch(cfg, gb, seq, data_shards)
        res["microbatch"] = mb
        aopt = M.abstract_opt_state(ap)
        ospecs = SH.opt_state_specs(pspecs)
        batch = M.train_input_specs(cfg, gb, seq)
        bspec = SH.batch_spec(mesh)
        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        step = M.make_train_step(cfg, tp=TP,
                                 hp=M.TrainHParams(microbatch=mb),
                                 batch_axes=data_axes)
        jstep = jax.jit(
            step,
            in_shardings=(p_shard, SH.shardings(mesh, ospecs),
                          {k: NamedSharding(mesh, bspec) for k in batch}),
            donate_argnums=(0, 1))
        args = (ap, aopt, batch)
    elif kind == "prefill":
        tokens = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
        bspec = SH.batch_spec(mesh)
        step = M.make_prefill(cfg, tp=TP)
        jstep = jax.jit(step, in_shardings=(p_shard,
                                            NamedSharding(mesh, bspec)))
        args = (ap, tokens)
    else:                                            # decode
        seq_shard = bool(sh.get("seq_shard"))
        acache = M.abstract_cache(cfg, gb, seq, tp=TP, dtype=DTYPE)
        cspecs = SH.cache_specs(acache, mesh, seq_shard=seq_shard)
        tokens = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
        tspec = P() if gb == 1 else SH.batch_spec(mesh)
        step = M.make_serve_step(cfg, tp=TP)
        jstep = jax.jit(step,
                        in_shardings=(p_shard, SH.shardings(mesh, cspecs),
                                      NamedSharding(mesh, tspec)),
                        donate_argnums=(1,))
        args = (ap, acache, tokens)

    jax.set_mesh(mesh)          # context mesh for with_sharding_constraint
    with mesh:
        lowered = jstep.lower(*args)
        res["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t0, 2)

    # analytic HBM-fit breakdown (XLA-CPU memory_analysis is a conservative
    # upper bound: the CPU scheduler lacks TPU's memory-saving passes; the
    # fit claim uses this auditable model, both numbers are recorded)
    p_bytes = cfg.n_params()
    state_gb = 0.0
    if kind == "train":
        state_gb = p_bytes * (2 + 4 + 8) / (n_chips if res["fsdp"] else TP) \
            / 2**30
        mbsz = res.get("microbatch") or gb
        import math as _m
        g_ = max(1, int(_m.sqrt(cfg.num_layers)))
        live = g_ + cfg.num_layers // g_
        resid_gb = (mbsz * seq / data_shards) * cfg.d_model * 2 * live / 2**30
    else:
        state_gb = p_bytes * 2 / (n_chips if res["fsdp"] else TP) / 2**30
        resid_gb = 0.0
    res["analytic_fit"] = {
        "state_gb_per_chip": round(state_gb, 2),
        "remat_residuals_gb": round(resid_gb, 2),
        "fits_16gb": bool(state_gb + resid_gb + 2.0 < 16.0),
    }

    ma = compiled.memory_analysis()
    res["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_per_device_gb": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    }
    xla_ca = compiled.cost_analysis() or {}
    res["xla_cost_analysis"] = {k: float(v) for k, v in xla_ca.items()
                                if k in ("flops", "bytes accessed")}

    # scan-aware global flops/bytes (see runtime/analysis.py)
    cost = jaxpr_cost(step, *args)
    res["jaxpr_cost"] = cost

    coll = hlo_collective_bytes(compiled.as_text())
    res["collectives"] = coll

    hbm = analytic_hbm_bytes(cfg, kind, gb, seq, n_chips, TP)
    res["analytic_hbm_bytes_per_chip"] = hbm
    res["roofline"] = roofline_terms(
        cost["flops"], hbm * n_chips, coll["total_bytes_tpu"],
        n_chips, model_flops_for(cfg, kind, gb, seq))
    return res


def cell_path(arch: str, shape: str, mesh_tag: str) -> str:
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_tag}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                ok, why = cell_is_applicable(arch, shape)
                meshes = ["single", "multi"]
                for mesh_tag in meshes:
                    path = cell_path(arch, shape, mesh_tag)
                    if os.path.exists(path) and not args.force:
                        continue
                    if not ok:
                        with open(path, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "mesh": mesh_tag, "skipped": why}, f)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_tag]
                    if args.force:
                        cmd.append("--force")
                    print(f"=== {arch} x {shape} x {mesh_tag}", flush=True)
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_tag))
        print("FAILURES:", failures or "none")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    ok, why = cell_is_applicable(args.arch, args.shape)
    mesh_tag = args.mesh
    path = cell_path(args.arch, args.shape, mesh_tag)
    if os.path.exists(path) and not args.force:
        print(f"cached: {path}")
        return
    if not ok:
        print(why)
        return
    try:
        res = run_cell(args.arch, args.shape, multi_pod=(mesh_tag == "multi"))
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    r = res["roofline"]
    print(json.dumps({k: res[k] for k in ("arch", "shape", "mesh",
                                          "lower_s", "compile_s")}))
    print(f"memory/device: {res['memory']['peak_per_device_gb']} GiB")
    print(f"terms: compute={r['compute_s']:.4g}s memory={r['memory_s']:.4g}s "
          f"collective={r['collective_s']:.4g}s dominant={r['dominant']} "
          f"useful={r['useful_ratio']:.3f} roofline_mfu={r['roofline_mfu']:.3f}")


if __name__ == "__main__":
    main()
