"""Training launcher: config-driven, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/run1 \
        --resume auto

Runs on whatever devices exist (CPU smoke -> full pod: same code path; the
mesh adapts via runtime.fault_tolerance.elastic_mesh). Features wired in:
atomic checkpoints + auto-resume, stateless data pipeline (restart-exact),
StepGuard retries, heartbeat/straggler log, optional int8 gradient
compression with error feedback, MoE Sinkhorn/topk router flag.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, batch_at_step
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime import compression as C
from repro.runtime.fault_tolerance import Heartbeat, StepGuard


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--router", choices=["sinkhorn", "topk"], default=None)
    ap.add_argument("--grad-compression", choices=["none", "int8"],
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "none"], default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.router and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=args.router))

    hp = M.TrainHParams(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps, microbatch=args.microbatch)
    step_fn = jax.jit(M.make_train_step(cfg, hp=hp))
    dc = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.global_batch,
                    seq_len=args.seq_len, seed=args.seed)

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw.init(params)
    start = 0
    if args.ckpt_dir and args.resume == "auto":
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            tmpl = {"params": params, "opt": opt}
            got = ckpt.restore(args.ckpt_dir, latest, tmpl)
            params, opt = got["params"], got["opt"]
            start = latest
            print(f"resumed from step {start}")

    residual = C.zero_residual(params) \
        if args.grad_compression == "int8" else None
    guard = StepGuard()
    hb = Heartbeat()
    t_start = time.time()

    for step in range(start, args.steps):
        batch = batch_at_step(dc, step)
        t0 = time.time()

        def do_step():
            return step_fn(params, opt, batch)
        params, opt, metrics = guard.run(do_step)
        if residual is not None:
            # NOTE: compression hooks into grads inside the step for the
            # pod-crossing reduction; applied here as a post-step pass in
            # the single-host driver to exercise the code path.
            pass
        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            raise SystemExit(f"poison step at {step}: loss={loss}")
        hb.record(0, time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(json.dumps({"step": step, "loss": round(loss, 4),
                              "ce": round(float(metrics["ce"]), 4),
                              "grad_norm": round(float(metrics["grad_norm"]), 3),
                              "lr": float(metrics["lr"]),
                              "s_per_step": round(time.time() - t0, 3)}),
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(args.ckpt_dir, step + 1,
                             {"params": params, "opt": opt})
            ckpt.prune_old(args.ckpt_dir, keep=3)
            print(f"checkpoint: {path}")

    print(f"done: {args.steps - start} steps in "
          f"{time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
