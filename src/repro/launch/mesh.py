"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the real device count).

Topology: TPU v5e pods of 256 chips (16x16 ICI torus). Single-pod mesh is
(data=16, model=16); multi-pod adds a leading "pod" axis over DCN. TP stays
inside a pod (ICI); only data-parallel gradient reductions cross pods —
the DCN-friendly layout (optionally int8-compressed, runtime/compression).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_devices: int | None = None, tp: int = 1):
    """Small mesh for tests/examples on whatever devices exist."""
    n = n_devices or len(jax.devices())
    assert n % tp == 0
    return jax.make_mesh((n // tp, tp), ("data", "model"))


# TPU runtime flags the real launch would set (documented here; no-ops on
# the CPU dry-run container):
TPU_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_latency_hiding_scheduler=true",   # overlap comm/compute
    "--xla_tpu_megacore_fusion_allow_ags=true",
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
])
