"""Serving launchers.

Two servers, matching the paper's two workload kinds:

LM decode server (assigned archs):
    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b \
        --reduced --batch 4 --steps 32

WMD query server (the paper's own workload — query documents against the
whole corpus through the persistent batched engine; ``--batch-queries Q``
scores Q stream requests per fused solve; ``--top-k K`` switches to the
staged retrieval pipeline — prune with ``--prune`` bounds, Sinkhorn-solve
only the surviving candidates, rank):
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 2048 \
        --impl kernel --batch-queries 8
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 2048 \
        --top-k 10 --prune rwmd
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 8192 \
        --top-k 10 --prune ivf+wcd+rwmd --nprobe 8   # sub-O(Q*N) prune
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.models import transformer as T


def serve_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, args.batch, max_len=args.steps + 8)
    step = jax.jit(M.make_serve_step(cfg))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    times = []
    for i in range(args.steps):
        t0 = time.time()
        tok, logits, cache = step(params, cache, tok)
        tok.block_until_ready()
        times.append(time.time() - t0)
    times = np.asarray(times[2:]) * 1e3
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "steps": args.steps,
        "ms_per_token_p50": round(float(np.percentile(times, 50)), 2),
        "ms_per_token_p99": round(float(np.percentile(times, 99)), 2),
        "tokens_per_s": round(args.batch / (times.mean() / 1e3), 1),
    }))


def serve_wmd(args) -> None:
    from repro.core import WmdEngine, build_index
    from repro.data.corpus import make_corpus
    from repro.data.pipeline import wmd_request_stream
    corpus = make_corpus(vocab_size=args.vocab, embed_dim=args.embed_dim,
                         n_docs=args.n_docs, n_queries=8, seed=0)
    # corpus side frozen ONCE; every request after this touches only its
    # own (v_r, ...) slice of work ('auto'/numeric strings parsed by
    # build_index itself)
    index = build_index(corpus.docs, corpus.vecs,
                        n_clusters=args.n_clusters)
    engine = WmdEngine(index, lam=args.lam, n_iter=args.n_iter,
                       impl=args.impl,
                       tol=args.tol if args.tol > 0 else None,
                       check_every=args.check_every,
                       precision=args.precision, scope=args.scope,
                       warm_start=args.warm_start)
    reqs = wmd_request_stream(corpus)
    bq = max(1, args.batch_queries)
    prune = None if args.prune == "none" else args.prune
    nprobe = args.nprobe if args.nprobe > 0 else None
    times = []
    solved = []
    for i in range(args.steps):
        batch = [next(reqs) for _ in range(bq)]
        t0 = time.time()
        if args.top_k > 0:
            res = engine.search(batch, args.top_k, prune=prune,
                                nprobe=nprobe)
            jax.block_until_ready(res.distances)
            solved.append(float(res.solved.mean()))
            if i == 0:
                print(f"query 0 -> top-3 docs {res.indices[0][:3].tolist()}")
        else:
            d = engine.query_batch(batch)
            jax.block_until_ready(d)
            if i == 0:
                top = np.argsort(np.asarray(d[0]))[:3]
                print(f"query 0 -> top-3 docs {top.tolist()}")
        times.append(time.time() - t0)
    times = np.asarray(times[1:]) * 1e3
    p50 = float(np.percentile(times, 50))   # median: late batches may still
    rec = {                                 # compile fresh bucket shapes
        "workload": "wmd_topk" if args.top_k > 0 else "wmd_batched",
        "impl": args.impl,
        "n_docs": args.n_docs, "vocab": args.vocab, "batch_queries": bq,
        "ms_per_batch_p50": round(p50, 2),
        "queries_per_s": round(bq / (p50 / 1e3), 1),
        "docs_per_s": round(bq * args.n_docs / (p50 / 1e3), 0),
        "precision": engine.precision.name,
    }
    iters = engine.iter_stats()
    if args.tol > 0 and iters.size:
        rec["tol"] = args.tol
        rec["scope"] = args.scope
        rec["solve_iters_mean"] = round(float(iters.mean()), 1)
        rec["solve_iters_max"] = int(iters.max())
        # per-stage realized counts (ISSUE 5): the warm-start win is the
        # "survivor" series relative to the cold "seed" solves
        by_stage = engine.iter_stats_by_stage()
        for st, arr in by_stage.items():
            if arr.size:
                rec[f"solve_iters_{st}_mean"] = round(float(arr.mean()), 1)
        if args.warm_start:
            rec["warm_start"] = True
    if args.top_k > 0:
        rec["top_k"] = args.top_k
        rec["prune"] = args.prune
        rec["solved_frac"] = round(float(np.mean(solved)) / args.n_docs, 4)
        if args.prune.startswith("ivf"):
            rec["n_clusters"] = index.clusters.n_clusters
            rec["nprobe"] = nprobe if nprobe else index.clusters.n_clusters
    print(json.dumps(rec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--wmd", action="store_true")
    ap.add_argument("--impl", default="sparse")
    ap.add_argument("--batch-queries", type=int, default=8)
    ap.add_argument("--top-k", type=int, default=0,
                    help="> 0: staged top-k retrieval (prune->solve->rank) "
                         "instead of exhaustive scoring")
    ap.add_argument("--prune", default="rwmd",
                    choices=["none", "wcd", "rwmd", "wcd+rwmd", "ivf+wcd",
                             "ivf+rwmd", "ivf+wcd+rwmd"],
                    help="lower bound / cascade for the prune stage "
                         "(with --top-k)")
    ap.add_argument("--nprobe", type=int, default=0,
                    help="ivf cascades: probe this many clusters per query "
                         "(0 = all = exact top-k; fewer trades recall for "
                         "prune speed)")
    ap.add_argument("--n-clusters", default=None,
                    help="IVF cluster count at index build (default: "
                         "sqrt(n_docs); 'auto' sweeps cluster-radius "
                         "statistics — dedup-style corpora want more)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "log", "bf16+log"],
                    help="solve-stage precision policy: bf16 GEMMs with "
                         "fp32 accumulation and/or the log-domain kernel "
                         "(underflow-free at any lam)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="> 0: convergence-adaptive solve — exit the "
                         "Sinkhorn loop at this relative doc-marginal "
                         "residual; --n-iter becomes a cap (realized counts "
                         "land on 1 + k*check-every)")
    ap.add_argument("--check-every", type=int, default=4,
                    help="adaptive solve: iterations between residual "
                         "checks")
    ap.add_argument("--scope", default="query",
                    choices=["chunk", "query"],
                    help="adaptive-exit granularity: 'query' scopes each "
                         "query's residual to its own candidate docs and "
                         "freezes it on convergence (one stubborn query "
                         "no longer stalls its chunkmates); 'chunk' keeps "
                         "the chunk-global scalar exit")
    ap.add_argument("--warm-start", action="store_true",
                    help="warm-start survivor solves from the seed "
                         "solve's converged per-query profile (with "
                         "--tol; sound when solves converge, see "
                         "WmdEngine docs)")
    ap.add_argument("--n-docs", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--embed-dim", type=int, default=64)
    # this synthetic corpus' distance scale is ~sqrt(2*embed_dim) ~ 11;
    # lam must keep lam*dist < ~87 or K underflows (the engine now raises)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--n-iter", type=int, default=15)
    args = ap.parse_args()
    if args.wmd:
        serve_wmd(args)
    else:
        assert args.arch, "--arch required for LM serving"
        serve_lm(args)


if __name__ == "__main__":
    main()
