"""Serving launchers.

Two servers, matching the paper's two workload kinds:

LM decode server (assigned archs):
    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b \
        --reduced --batch 4 --steps 32

WMD query server (the paper's own workload — query documents against the
whole corpus through the persistent batched engine; ``--batch-queries Q``
scores Q stream requests per fused solve; ``--top-k K`` switches to the
staged retrieval pipeline — prune with ``--prune`` bounds, Sinkhorn-solve
only the surviving candidates, rank):
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 2048 \
        --impl kernel --batch-queries 8
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 2048 \
        --top-k 10 --prune rwmd
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 8192 \
        --top-k 10 --prune ivf+wcd+rwmd --nprobe 8   # sub-O(Q*N) prune
    PYTHONPATH=src python -m repro.launch.serve --wmd --n-docs 8192 \
        --top-k 10 --prune ivf+pivot+wcd+rwmd --mode refine \
        --refine-factor 4      # rank-then-refine: bounded solve budget

Async serving runtime (``--serve``, ISSUE 6): the long-lived front-end —
deadline-or-full micro-batching, bounded-queue backpressure, tiered
degradation under load, per-dispatch retry/watchdog, optional seeded
fault injection. Drives an open-loop request stream at ``--rate`` qps and
prints one JSON line per request plus a summary record:
    PYTHONPATH=src python -m repro.launch.serve --wmd --serve \
        --n-docs 2048 --top-k 10 --requests 64 --rate 50
    PYTHONPATH=src python -m repro.launch.serve --wmd --serve \
        --requests 64 --rate 200 --inject-transient-rate 0.2 \
        --inject-poison-rate 0.05 --inject-seed 3     # chaos drill

Shard-level fault tolerance (ISSUE 9): with ``--shards N --serve`` the
fan-out is deadline-bounded (``--shard-timeout-ms``) and shard-site
faults can be injected (``--inject-shard-crash`` etc.); responses
covering fewer docs than the full corpus are tagged ``partial`` with
honest coverage. ``--snapshot-dir`` writes per-shard snapshots after
warmup so a dead shard can be restored bit-compatibly. SIGTERM/SIGINT
drain the admission queue (graceful shutdown) instead of dropping
in-flight work:
    PYTHONPATH=src python -m repro.launch.serve --wmd --serve --shards 2 \
        --n-docs 2048 --top-k 10 --requests 64 --shard-timeout-ms 2000 \
        --inject-shard-crash 1 --inject-shard-crash-after 8 \
        --snapshot-dir /tmp/wmd-snap
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.models import transformer as T


def serve_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, args.batch, max_len=args.steps + 8)
    step = jax.jit(M.make_serve_step(cfg))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    times = []
    for i in range(args.steps):
        t0 = time.time()
        tok, logits, cache = step(params, cache, tok)
        tok.block_until_ready()
        times.append(time.time() - t0)
    times = np.asarray(times[2:]) * 1e3
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "steps": args.steps,
        "ms_per_token_p50": round(float(np.percentile(times, 50)), 2),
        "ms_per_token_p99": round(float(np.percentile(times, 99)), 2),
        "tokens_per_s": round(args.batch / (times.mean() / 1e3), 1),
    }))


def _build_wmd_engine(args, corpus):
    """Engine construction shared by serve_wmd/serve_async: the
    single-device engine by default; with ``--shards N`` the corpus is
    partitioned cluster-aligned over an N-device mesh. ``main()`` forces
    host-platform devices right after argparse (before the first jax
    array op); the ``ensure_host_devices`` here re-validates the count
    for callers that enter below ``main()``."""
    kw = dict(lam=args.lam, n_iter=args.n_iter, impl=args.impl,
              tol=args.tol if args.tol > 0 else None,
              check_every=args.check_every, precision=args.precision,
              scope=args.scope, warm_start=args.warm_start)
    if getattr(args, "kcache_slots", -1) > 0:
        # explicit opt-in at engine build; -1 leaves it to the serving
        # runtime's default-on behaviour (ServeConfig.kcache_slots), 0
        # disables there too
        kw["kcache_slots"] = args.kcache_slots
    if args.shards > 1:
        from repro.core import ShardedWmdEngine, shard_corpus
        from repro.runtime.sharding import ensure_host_devices
        ensure_host_devices(args.shards)
        sindex = shard_corpus(corpus.docs, corpus.vecs, args.shards,
                              n_clusters=args.n_clusters)
        timeout = getattr(args, "shard_timeout_ms", 0.0)
        return ShardedWmdEngine(
            sindex,
            shard_timeout_s=timeout / 1e3 if timeout > 0 else None,
            snapshot_dir=getattr(args, "snapshot_dir", None), **kw)
    from repro.core import WmdEngine, build_index
    # corpus side frozen ONCE; every request after this touches only its
    # own (v_r, ...) slice of work ('auto'/numeric strings parsed by
    # build_index itself)
    index = build_index(corpus.docs, corpus.vecs,
                        n_clusters=args.n_clusters)
    return WmdEngine(index, **kw)


def serve_wmd(args) -> None:
    from repro.core.sinkhorn import LamUnderflowError
    from repro.data.corpus import make_corpus
    from repro.data.pipeline import wmd_request_stream
    corpus = make_corpus(vocab_size=args.vocab, embed_dim=args.embed_dim,
                         n_docs=args.n_docs, n_queries=8, seed=0)
    engine = _build_wmd_engine(args, corpus)
    reqs = wmd_request_stream(corpus)
    bq = max(1, args.batch_queries)
    prune = None if args.prune == "none" else args.prune
    nprobe = args.nprobe if args.nprobe > 0 else None

    def score(batch):
        if args.top_k > 0:
            res = engine.search(batch, args.top_k, prune=prune,
                                nprobe=nprobe, mode=args.mode,
                                refine_factor=args.refine_factor)
            jax.block_until_ready(res.distances)
            return res
        d = engine.query_batch(batch)
        jax.block_until_ready(d)
        return d

    times = []
    solved = []
    underflows = 0
    for i in range(args.steps):
        batch = [next(reqs) for _ in range(bq)]
        t0 = time.time()
        try:
            out = score(batch)
        except LamUnderflowError:
            # per-request isolation (ISSUE 6 satellite): lam underflow is
            # deterministic for the query that hit it — re-score one at a
            # time so its batchmates still get answers, and emit the
            # failing request's diagnostics as a structured JSON error
            # instead of killing the server
            out = None
            for qi, q in enumerate(batch):
                try:
                    sub = score([q])
                    out = sub if out is None else out
                except LamUnderflowError as e:
                    underflows += 1
                    print(json.dumps({
                        "step": i, "query": qi, "ok": False,
                        "error": {"code": "lam_underflow",
                                  "underflow_report": str(e)}}))
        if i == 0 and out is not None:
            if args.top_k > 0:
                print(f"query 0 -> top-3 docs "
                      f"{out.indices[0][:3].tolist()}")
            else:
                top = np.argsort(np.asarray(out[0]))[:3]
                print(f"query 0 -> top-3 docs {top.tolist()}")
        if args.top_k > 0 and out is not None:
            solved.append(float(out.solved.mean()))
        times.append(time.time() - t0)
    times = np.asarray(times[1:]) * 1e3
    p50 = float(np.percentile(times, 50))   # median: late batches may still
    rec = {                                 # compile fresh bucket shapes
        "workload": "wmd_topk" if args.top_k > 0 else "wmd_batched",
        "impl": args.impl,
        "n_docs": args.n_docs, "vocab": args.vocab, "batch_queries": bq,
        "ms_per_batch_p50": round(p50, 2),
        "queries_per_s": round(bq / (p50 / 1e3), 1),
        "docs_per_s": round(bq * args.n_docs / (p50 / 1e3), 0),
        "precision": engine.precision.name,
        "iter_stats_dropped": engine.iter_stats_dropped,
    }
    if underflows:
        rec["underflow_errors"] = underflows
    iters = engine.iter_stats()
    if args.tol > 0 and iters.size:
        rec["tol"] = args.tol
        rec["scope"] = args.scope
        rec["solve_iters_mean"] = round(float(iters.mean()), 1)
        rec["solve_iters_max"] = int(iters.max())
        # per-stage realized counts (ISSUE 5): the warm-start win is the
        # "survivor" series relative to the cold "seed" solves
        by_stage = engine.iter_stats_by_stage()
        for st, arr in by_stage.items():
            if arr.size:
                rec[f"solve_iters_{st}_mean"] = round(float(arr.mean()), 1)
        if args.warm_start:
            rec["warm_start"] = True
    if args.top_k > 0:
        rec["top_k"] = args.top_k
        rec["prune"] = args.prune
        if args.mode != "exact":
            rec["mode"] = args.mode
            rec["refine_factor"] = args.refine_factor
        if solved:
            rec["solved_frac"] = round(float(np.mean(solved))
                                       / args.n_docs, 4)
        if args.prune.startswith("ivf"):
            counts = getattr(engine, "cluster_counts", None) \
                or (engine.index.clusters.n_clusters,)
            rec["n_clusters"] = (list(counts) if len(counts) > 1
                                 else counts[0])
            rec["nprobe"] = nprobe if nprobe else \
                ("all" if len(counts) > 1 else counts[0])
    if getattr(engine, "n_shards", 1) > 1:
        rec["shards"] = engine.n_shards
        rec["docs_per_shard"] = list(engine.docs_per_shard)
    print(json.dumps(rec))


def serve_async(args) -> None:
    """ISSUE 6 front-end: drive the long-lived :class:`ServingRuntime`
    open-loop and print per-request JSON lines + a summary record."""
    from repro.data.corpus import make_corpus
    from repro.data.pipeline import wmd_request_stream
    from repro.runtime.serving import (FaultInjector, ServeConfig,
                                       ServingRuntime, poisson_arrivals,
                                       run_open_loop)
    corpus = make_corpus(vocab_size=args.vocab, embed_dim=args.embed_dim,
                         n_docs=args.n_docs, n_queries=8, seed=0)
    engine = _build_wmd_engine(args, corpus)
    injector = None
    if args.inject_latency_rate or args.inject_transient_rate \
            or args.inject_poison_rate or args.inject_shard_latency_rate \
            or args.inject_shard_transient_rate \
            or args.inject_shard_crash >= 0:
        injector = FaultInjector(
            latency_rate=args.inject_latency_rate,
            latency_s=args.inject_latency_ms / 1e3,
            transient_rate=args.inject_transient_rate,
            poison_rate=args.inject_poison_rate,
            shard_latency_rate=args.inject_shard_latency_rate,
            shard_latency_s=args.inject_shard_latency_ms / 1e3,
            shard_transient_rate=args.inject_shard_transient_rate,
            crash_shard=args.inject_shard_crash,
            crash_after=args.inject_shard_crash_after,
            seed=args.inject_seed)
    cfg = ServeConfig(
        max_batch=max(1, args.batch_queries),
        window_s=args.window_ms / 1e3, max_queue=args.max_queue,
        deadline_s=args.deadline_ms / 1e3 if args.deadline_ms > 0 else None,
        prune="rwmd" if args.prune == "none" else args.prune,
        nprobe=args.nprobe if args.nprobe > 0 else None,
        refine_factor=args.refine_factor,
        kcache_slots=(args.kcache_slots if args.kcache_slots >= 0
                      else ServeConfig.kcache_slots))
    runtime = ServingRuntime(engine, cfg, injector=injector)
    # warm the compile caches OUTSIDE the measured stream: one dispatch per
    # tier (first-request latency would otherwise be compile time)
    reqs = wmd_request_stream(corpus)
    warm = [next(reqs) for _ in range(2)]
    for tier in runtime.tiers:
        if tier.solve:
            engine.search(warm, max(1, args.top_k), prune=cfg.prune,
                          nprobe=tier.nprobe, mode=tier.mode,
                          refine_factor=tier.refine_factor or 4)
        else:
            from repro.runtime.serving import rwmd_topk
            rwmd_topk(engine, warm, max(1, args.top_k))
    engine.reset_iter_stats()
    if args.snapshot_dir and hasattr(engine, "snapshot"):
        # take the recovery snapshot AFTER warmup so a mid-stream
        # restore_shard() rejoins with compile caches already primed
        engine.snapshot()
    n = max(1, args.requests)
    queries = [next(reqs) for _ in range(n)]
    arrivals = poisson_arrivals(n, rate_per_s=args.rate, seed=1)
    # handle_signals: SIGTERM/SIGINT drain the admission queue instead of
    # killing in-flight futures — late arrivals get `shutting_down`
    responses, stats = run_open_loop(runtime, queries, arrivals,
                                     k=max(1, args.top_k),
                                     handle_signals=True)
    for r in responses:
        print(json.dumps(r.to_json()))
    lat = np.asarray([r.queue_ms + r.service_ms for r in responses
                      if r.ok])
    span = float(arrivals[-1]) + max(
        (r.service_ms for r in responses), default=0.0) / 1e3
    print(json.dumps({
        "workload": "wmd_serve", "impl": args.impl,
        "n_docs": args.n_docs, "requests": n, "rate_qps": args.rate,
        "latency_ms_p50": round(float(np.percentile(lat, 50)), 2)
        if lat.size else None,
        "latency_ms_p99": round(float(np.percentile(lat, 99)), 2)
        if lat.size else None,
        "throughput_qps": round(n / span, 1) if span > 0 else None,
        "stats": stats,
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--wmd", action="store_true")
    ap.add_argument("--impl", default="sparse")
    ap.add_argument("--batch-queries", type=int, default=8)
    ap.add_argument("--top-k", type=int, default=0,
                    help="> 0: staged top-k retrieval (prune->solve->rank) "
                         "instead of exhaustive scoring")
    ap.add_argument("--prune", default="rwmd",
                    choices=["none", "wcd", "rwmd", "wcd+rwmd", "ivf+wcd",
                             "ivf+rwmd", "ivf+wcd+rwmd",
                             "ivf+pivot+wcd+rwmd", "ivf+pivot+rwmd"],
                    help="lower bound / cascade for the prune stage "
                         "(with --top-k); 'pivot' rungs read the index's "
                         "precomputed pivot-word triangle bounds")
    ap.add_argument("--nprobe", type=int, default=0,
                    help="ivf cascades: probe this many clusters per query "
                         "(0 = all = exact top-k; fewer trades recall for "
                         "prune speed)")
    ap.add_argument("--mode", default="exact",
                    choices=["exact", "refine"],
                    help="with --top-k: 'refine' ranks candidates by the "
                         "cascade's lower bound and Sinkhorn-solves only "
                         "the top refine-factor*k per query (distances "
                         "exact, membership approximate; recall measured "
                         "in fig13)")
    ap.add_argument("--refine-factor", type=int, default=4,
                    help="--mode refine: solve budget multiple (k' = "
                         "refine_factor*k; at a covering factor the "
                         "result equals the exact path)")
    ap.add_argument("--shards", type=int, default=0,
                    help="> 1: partition the corpus into this many "
                         "cluster-aligned doc shards over a device mesh "
                         "(forces host-platform CPU devices when no real "
                         "accelerators exist); per-shard cascades merge "
                         "through one top-k collective")
    ap.add_argument("--n-clusters", default=None,
                    help="IVF cluster count at index build (default: "
                         "sqrt(n_docs); 'auto' sweeps cluster-radius "
                         "statistics — dedup-style corpora want more)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "log", "bf16+log"],
                    help="solve-stage precision policy: bf16 GEMMs with "
                         "fp32 accumulation and/or the log-domain kernel "
                         "(underflow-free at any lam)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="> 0: convergence-adaptive solve — exit the "
                         "Sinkhorn loop at this relative doc-marginal "
                         "residual; --n-iter becomes a cap (realized counts "
                         "land on 1 + k*check-every)")
    ap.add_argument("--check-every", type=int, default=4,
                    help="adaptive solve: iterations between residual "
                         "checks")
    ap.add_argument("--scope", default="query",
                    choices=["chunk", "query"],
                    help="adaptive-exit granularity: 'query' scopes each "
                         "query's residual to its own candidate docs and "
                         "freezes it on convergence (one stubborn query "
                         "no longer stalls its chunkmates); 'chunk' keeps "
                         "the chunk-global scalar exit")
    ap.add_argument("--warm-start", action="store_true",
                    help="warm-start survivor solves from the seed "
                         "solve's converged per-query profile (with "
                         "--tol; sound when solves converge, see "
                         "WmdEngine docs)")
    ap.add_argument("--serve", action="store_true",
                    help="long-lived async serving runtime (ISSUE 6): "
                         "deadline-or-full micro-batching, backpressure, "
                         "tiered degradation, fault injection")
    ap.add_argument("--requests", type=int, default=32,
                    help="--serve: open-loop request count")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="--serve: offered load (requests/s)")
    ap.add_argument("--window-ms", type=float, default=10.0,
                    help="--serve: coalescer deadline (a partial batch "
                         "dispatches once its oldest member waited this)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="--serve: admission bound (queued + in flight); "
                         "arrivals beyond it get structured rejections")
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="--serve: per-request deadline budget "
                         "(0 = none); blown budgets degrade, not drop")
    ap.add_argument("--kcache-slots", type=int, default=-1,
                    help="cross-request cdist-row cache capacity (ISSUE "
                         "10). -1 (default): engine built without a cache "
                         "but --serve enables its default "
                         "(ServeConfig.kcache_slots); 0: disabled "
                         "everywhere; > 0: enabled at engine build with "
                         "this many device-resident (V,) rows. Results "
                         "are bit-exact either way; requires "
                         "--impl sparse")
    ap.add_argument("--inject-latency-rate", type=float, default=0.0,
                    help="fault injection: per-attempt probability of "
                         "added dispatch latency")
    ap.add_argument("--inject-latency-ms", type=float, default=50.0)
    ap.add_argument("--inject-transient-rate", type=float, default=0.0,
                    help="fault injection: per-dispatch probability of a "
                         "transient first-attempt failure (retried)")
    ap.add_argument("--inject-poison-rate", type=float, default=0.0,
                    help="fault injection: per-request probability of a "
                         "poison request (isolated, structured error)")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="fault injection: deterministic replay seed")
    ap.add_argument("--shard-timeout-ms", type=float, default=30000.0,
                    help="sharded fan-out (--shards > 1): per-dispatch "
                         "deadline; shards that miss it are excluded from "
                         "the merge and the response is tagged partial "
                         "(0 = wait forever)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="sharded engine: write per-shard snapshots here "
                         "after warmup; restore_shard() rebuilds a dead "
                         "shard from them (bit-compatible at nprobe=None)")
    ap.add_argument("--inject-shard-latency-rate", type=float, default=0.0,
                    help="fault injection: per-shard-attempt probability "
                         "of added latency inside the fan-out")
    ap.add_argument("--inject-shard-latency-ms", type=float, default=50.0)
    ap.add_argument("--inject-shard-transient-rate", type=float,
                    default=0.0,
                    help="fault injection: per-shard-attempt probability "
                         "of a transient failure (burns a shard retry)")
    ap.add_argument("--inject-shard-crash", type=int, default=-1,
                    help="fault injection: crash this shard id on every "
                         "attempt from --inject-shard-crash-after "
                         "onwards (-1 = off); responses go partial with "
                         "honest coverage until the shard is restored")
    ap.add_argument("--inject-shard-crash-after", type=int, default=0,
                    help="fan-out sequence number the crash window "
                         "opens at")
    ap.add_argument("--n-docs", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--embed-dim", type=int, default=64)
    # this synthetic corpus' distance scale is ~sqrt(2*embed_dim) ~ 11;
    # lam must keep lam*dist < ~87 or K underflows (the engine now raises)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--n-iter", type=int, default=15)
    args = ap.parse_args()
    if args.shards > 1:
        # must run before make_corpus/engine build does the first jax
        # array op — forcing host devices after backend init is a no-op
        from repro.runtime.sharding import ensure_host_devices
        ensure_host_devices(args.shards)
    if args.serve:
        serve_async(args)
    elif args.wmd:
        serve_wmd(args)
    else:
        assert args.arch, "--arch required for LM serving"
        serve_lm(args)


if __name__ == "__main__":
    main()
