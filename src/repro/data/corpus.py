"""Synthetic WMD corpus generation + nnz-balanced sharding.

The paper's dataset (crawl-300d-2M embeddings subset, V=100k, w=300; dbpedia
documents, N=5000, density 0.0035%) is reproduced *statistically*: Zipf-drawn
word ids, document lengths matching the paper's 19-43 word queries and ~35
nnz/doc corpus, and Gaussian embeddings (WMD only consumes pairwise
distances, so any fixed embedding distribution exercises the identical
compute). Generation is deterministic in the seed.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.sparse import PaddedDocs, padded_docs_from_lists


class WmdCorpus(NamedTuple):
    vecs: np.ndarray        # (V, w) embeddings
    docs: PaddedDocs        # N target documents (ELL)
    queries: np.ndarray     # (Q, V) full-vocab frequency rows, normalized


def make_corpus(vocab_size: int = 4096, embed_dim: int = 64,
                n_docs: int = 512, n_queries: int = 4,
                words_per_doc: tuple[int, int] = (8, 40),
                max_words: int | None = None, zipf_a: float = 1.4,
                seed: int = 0, dtype=np.float32) -> WmdCorpus:
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((vocab_size, embed_dim)).astype(dtype)

    def draw_doc():
        n_words = int(rng.integers(words_per_doc[0], words_per_doc[1] + 1))
        # zipf over the vocab, clipped; unique ids with counts
        ids = np.minimum(rng.zipf(zipf_a, size=n_words * 2), vocab_size) - 1
        ids = rng.permutation(vocab_size)[ids % vocab_size]  # decorrelate
        uniq, counts = np.unique(ids[:n_words], return_counts=True)
        return uniq.astype(np.int32), counts.astype(np.float64)

    ids, counts = zip(*[draw_doc() for _ in range(n_docs)])
    docs = padded_docs_from_lists(list(ids), list(counts),
                                  max_words=max_words, dtype=dtype)

    queries = np.zeros((n_queries, vocab_size), dtype=dtype)
    for q in range(n_queries):
        uniq, cnt = draw_doc()
        queries[q, uniq] = cnt / cnt.sum()
    return WmdCorpus(vecs=vecs, docs=docs, queries=queries)


def paper_corpus(seed: int = 0) -> WmdCorpus:
    """Paper-scale corpus: V=100k, w=300, N=5000, ~35 nnz/doc, 19-43-word
    queries (the shapes behind Table 1 / Fig 5-7)."""
    return make_corpus(vocab_size=100_000, embed_dim=300, n_docs=5000,
                       n_queries=10, words_per_doc=(19, 43), seed=seed)


def shard_balanced(docs: PaddedDocs, n_shards: int) -> PaddedDocs:
    """nnz-balanced document order (the paper's per-thread binary-search
    split, moved to ingest): sort docs by nnz, deal round-robin to shards,
    concatenate — every contiguous 1/n_shards slice then has ~equal nnz.
    Pads N up to a multiple of n_shards with empty docs."""
    idx = np.asarray(docs.idx)
    val = np.asarray(docs.val)
    n, length = idx.shape
    n_pad = -(-n // n_shards) * n_shards
    if n_pad != n:
        idx = np.concatenate([idx, np.zeros((n_pad - n, length), idx.dtype)])
        val = np.concatenate([val, np.zeros((n_pad - n, length), val.dtype)])
        # padded docs get one dummy word of mass 1 to keep x > 0
        val[n:, 0] = 1.0
    nnz = (val > 0).sum(axis=1)
    order = np.argsort(-nnz, kind="stable")
    shards = [order[s::n_shards] for s in range(n_shards)]
    new_order = np.concatenate(shards)
    return PaddedDocs(idx=idx[new_order], val=val[new_order])
