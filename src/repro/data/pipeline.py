"""Deterministic stateless token pipeline.

Restart-exact by construction: batch(step) is a pure function of
(seed, step, shape) via counter-mode hashing (threefry), so a job resumed
from a checkpoint at step k replays the identical stream with NO pipeline
state in the checkpoint — the fault-tolerance property the checkpointer
relies on (DESIGN.md §6). Per-host sharding: each host materializes only its
slice of the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0


def batch_at_step(dc: DataConfig, step: int, host_id: int = 0,
                  n_hosts: int = 1) -> dict:
    """Synthetic-corpus batch for ``step`` (host slice). Labels are the
    next-token shift; a simple Markov-ish structure (mixing two hash streams)
    gives the model something learnable."""
    per_host = dc.global_batch // n_hosts
    key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
    key = jax.random.fold_in(key, host_id)
    base = jax.random.randint(key, (per_host, dc.seq_len + 1), 0,
                              dc.vocab_size, dtype=jnp.int32)
    # inject copy structure: second half echoes the first half shifted
    half = dc.seq_len // 2
    echoed = base.at[:, half + 1:].set(base[:, 1:dc.seq_len - half + 1])
    return {"tokens": echoed[:, :-1], "labels": echoed[:, 1:]}


def host_batch_iterator(dc: DataConfig, start_step: int = 0, host_id: int = 0,
                        n_hosts: int = 1):
    step = start_step
    while True:
        yield step, batch_at_step(dc, step, host_id, n_hosts)
        step += 1


def wmd_request_stream(corpus, seed: int = 0):
    """Batched WMD serving requests: yields full-vocab query histograms
    drawn from the corpus query set (repro.data.corpus.make_corpus)."""
    rng = np.random.default_rng(seed)
    n = corpus.queries.shape[0]
    while True:
        yield corpus.queries[rng.integers(0, n)]
