"""Sharded AdamW with global-norm clipping (framework-free, pytree-based).

Optimizer state inherits each parameter's sharding (m, v are tree_map'd from
params), so under pjit the update is fully sharded with zero extra
collectives beyond the gradient all-reduce. Optional ZeRO-1 style state
sharding hook lives in repro.runtime.sharding (opt-state specs can further
shard the leading dim over 'data').
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    # fp32 moments regardless of param dtype (bf16 moments lose the tail
    # of the second-moment EMA; this is the standard mixed-precision setup)
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: AdamWState, params, lr, *, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: float = 1.0):
    """One AdamW step. ``lr`` may be a scalar array (schedule output)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                         state.m, grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
        state.v, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + eps)
                        + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
