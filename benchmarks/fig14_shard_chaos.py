"""Beyond-paper Fig 14: shard-level chaos drill — kill a shard mid-load,
serve honest partials, snapshot-restore back to exact (ISSUE 9).

The scale-out story (fig11) assumed every shard answers every fan-out.
This drill is the failure half of that contract, run as one open-loop
scenario on a forced 2-device CPU mesh:

1. *snapshot first*: the warmed 2-shard engine writes per-shard
   snapshots (``snapshot_shards``) and a never-failed exact baseline is
   recorded at ``nprobe=None``.
2. *crash window*: the seeded :class:`FaultInjector` kills shard 1 on
   every fan-out attempt starting a few dispatches into the request
   stream (``crash_shard``/``crash_after`` keyed on the engine's public
   ``fanouts`` counter, so the window is deterministic, not timed).
3. *partial serving, asserted*: EVERY submitted request resolves (result
   or structured error — zero process deaths); once shard retries burn
   and the circuit opens, responses are tagged ``partial`` with
   ``missing_shards == [1]``, coverage == shard 0's doc fraction, a
   recall caveat, and ``exact`` forced off.
4. *recovery, measured*: ``revive_shard()`` + ``engine.restore_shard(1)``
   rebuilds the dead shard from its snapshot; the drill asserts the
   restore-then-search result is BIT-COMPATIBLE with the never-failed
   baseline (same indices, same distances) and reports time-to-exact-
   recovery. A second injector-free stream then confirms no partials.

Records: ``fig14.p50`` (ok-response end-to-end latency during the crash
window, gated by compare.py) and ``fig14.recovery_s`` (revive -> first
exact full-coverage search, compile included — that IS the recovery a
pager sees; gated loosely as a wall time).

``FIG14_SMOKE=1`` shrinks the corpus/request counts; all asserts still
gate. Needs its own process (2 forced host devices) — CI runs it as a
dedicated step, and a combined ``benchmarks.run`` invocation without
``XLA_FLAGS`` prints a skip instead of failing.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from .common import row

K = 10
PRUNE = "ivf+wcd+rwmd"
N_SHARDS = 2
CRASHED = 1          # the shard the drill kills
DEADLINE_S = 2.0


def _setup(smoke: bool):
    """Sharded engine with drill-friendly fault knobs: fast retries, a
    2-strike breaker, and a snapshot dir for the recovery phase."""
    from repro.core import ShardedWmdEngine, shard_corpus
    from repro.data.corpus import make_corpus
    n_docs = 256 if smoke else 2048
    corpus = make_corpus(vocab_size=1024 if smoke else 4096,
                         embed_dim=32, n_docs=n_docs,
                         n_queries=16, seed=0)
    sindex = shard_corpus(corpus.docs, corpus.vecs, N_SHARDS,
                          n_clusters=16 if smoke else 32)
    engine = ShardedWmdEngine(
        # shard_timeout_s is generous ON PURPOSE: first-touch compiles of
        # fresh batch shapes can take ~10s on a small CI box, and this
        # drill's partials must come from the injected crash, not from a
        # compile racing a tight deadline (the timeout path has its own
        # tests)
        sindex, lam=1.0, n_iter=15, tol=1e-3,
        shard_timeout_s=60.0, shard_retries=1, shard_backoff_s=0.002,
        fail_threshold=2, probe_every=3,
        snapshot_dir=tempfile.mkdtemp(prefix="fig14_snap_"))
    return corpus, engine


def _warm(engine, queries) -> float:
    """Compile every tier outside the measured stream; return the exact
    tier's closed-loop capacity estimate (queries/s)."""
    from repro.runtime.serving import rwmd_topk
    c = min(engine.cluster_counts)
    for bs in (8, 4, 2, 1):   # pow2 ladder: open-loop batches are 1..8
        batch = [queries[i % len(queries)] for i in range(bs)]
        engine.search(batch, K, prune=PRUNE)
        engine.search(batch, K, prune=PRUNE, nprobe=max(1, c // 4))
        rwmd_topk(engine, batch, K)
    batch = [queries[i % len(queries)] for i in range(8)]
    t0 = time.perf_counter()
    engine.search(batch, K, prune=PRUNE)
    dt = time.perf_counter() - t0
    engine.reset_iter_stats()
    return len(batch) / max(dt, 1e-6)


def _drive(engine, queries, n: int, rate: float, injector=None):
    from repro.runtime.serving import (ServeConfig, ServingRuntime,
                                       poisson_arrivals, run_open_loop)
    runtime = ServingRuntime(
        engine,
        ServeConfig(max_batch=8, window_s=0.01, max_queue=64,
                    deadline_s=DEADLINE_S, prune=PRUNE, backoff_s=0.002,
                    seed=9),
        injector=injector)
    reqs = [queries[i % len(queries)] for i in range(n)]
    arrivals = poisson_arrivals(n, rate_per_s=rate, seed=9)
    responses, stats = run_open_loop(runtime, reqs, arrivals, k=K)
    assert len(responses) == n, (
        f"runtime lost requests: {len(responses)}/{n} resolved")
    unresolved = [r for r in responses if not r.ok and r.error is None]
    assert not unresolved, f"unstructured failures: {unresolved}"
    return responses, stats


def run_chaos(out=print, smoke: bool | None = None) -> dict:
    """The CI shard-chaos drill; returns the final stats dict."""
    smoke = bool(os.environ.get("FIG14_SMOKE")) if smoke is None else smoke

    from repro.runtime.sharding import ensure_host_devices
    try:
        ensure_host_devices(N_SHARDS)
    except RuntimeError as e:
        print(f"fig14: skipped ({e})")
        return {}

    from repro.runtime.serving import FaultInjector

    corpus, engine = _setup(smoke)
    queries = list(corpus.queries)
    cap = _warm(engine, queries)
    engine.snapshot()                     # recovery source, post-warmup
    baseline = engine.search(queries, K, prune=PRUNE)
    assert engine.last_coverage.full, "baseline must be full-coverage"

    frac0 = engine.docs_per_shard[1 - CRASHED] / engine.n_docs
    n = 24 if smoke else 64

    # ---- phase A: crash window opens a few dispatches into the stream
    injector = FaultInjector(seed=7, crash_shard=CRASHED,
                             crash_after=engine.fanouts + 2)
    responses, stats = _drive(engine, queries, n, rate=0.5 * cap,
                              injector=injector)
    partials = [r for r in responses if r.ok and r.partial]
    assert partials, (
        f"crash window never produced a partial response: "
        f"tiers={stats['tiers']} errors={stats['errors']}")
    for r in partials:
        assert r.missing_shards == [CRASHED], r.missing_shards
        assert abs(r.coverage - frac0) < 1e-3, (r.coverage, frac0)
        assert not r.exact, "partial response must never claim exactness"
        assert "PARTIAL" in (r.caveat or ""), r.caveat
    assert stats["partial"] == len(partials)
    health = stats["shard_health"]
    assert health["opened"][CRASHED] >= 1, (
        f"breaker never opened for shard {CRASHED}: {health}")
    lat = np.asarray([r.queue_ms + r.service_ms
                      for r in responses if r.ok])
    out(row("fig14.p50", float(np.percentile(lat, 50)) * 1e3,
            f"end-to-end ms*1e3 during crash window; {len(partials)}/{n} "
            f"partial (coverage {frac0:.2%}) "
            f"breaker opened={health['opened'][CRASHED]} "
            f"probes={health['probes'][CRASHED]}"))

    # ---- recovery: revive + snapshot-restore, then prove exactness
    t0 = time.monotonic()
    injector.revive_shard()
    engine.restore_shard(CRASHED)
    res = engine.search(queries, K, prune=PRUNE)
    recovery_s = time.monotonic() - t0
    assert engine.last_coverage.full, engine.last_coverage
    assert np.array_equal(baseline.indices, res.indices), \
        "restore-then-search indices diverge from never-failed baseline"
    assert np.array_equal(
        np.nan_to_num(np.asarray(baseline.distances), nan=-1.0),
        np.nan_to_num(np.asarray(res.distances), nan=-1.0)), \
        "restore-then-search distances diverge from baseline"
    out(row("fig14.recovery_s", recovery_s * 1e6,
            "revive -> restore_shard -> first exact full-coverage "
            "search (compile included; usec of wall)"))

    # ---- phase B: injector-free stream must be partial-free again
    responses, stats = _drive(engine, queries, n, rate=0.5 * cap)
    bad = [r for r in responses if not r.ok or r.partial]
    assert not bad, (
        f"post-recovery stream not clean: "
        f"{[(r.rid, r.ok, r.partial) for r in bad]}")
    return stats


def main(out=print) -> None:
    run_chaos(out=out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="run the shard-kill drill (CI serve-chaos job): "
                         "asserts every request resolves, partials carry "
                         "honest coverage, and snapshot restore returns "
                         "the engine to bit-exact full coverage")
    args = ap.parse_args()
    stats = run_chaos()
    if args.chaos and stats:
        print(f"shard-chaos OK: {stats['submitted']} submitted, "
              f"{stats['errors']} structured errors, 0 unhandled, "
              f"recovery exact")
