"""Paper headline: optimized sparse vs naive dense ("700x faster than
python"). Same corpus, same iteration count, identical outputs (asserted);
the ratio here is the dense->sparse algorithmic win on this host — the
paper's 700x additionally includes C-vs-python overhead we don't model."""
from __future__ import annotations

import numpy as np

from repro.core import one_to_many
from repro.data.corpus import make_corpus
from .common import row, timeit

V, W, N = 16384, 64, 1024


def main(out=print) -> None:
    corpus = make_corpus(vocab_size=V, embed_dim=W, n_docs=N, n_queries=1,
                         words_per_doc=(19, 43), seed=0)
    q = corpus.queries[0]
    args = dict(lam=4.0, n_iter=15)  # fp32-safe: lam*max(M) << 87 at w=64

    d_dense = one_to_many(q, corpus.docs, corpus.vecs, impl="dense", **args)
    d_sparse = one_to_many(q, corpus.docs, corpus.vecs, impl="sparse", **args)
    assert np.allclose(np.asarray(d_dense), np.asarray(d_sparse), atol=2e-3)

    t_dense = timeit(lambda: one_to_many(q, corpus.docs, corpus.vecs,
                                         impl="dense", **args), iters=3)
    t_sparse = timeit(lambda: one_to_many(q, corpus.docs, corpus.vecs,
                                          impl="sparse", **args), iters=3)
    t_unfused = timeit(lambda: one_to_many(q, corpus.docs, corpus.vecs,
                                           impl="sparse_unfused", **args),
                       iters=3)
    out(row("paper.dense_query", t_dense * 1e6, "python/MKL-analogue"))
    out(row("paper.sparse_query", t_sparse * 1e6,
            f"speedup={t_dense/t_sparse:.1f}x_paper_700x_incl_C_vs_py"))
    out(row("paper.sparse_unfused_query", t_unfused * 1e6,
            f"fusion_win={t_unfused/t_sparse:.2f}x"))


if __name__ == "__main__":
    main()
