"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.getcwd(), "experiments", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if name.endswith(".json"):
            with open(os.path.join(DRYRUN_DIR, name)) as f:
                cells.append(json.load(f))
    return cells


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(cells, mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful | roofline-MFU | fits16G |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("skipped") or c.get("mesh") != mesh:
            continue
        r = c["roofline"]
        fit = c.get("analytic_fit", {}).get("fits_16gb", "?")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_mfu']:.3f} | {fit} |")
    return "\n".join(rows)


def main(out=print) -> None:
    cells = load_cells()
    done = [c for c in cells if not c.get("skipped")]
    skipped = [c for c in cells if c.get("skipped")]
    out(f"# cells analysed: {len(done)}  skipped(documented): {len(skipped)}")
    for c in done:
        r = c["roofline"]
        out(f"roofline.{c['arch']}.{c['shape']}.{c['mesh']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
            f"dominant={r['dominant']};mfu={r['roofline_mfu']:.3f}")


if __name__ == "__main__":
    print(table(load_cells()))
