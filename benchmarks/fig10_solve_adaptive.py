"""Beyond-paper Fig 10: the solve-stage overhaul — convergence-adaptive
early-exit Sinkhorn + SolvePrecision policies (ISSUE 4).

PR 3 made the prune stage sub-O(Q*N); `WmdEngine.search` latency is now
dominated by the solve stage, which ran a fixed ``n_iter=15`` fp32 scan for
every survivor regardless of convergence. This benchmark A/Bs the overhauled
solve on the fig8 near-duplicate corpus:

1. *correctness gate FIRST*: the adaptive engine's top-k == the
   fixed-iteration fp32 reference's top-k (asserted, exact set equality),
   and the bf16 policy's top-k matches with distances within
   ``BF16_RTOL`` — both before any timing is reported.
2. *solve-stage A/B*: chunks are staged and the K matrix precomputed once
   (search shares both with the prune stage), then the timed unit is the
   solve pass — ``_solve_group`` over every (chunk, doc-group): the gather
   plus the Sinkhorn dispatch. Reported alongside is the solver-dispatch
   speedup implied by the realized iteration histogram, which is what the
   early exit actually cuts (the gather is iteration-independent).
   Interleaved A/B reps, min of each (this box's wall times are noisy
   and load only ever adds time).
3. *iteration histogram*: realized per-dispatch iteration counts from
   ``engine.iter_stats()`` — the early exit doing the work (most chunks
   stop well under the 15-iteration cap).
4. *log-domain at lam=9*: the paper's own lam on this corpus' distance
   scale (~11) underflows fp32 ``exp(-lam*M)`` — ASSERTED to raise
   ``LamUnderflowError`` on the legacy path — while ``precision="log"``
   completes with finite distances (asserted) at ordinary cost.
5. *per-query scope A/B* (ISSUE 5): through ``WmdEngine.search`` at
   lam=1 (fp32) and lam=9 (log domain) — the regimes where the
   chunk-global residual runs to the cap — per-query scoping freezes
   each query at its own convergence: ASSERTED top-k consistent with
   the fixed-iteration reference (exact set identity in the convergent
   lam=1 smoke config; tolerance-band membership elsewhere — cap-bound
   runs overshoot by up to check_every-1 iterations and flip dup-group
   near-ties) and realized mean iterations strictly below the cap
   wherever any query can genuinely freeze (lam=1 at both sizes; lam=9
   at the N=1024 CI config, where exhausted candidate scopes freeze
   structurally — at N=8192 every lam=9 scope stays contested and the
   loop CORRECTLY runs to the cap, asserted as bounded by the
   documented overshoot). The chunk-scoped counterfactual is recorded
   alongside (``iter_stats`` charges a chunk exit to every live query,
   so the two series measure the same per-query unit).
6. *warm-start A/B* (ISSUE 5): same run, ``warm_start=True`` vs cold —
   survivor solves starting from the seed solve's converged per-query
   profile are ASSERTED to realize a strictly lower mean iteration
   count at lam=1 (where the adaptive exit genuinely converges; at
   lam=9 the cap binds and warm-starting is correctly inert, reported
   not asserted).

The per-query/warm series land in the CI trajectory as ``fig10.iters_*``
records (gated by ``benchmarks/compare.py`` — convergence regressions
fail independent of wall-clock noise).

Solver-rate note: Sinkhorn's convergence rate degrades as ``lam`` grows
(the kernel approaches the LP limit), so the A/B runs at ``LAM = 0.25``
where the iteration genuinely converges within the cap — at lam >= 1 on
this corpus NO honest residual drops below tol within 15 iterations and
the adaptive loop correctly runs to the cap (no speedup, no wrong exit).
Set ``FIG10_SMOKE=1`` to run only the small config (CI smoke); the top-k
and underflow asserts still gate.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import LamUnderflowError, WmdEngine, build_index

from .common import row, timeit
from .fig8_topk_prune import dedup_corpus

LAM = 0.25  # convergence-rate sweet spot; see module docstring
N_ITER = 15  # the paper's fixed iteration count == the adaptive cap
TOL = 3e-2  # relative doc-marginal residual (per-doc scale)
CHECK_EVERY = 2
K = 10
BF16_RTOL = 5e-2  # documented bf16 distance tolerance vs fp32
LAM_UNDERFLOW = 9.0  # the paper's lam; underflows fp32 K on this corpus

# per-query scope A/B (ISSUE 5): search-stage operating point — the cap
# is deliberately ABOVE the paper's 15 so there is convergence headroom
# for the scoped exit to realize (at lam>=1 nothing converges by 15)
PQ_CAP = 60
PQ_TOL = 1e-2
PQ_LAMS = (1.0, LAM_UNDERFLOW)  # lam=9 rides the log-domain path


def _stage(engine, queries):
    """Per-chunk staging + K precompute (shared with the prune stage in
    search, so it sits OUTSIDE the timed solve pass)."""
    _, chunks = engine._plan(queries)
    staged = []
    for chunk, width in chunks:
        sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk], width)
        staged.append((r, mask, engine._kq(sup, mask)))
    return staged


def _solve_pass(engine, staged):
    """The solve stage exactly as query_batch runs it: every (chunk, group)
    gather + batched Sinkhorn dispatch."""
    outs = [
        engine._solve_group(kq, r, mask, grp)
        for r, mask, kq in staged
        for grp in engine.index.groups
    ]
    jax.block_until_ready(outs)


def _sinkhorn_dispatch_ab(fixed, adaptive, staged_f, staged_a, reps=15):
    """Solver-dispatch A/B: the Sinkhorn kernel alone, G pre-gathered.

    The doc-word gather lives in its OWN jit by design (the XLA CPU
    refusion hazard — see the ROADMAP note) and is iteration-independent,
    so the early exit's win is concentrated in this dispatch. One
    (chunk, group) G tile is resident at a time (memory-bounded at
    N=8192); per-pair interleaved min-of-reps are summed (background load
    on this box only ever adds time, so min estimates the quiet-box A/B).
    """
    from repro.core.index import _gather_g, _solve_gathered

    t_fixed = t_adapt = 0.0
    for (r_f, mask_f, kq_f), (r_a, mask_a, kq_a) in zip(staged_f, staged_a):
        kqk, mq = kq_f
        for grp in fixed.index.groups:
            g = _gather_g(kqk, grp.docs.idx)

            def run(engine, r, mask):
                return _solve_gathered(
                    g,
                    mq,
                    grp.docs.idx,
                    grp.docs.val,
                    r,
                    mask,
                    engine.lam,
                    engine.n_iter,
                    engine.tol,
                    engine.check_every,
                    engine.precision.gemm,
                    engine.precision.log_domain,
                )

            jax.block_until_ready(run(fixed, r_f, mask_f))  # compile
            jax.block_until_ready(run(adaptive, r_a, mask_a))
            tf, ta = [], []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(run(fixed, r_f, mask_f))
                tf.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                jax.block_until_ready(run(adaptive, r_a, mask_a))
                ta.append(time.perf_counter() - t0)
            # min-of-reps: background load on this box only ever ADDS
            # time, so min is the stable estimator for the A/B ratio
            t_fixed += float(np.min(tf))
            t_adapt += float(np.min(ta))
    return t_fixed, t_adapt


def _topk(dists, k):
    return [set(np.argsort(dists[qi])[:k]) for qi in range(dists.shape[0])]


def _assert_topk_tolerant(d_ref, res, rtol, label):
    """Every doc the adaptive run returned must be within ``rtol`` of
    truly top-K under the reference distances (the PR 4 bf16 gate shape:
    near-ties inside dup groups may flip at solve tolerance, but nothing
    outside the tolerance band may appear)."""
    for qi in range(d_ref.shape[0]):
        kth = np.sort(d_ref[qi])[K - 1]
        picked = np.asarray(sorted(set(res.indices[qi].tolist())))
        worst = d_ref[qi, picked].max()
        assert worst <= kth * (1.0 + rtol) + 1e-3, (
            f"{label} q{qi}: returned doc outside rtol={rtol} of top-{K}"
        )


def _bench_per_query(index, queries, n_docs, out):
    """Per-query residual scoping + warm-start A/B through the search
    pipeline (ISSUE 5). Asserts gate BEFORE any record is emitted."""
    for lam in PQ_LAMS:
        prec = "log" if lam >= LAM_UNDERFLOW else None
        tag = f"lam{lam:g}"
        fixed = WmdEngine(index, lam=lam, n_iter=PQ_CAP, precision=prec)
        r_fix = fixed.search(queries, K, prune="rwmd")
        ref_sets = [set(r.tolist()) for r in r_fix.indices]
        d_ref = np.asarray(fixed.query_batch(queries))
        engines = {}
        for scope in ("chunk", "query"):
            e = WmdEngine(index, lam=lam, n_iter=PQ_CAP, tol=PQ_TOL,
                          check_every=CHECK_EVERY, precision=prec,
                          scope=scope)
            r = e.search(queries, K, prune="rwmd")
            # membership gated at the solve tolerance against the
            # exhaustive fixed reference: a cap-bound adaptive run
            # overshoots the cap by up to check_every-1 iterations, and
            # near-ties inside dup groups flip at that delta (the PR 4
            # bf16-gate shape) — nothing OUTSIDE the band may appear
            _assert_topk_tolerant(d_ref, r, 2.0 * PQ_TOL,
                                  f"{tag} {scope}")
            if lam < LAM_UNDERFLOW and n_docs <= 1024:
                # the convergent regime at smoke scale holds exact set
                # identity with the fixed reference (CI-gated config)
                got = [set(row.tolist()) for row in r.indices]
                assert got == ref_sets, (
                    f"{tag} {scope}: adaptive top-{K} diverged from the "
                    f"fixed reference"
                )
            engines[scope] = e
        it_q = engines["query"].iter_stats()
        it_c = engines["chunk"].iter_stats()
        # the headline claim: per-query exit realizes strictly fewer
        # iterations than the cap the fixed reference always pays. At
        # lam=9 the freezes that pay are structural (queries whose
        # candidate scope is exhausted) — present at the N=1024 CI
        # config; at N=8192 every query's scope stays contested and the
        # loop CORRECTLY runs to the cap (asserted as such: bounded by
        # the documented check_every-1 overshoot, never beyond)
        if lam < LAM_UNDERFLOW or n_docs <= 1024:
            assert it_q.mean() < PQ_CAP, (tag, it_q)
        else:
            assert it_q.max() <= PQ_CAP + CHECK_EVERY - 1, (tag, it_q)
        out(
            row(
                f"fig10.iters_pq_{tag}_n{n_docs}",
                float(it_q.mean()),
                f"per-query scope mean realized iters/query (cap {PQ_CAP} "
                f"tol={PQ_TOL:g}; chunk scope pays {it_c.mean():.1f}) — "
                f"convergence-trajectory record, not a wall time",
            )
        )
        out(
            row(
                f"fig10.iters_chunk_{tag}_n{n_docs}",
                float(it_c.mean()),
                f"chunk-global scope counterfactual, same unit "
                f"(iters/query)",
            )
        )

        # warm-start A/B: survivor solves from the seed solve's profile
        cold = WmdEngine(index, lam=lam, n_iter=PQ_CAP, tol=PQ_TOL,
                         check_every=CHECK_EVERY, precision=prec,
                         warm_start=False)
        warm = WmdEngine(index, lam=lam, n_iter=PQ_CAP, tol=PQ_TOL,
                         check_every=CHECK_EVERY, precision=prec,
                         warm_start=True)
        r_cold = cold.search(queries, K, prune="rwmd")
        r_warm = warm.search(queries, K, prune="rwmd")
        np.testing.assert_allclose(
            np.sort(r_warm.distances, axis=1),
            np.sort(r_cold.distances, axis=1), rtol=5.0 * PQ_TOL,
            atol=1e-3)
        sv_c = cold.iter_stats_by_stage().get("survivor")
        sv_w = warm.iter_stats_by_stage().get("survivor")
        if sv_c is not None and sv_c.size and sv_w is not None:
            if lam < LAM_UNDERFLOW:
                # the convergent regime: warm must pay strictly less
                assert sv_w.mean() < sv_c.mean(), (tag, sv_c, sv_w)
                regime = "converges"
            else:
                regime = "cap-bound: warm inert by design"
            out(
                row(
                    f"fig10.iters_warm_surv_{tag}_n{n_docs}",
                    float(sv_w.mean()),
                    f"warm-started survivor mean (cold pays "
                    f"{sv_c.mean():.1f}; lam={lam:g} {regime})",
                )
            )
            out(
                row(
                    f"fig10.iters_cold_surv_{tag}_n{n_docs}",
                    float(sv_c.mean()),
                    "cold survivor mean, same unit (iters/query)",
                )
            )


def _bench_one(n_docs: int, out) -> None:
    corpus = dedup_corpus(n_docs)
    queries = list(corpus.queries)
    index = build_index(corpus.docs, corpus.vecs)
    fixed = WmdEngine(index, lam=LAM, n_iter=N_ITER)
    adaptive = WmdEngine(
        index, lam=LAM, n_iter=N_ITER, tol=TOL, check_every=CHECK_EVERY
    )
    bf16 = WmdEngine(
        index,
        lam=LAM,
        n_iter=N_ITER,
        tol=TOL,
        check_every=CHECK_EVERY,
        precision="bf16",
    )

    # correctness gates FIRST: equal top-k before any timing
    d_fixed = np.asarray(fixed.query_batch(queries))
    d_adapt = np.asarray(adaptive.query_batch(queries))
    d_bf16 = np.asarray(bf16.query_batch(queries))
    for qi, (a, b) in enumerate(zip(_topk(d_fixed, K), _topk(d_adapt, K))):
        assert a == b, f"N={n_docs} q{qi}: adaptive top-{K} diverged"
    # bf16 is tolerance-bounded, not exact: near-ties inside a dup group
    # may flip, so the gate is top-k agreement AT the documented tolerance
    # — every doc bf16 returns must be within BF16_RTOL of truly top-k
    for qi in range(d_fixed.shape[0]):
        kth = np.sort(d_fixed[qi])[K - 1]
        picked = np.asarray(sorted(_topk(d_bf16, K)[qi]))
        worst = d_fixed[qi, picked].max()
        assert worst <= kth * (1.0 + BF16_RTOL) + 1e-3, (
            f"N={n_docs} q{qi}: bf16 top-{K} outside rtol={BF16_RTOL}"
        )
    np.testing.assert_allclose(d_bf16, d_fixed, rtol=BF16_RTOL, atol=1e-3)

    # solve-stage A/B: staging + kq OUTSIDE the timed unit, interleaved reps
    st_fixed = _stage(fixed, queries)
    st_adapt = _stage(adaptive, queries)
    st_bf16 = _stage(bf16, queries)
    _solve_pass(fixed, st_fixed)  # compile
    _solve_pass(adaptive, st_adapt)
    _solve_pass(bf16, st_bf16)
    adaptive.reset_iter_stats()
    _solve_pass(adaptive, st_adapt)
    iters = adaptive.iter_stats()
    t_f, t_a, t_b = [], [], []
    for _ in range(11):
        t0 = time.perf_counter()
        _solve_pass(fixed, st_fixed)
        t_f.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _solve_pass(adaptive, st_adapt)
        t_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _solve_pass(bf16, st_bf16)
        t_b.append(time.perf_counter() - t0)
    t_fixed, t_adapt, t_bf16 = (float(np.min(t)) for t in (t_f, t_a, t_b))

    # solver-dispatch A/B: the Sinkhorn kernel alone (G pre-gathered) —
    # the headline early-exit win; the stage rows above it fold in the
    # iteration-independent gather
    t_sink_f, t_sink_a = _sinkhorn_dispatch_ab(
        fixed, adaptive, st_fixed, st_adapt
    )
    hist = {int(v): int(c) for v, c in zip(*np.unique(iters, return_counts=True))}
    out(
        row(
            f"fig10.solve_fixed_n{n_docs}",
            t_fixed * 1e6,
            f"Q={len(queries)} n_iter={N_ITER} lam={LAM}",
        )
    )
    out(
        row(
            f"fig10.solve_adaptive_n{n_docs}",
            t_adapt * 1e6,
            f"stage_speedup={t_fixed / t_adapt:.2f}x tol={TOL:g} "
            f"iters={hist}",
        )
    )
    out(
        row(
            f"fig10.sinkhorn_fixed_n{n_docs}",
            t_sink_f * 1e6,
            "solver dispatch only (gather excluded)",
        )
    )
    out(
        row(
            f"fig10.sinkhorn_adaptive_n{n_docs}",
            t_sink_a * 1e6,
            f"solver speedup={t_sink_f / t_sink_a:.2f}x "
            f"(early exit at mean {iters.mean():.1f}/{N_ITER} iters)",
        )
    )
    out(
        row(
            f"fig10.solve_bf16_n{n_docs}",
            t_bf16 * 1e6,
            f"vs fixed fp32 {t_fixed / t_bf16:.2f}x rtol<={BF16_RTOL:g}",
        )
    )
    out(
        row(
            f"fig10.iters_mean_n{n_docs}",
            float(iters.mean()),
            f"realized-iteration histogram {hist} (cap {N_ITER}) "
            f"— convergence-trajectory record, not a wall time",
        )
    )

    # log-domain: the paper's lam=9 underflows the legacy path (asserted)
    # and completes on the log-domain path (asserted finite)
    hot = WmdEngine(index, lam=LAM_UNDERFLOW, n_iter=N_ITER)
    try:
        hot.query_batch(queries[:1])
        raise AssertionError(
            f"lam={LAM_UNDERFLOW} should underflow fp32 K on this corpus"
        )
    except LamUnderflowError:
        pass
    logeng = WmdEngine(
        index, lam=LAM_UNDERFLOW, n_iter=N_ITER, precision="log"
    )
    d_log = np.asarray(logeng.query_batch(queries))
    assert np.isfinite(d_log).all(), "log-domain path returned non-finite"
    t_log = timeit(lambda: logeng.query_batch(queries), warmup=0, iters=3)
    out(
        row(
            f"fig10.logdomain_lam9_n{n_docs}",
            t_log * 1e6,
            f"lam={LAM_UNDERFLOW:g} finite=yes (legacy path raises "
            f"LamUnderflowError)",
        )
    )

    # per-query residual scoping + warm-start A/B (ISSUE 5)
    _bench_per_query(index, queries, n_docs, out)


def main(out=print) -> None:
    sizes = (1024,) if os.environ.get("FIG10_SMOKE") else (1024, 8192)
    for n_docs in sizes:
        _bench_one(n_docs, out)


if __name__ == "__main__":
    main()
