"""Paper-scale distributed WMD dry-run + roofline (the paper's own workload
as a production-mesh cell).

V=100k vocab, w=300 embeddings, N=5120 docs (5000 padded to the 512-chip
doc sharding), v_r=43 (the paper's larger query), 15 iterations — lowered
and compiled for the (16,16) mesh; roofline terms reported like the LM
cells. Run standalone (sets the 512-device flag before jax import):

    PYTHONPATH=src python -m benchmarks.wmd_dryrun
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json

import jax
import jax.numpy as jnp


def main(out=print) -> None:
    from repro.core.distributed import sinkhorn_wmd_sparse_distributed
    from repro.core.sparse import PaddedDocs
    from repro.launch.mesh import make_production_mesh
    from repro.runtime.analysis import (hlo_collective_bytes, jaxpr_cost,
                                        roofline_terms)

    v, w, n, l_max, v_r = 100_000, 300, 5120, 64, 43
    lam, n_iter = 10.0, 15
    mesh = make_production_mesh()
    n_chips = mesh.devices.size

    r = jax.ShapeDtypeStruct((v_r,), jnp.float32)
    vecs_sel = jax.ShapeDtypeStruct((v_r, w), jnp.float32)
    vecs = jax.ShapeDtypeStruct((v, w), jnp.float32)
    docs = PaddedDocs(idx=jax.ShapeDtypeStruct((n, l_max), jnp.int32),
                      val=jax.ShapeDtypeStruct((n, l_max), jnp.float32))

    def run(r, vecs_sel, vecs, idx, val):
        return sinkhorn_wmd_sparse_distributed(
            r, vecs_sel, vecs, PaddedDocs(idx=idx, val=val), lam, n_iter,
            mesh, vshard_precompute=True)

    with mesh:
        lowered = jax.jit(run).lower(r, vecs_sel, vecs, docs.idx, docs.val)
        compiled = lowered.compile()

    cost = jaxpr_cost(run, r, vecs_sel, vecs, docs.idx, docs.val)
    coll = hlo_collective_bytes(compiled.as_text())
    # memory: per chip = cdist slab (v_r x V/16) x3 arrays + G tiles x2 reads
    hbm = (3 * v_r * (v / 16) * 4            # M,K,KM local slabs
           + 3 * v_r * (n / n_chips) * l_max * 4 * 2)
    rt = roofline_terms(cost["flops"], hbm * n_chips,
                        coll["total_bytes_tpu"], n_chips,
                        model_flops=2.0 * v_r * v * w   # cdist is the floor
                        + 4.0 * n * l_max * v_r * n_iter)
    ma = compiled.memory_analysis()
    out(f"wmd.paper_scale.512chips,"
        f"{max(rt['compute_s'], rt['memory_s'], rt['collective_s'])*1e6:.1f},"
        f"dominant={rt['dominant']};collective_bytes="
        f"{coll['total_bytes']/1e6:.1f}MB;mem_gb="
        f"{(ma.argument_size_in_bytes + ma.temp_size_in_bytes)/2**30:.2f}")
    out(json.dumps({k: round(val, 8) if isinstance(val, float) else val
                    for k, val in rt.items()}))


if __name__ == "__main__":
    main()
