"""Paper-technique-in-LM benchmark: Sinkhorn vs top-k MoE routing.

Metrics: token drop fraction at capacity and expert load imbalance
(max/mean), on skewed activations — the regime where balanced assignment
(the paper's solver) pays."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.router import route
from repro.models.moe import init_moe, moe_dropped_fraction
from .common import row, timeit


def main(out=print) -> None:
    key = jax.random.PRNGKey(0)
    p = init_moe(key, d_model=64, d_ff=32, n_experts=16, n_shared=0, top_k=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256, 64)) \
        + 2.0 * jax.random.normal(jax.random.PRNGKey(2), (1, 1, 64))
    logits = (x.reshape(-1, 64) @ p["router"]).astype(jnp.float32)

    for kind in ("topk", "sinkhorn"):
        drop = float(moe_dropped_fraction(p, x, 2, kind))
        probs = route(logits, kind)
        top1 = jnp.argmax(probs, -1)
        load = jnp.bincount(top1, length=16).astype(jnp.float32)
        imb = float(load.max() / load.mean())
        t = timeit(jax.jit(lambda l: route(l, kind)), logits)
        out(row(f"moe_router.{kind}", t * 1e6,
                f"drop={drop:.4f};imbalance={imb:.2f}"))


if __name__ == "__main__":
    main()
