"""Paper Fig 7 / §6: GEMM-shaped Euclidean distance vs broadcast
("dot-product type") computation, and the fused Pallas kernel (M, K,
K_over_r in one pass — the paper's "compute K and K_over_r at once")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sinkhorn import cdist
from repro.kernels import ops
from .common import row, timeit

V_R, V, W = 43, 16384, 128


def main(out=print) -> None:
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (V_R, W))
    b = jax.random.normal(jax.random.PRNGKey(1), (V, W))
    r = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (V_R,))) + 0.1
    lam = 9.0

    f_bcast = jax.jit(lambda: jnp.sqrt(jnp.maximum(
        jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, -1), 0.0)))
    f_gemm = jax.jit(lambda: cdist(a, b))

    def pipeline_gemm():
        m = cdist(a, b)
        k = jnp.exp(-lam * m)
        return m, k, k / r[:, None]
    f_pipe = jax.jit(pipeline_gemm)
    def f_fused():
        return ops.cdist_exp(a, b, r, lam)

    t_b = timeit(f_bcast)
    t_g = timeit(f_gemm)
    t_p = timeit(f_pipe)
    t_f = timeit(f_fused, iters=2)
    out(row("fig7.cdist_broadcast", t_b * 1e6, "dot-product_type"))
    out(row("fig7.cdist_gemm", t_g * 1e6, f"speedup={t_b/t_g:.1f}x"))
    out(row("fig7.mkk_pipeline", t_p * 1e6, "M,K,K_over_r_separate"))
    out(row("fig7.mkk_fused_kernel", t_f * 1e6,
            "pallas_interpret_CPU;one_HBM_pass_on_TPU"))


if __name__ == "__main__":
    main()
