"""fig11: sharded corpus serving — prune+solve scaling over a device mesh.

The ROADMAP's scale-out scenario (ISSUE 7): the corpus is partitioned
into cluster-aligned doc shards (whole IVF clusters per shard, greedy
bin-packed by doc count), each shard runs the ENTIRE cascade locally on
its own device, and the global top-k is ONE all_gather + local top_k.

Run on a forced multi-device CPU mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.fig11_sharded

Contract gates (asserted BEFORE any timing):
- sharded top-k == single-device top-k at nprobe=None for every shard
  count (tie-tolerant set equality + sorted-distance match);
- the merge jaxpr contains EXACTLY one all_gather and no other
  collective (the structural single-collective guarantee).

Records: ``fig11.wall_s{S}`` end-to-end search wall (us) per shard count
(gated by compare.py via the ``fig11.wall`` prefix), plus informational
``fig11.speedup_s4`` (wall_s1 / wall_s4 ratio), ``fig11.merge_us_s4``
(merge-collective wall per search), and ``fig11.collective_frac_s4``
(merge as a fraction of total wall — the carried measurement note: the
residual pmax contributes ZERO on this path because per-shard cascades
are collective-free, so the merge IS the entire communication budget a
future multi-host design starts from).

Scaling: wall_s1/wall_s4 >= 1.6x is asserted only when the host has >= 4
cores and FIG11_SMOKE is off — shard parallelism is real thread/device
overlap, which a 1-core container or a noisy smoke run cannot show; the
trajectory records stay honest either way. TPU-pod notes live in
``repro/core/shard_index.py``'s module docstring.
"""
from __future__ import annotations

import os

import numpy as np

from .common import row, timeit

LAM = 4.0
TOL = 1e-3
SHARD_COUNTS = (1, 2, 4)


def _tie_tolerant_equal(ref, res, rtol=2e-4):
    """Top-k set equality up to ties: sorted distances match, and every
    returned id's distance matches the reference distance at its rank."""
    nq, k = ref.indices.shape
    for qi in range(nq):
        rd, sd = np.sort(ref.distances[qi]), np.sort(res.distances[qi])
        if not np.allclose(rd, sd, rtol=rtol, equal_nan=True):
            return False, f"query {qi}: distance mismatch {rd} vs {sd}"
        only_ref = set(ref.indices[qi]) - set(res.indices[qi])
        for doc in only_ref:    # tie slots: distance must still be matched
            pos = np.where(ref.indices[qi] == doc)[0][0]
            if not np.isclose(ref.distances[qi][pos], sd[pos], rtol=rtol):
                return False, f"query {qi}: doc {doc} not a tie"
    return True, ""


def main(out=print) -> None:
    smoke = os.environ.get("FIG11_SMOKE") == "1"
    n_docs = 512 if smoke else 4096
    vocab = 1024 if smoke else 4096
    n_queries = 4 if smoke else 8
    n_clusters = 32 if smoke else 64
    k = 10

    from repro.runtime.sharding import ensure_host_devices
    try:
        ensure_host_devices(max(SHARD_COUNTS))
    except RuntimeError as e:
        # backend already initialized single-device (e.g. a combined
        # benchmarks.run invocation without XLA_FLAGS) — fig11 needs its
        # own process; CI runs it as a dedicated step
        print(f"fig11: skipped ({e})")
        return

    import jax
    from repro.core import (ShardedWmdEngine, WmdEngine, build_index,
                            count_collectives, shard_corpus)
    from repro.data.corpus import make_corpus

    corpus = make_corpus(vocab_size=vocab, embed_dim=32, n_docs=n_docs,
                         n_queries=n_queries, seed=7)
    queries = list(corpus.queries)
    kw = dict(lam=LAM, n_iter=15, tol=TOL)

    index = build_index(corpus.docs, corpus.vecs, n_clusters=n_clusters)
    ref_engine = WmdEngine(index, **kw)
    ref = ref_engine.search(queries, k, prune="ivf+wcd+rwmd")

    walls = {}
    merge_us = {}
    for s in SHARD_COUNTS:
        sindex = shard_corpus(corpus.docs, corpus.vecs, s,
                              n_clusters=n_clusters)
        engine = ShardedWmdEngine(sindex, **kw)
        # ---- contract gates, BEFORE timing -------------------------------
        res = engine.search(queries, k, prune="ivf+wcd+rwmd")
        ok, why = _tie_tolerant_equal(ref, res)
        assert ok, f"fig11 exactness gate ({s} shards): {why}"
        if s == 1:
            # shard-count-1 must be bit-compatible, not just tie-equal
            assert np.array_equal(ref.indices, res.indices), \
                "fig11: 1-shard indices differ from single-device"
        packed = np.zeros((s, n_queries, 2 * k), np.float32)
        jaxpr = jax.make_jaxpr(engine._merge_fn(k))(packed)
        colls = count_collectives(jaxpr)
        n_ag = sum(v for p, v in colls.items() if "all_gather" in p)
        assert n_ag == 1 and sum(colls.values()) == 1, \
            f"fig11 single-collective gate: merge jaxpr has {colls}"
        # ---- timing ------------------------------------------------------
        engine.reset_iter_stats()       # also zeroes merge_seconds
        wall = timeit(lambda e=engine: e.search(queries, k,
                                                prune="ivf+wcd+rwmd"),
                      warmup=1, iters=3 if smoke else 5)
        n_searches = (1 + (3 if smoke else 5))  # warmup + timed
        merge_us[s] = engine.merge_seconds / n_searches * 1e6
        walls[s] = wall * 1e6
        out(row(f"fig11.wall_s{s}", walls[s],
                f"search wall | {s} shards | docs/shard "
                f"{list(engine.docs_per_shard)}"))

    speedup = walls[1] / walls[max(SHARD_COUNTS)]
    out(row(f"fig11.speedup_s{max(SHARD_COUNTS)}", speedup,
            "wall_s1 / wall_s4 ratio (info, not a wall time)"))
    out(row(f"fig11.merge_us_s{max(SHARD_COUNTS)}",
            merge_us[max(SHARD_COUNTS)],
            "top-k merge collective wall per search"))
    frac = merge_us[max(SHARD_COUNTS)] / walls[max(SHARD_COUNTS)]
    out(row(f"fig11.collective_frac_s{max(SHARD_COUNTS)}", frac,
            "merge / total wall (residual pmax: structurally zero on "
            "this path)"))
    if not smoke and (os.cpu_count() or 1) >= 4:
        assert speedup >= 1.6, \
            f"fig11 scaling gate: {speedup:.2f}x < 1.6x at " \
            f"{max(SHARD_COUNTS)} shards"


if __name__ == "__main__":
    main()
