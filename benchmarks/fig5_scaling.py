"""Paper Fig 5: strong scaling of the parallel Sinkhorn-WMD.

The paper scales OpenMP threads across NUMA sockets (14-16x on 24-28 cores,
67x on 96 cores). Our shards are devices: we sweep fake-device counts in
subprocesses (this container has one core, so wall-time flattens — the
reported metric is the WORK PER SHARD reduction, which is what transfers to
a real pod and what Fig 5 measures in the limit) plus the collective count
from the lowered HLO (zero for the sparse path = perfect scaling region).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import row

WORKER = textwrap.dedent("""
    import os, sys, json, time
    n = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, "src")
    from repro.data.corpus import make_corpus, shard_balanced
    from repro.core import select_support
    from repro.core.distributed import sinkhorn_wmd_sparse_distributed
    c = make_corpus(vocab_size=8192, embed_dim=64, n_docs=1024, n_queries=1,
                    seed=0, words_per_doc=(19, 43))
    q = c.queries[0]
    r, vs, _ = select_support(q, c.vecs)
    docs = shard_balanced(c.docs, n)
    mesh = jax.make_mesh((1, n), ("data", "model"))
    def run():
        return sinkhorn_wmd_sparse_distributed(
            r, vs, jnp.asarray(c.vecs), docs, 9.0, 15, mesh,
            vshard_precompute=True)
    jax.block_until_ready(run())
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    print(json.dumps({"n": n, "t": float(np.median(ts)),
                      "docs_per_shard": int(docs.idx.shape[0]) // n}))
""")


def main(out=print) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    base_t = None
    for n in (1, 2, 4, 8):
        res = subprocess.run([sys.executable, "-c", WORKER, str(n)],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        line = [ln for ln in res.stdout.splitlines() if ln.startswith("{")]
        if not line:
            out(row(f"fig5.shards_{n}", -1, "FAILED"))
            continue
        j = json.loads(line[-1])
        base_t = base_t or j["t"]
        out(row(f"fig5.shards_{n}", j["t"] * 1e6,
                f"docs/shard={j['docs_per_shard']};speedup={base_t/j['t']:.2f}x"
                f";ideal={n}x"))


if __name__ == "__main__":
    main()
