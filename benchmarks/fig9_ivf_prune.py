"""Beyond-paper Fig 9: sub-O(Q*N) pruning via the IVF centroid cascade.

PR 2's staged retrieval made search solve-light; the prune stage's full
(Q, N) sweep — a WCD GEMM over every doc plus an RWMD min-cdist over the
whole vocabulary — became the asymptotic floor. The cascade
(``prune="ivf+wcd+rwmd"``) replaces it with cheapest-first stages over a
shrinking candidate set: a (Q, n_clusters) probe against the frozen
k-means centers, WCD on the shortlisted docs only, RWMD only on the WCD
survivors and only over *their* vocabulary (the (Q*B, V) min-cdist block
shrinks to (Q*B, V_survivors)).

This benchmark measures three things on the fig8 near-duplicate corpus:

1. *prune-stage time*: the ``"wcd+rwmd"`` full-sweep ``lower_bounds``
   pass vs the cascade's bound pipeline at the steady-state threshold
   (the kth exact distance, which search converges to after its seed
   solve). Gate: >= 3x faster at N=8192, ``nprobe = n_clusters``.
2. *recall@k* vs the exhaustive oracle across ``nprobe`` — ASSERTED 1.0
   at ``nprobe = n_clusters`` (the exact mode) before any timing is
   reported, and reported as a measured recall/speed curve below it.
3. end-to-end ``search`` wall time for both pruners.

``FIG9_SMOKE=1`` runs only the small config (CI smoke); the recall
assert still gates.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import WmdEngine, build_index, resolve_pruner

from .common import row, timeit
from .fig8_topk_prune import DUP, LAM, N_ITER, dedup_corpus

K = 10
NPROBE_CURVE = (1, 4, 16)


def _n_clusters(n_docs: int) -> int:
    """Cluster budget ~ the corpus' near-duplicate group count (IVF cluster
    counts are data-tuned in practice; the build default is sqrt(N))."""
    return max(1, n_docs // DUP)


def _chunks(engine, queries):
    """The engine's per-chunk staging (what PR 2's full-sweep prune pays):
    [(sup, r, mask, qc, chunk)]."""
    _, chunks = engine._plan(queries)
    out = []
    for chunk, width in chunks:
        sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk], width)
        out.append((sup, r, mask, len(chunk), chunk))
    return out


def _global_stage(engine, queries):
    """The cascade's one-pass staging (the engine's _search_cascade
    layout): all live queries at the widest chunk's bucket."""
    _, chunks = engine._plan(queries)
    live_q = [qi for chunk, _ in chunks for qi in chunk]
    width = max(w for _, w in chunks)
    sup, r, mask = engine._prep_chunk([queries[qi] for qi in live_q], width)
    return sup, r, mask, len(live_q), live_q


def _steady_thresholds(engine, exhaustive, query_ids, k):
    """Per-query steady-state pruning threshold: the kth exact distance
    (+ the engine's fp slack margin) — what search's seed solve converges
    to. Benchmarking the bound pipeline at this threshold measures the
    prune stage alone, seed solve excluded on both sides."""
    t = exhaustive.distances[query_ids, k - 1].astype(np.float64)
    return jnp.asarray(t + engine.prune_slack * (np.abs(t) + 1.0))


def _cascade_prune_pass(pruner, index, sup, r, mask, qc, thresh, nprobe):
    """One cascade prune pass at a fixed threshold (probe -> cluster-radius
    filter -> per-doc WCD -> RWMD on WCD survivors); returns the final
    survivor count. The timed unit calls the SAME ``survivors`` pass the
    engine's search runs post-seed — exactly the work that replaces the
    full-sweep ``lower_bounds``."""
    cdists, pm, qcent = pruner.probe(index, sup, r, mask, nprobe)
    return int(
        pruner.survivors(index, sup, r, mask, cdists, pm, qcent, thresh).size
    )


def _recall(result, exhaustive, k):
    per_q = [
        len(set(result.indices[qi]) & set(exhaustive.indices[qi])) / k
        for qi in range(result.indices.shape[0])
    ]
    return float(np.mean(per_q))


def _bench_one(n_docs, out):
    corpus = dedup_corpus(n_docs)
    queries = list(corpus.queries)
    index = build_index(corpus.docs, corpus.vecs,
                        n_clusters=_n_clusters(n_docs))
    n_clusters = index.clusters.n_clusters
    engine = WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse")
    exhaustive = engine.search(queries, K, prune=None)

    # correctness gate FIRST: exact mode (nprobe = n_clusters) must return
    # recall@K == 1.0 before any timing is reported
    exact = engine.search(queries, K, prune="ivf+wcd+rwmd")
    rec = _recall(exact, exhaustive, K)
    assert rec == 1.0, f"N={n_docs}: cascade recall@{K}={rec} at nprobe=all"
    np.testing.assert_allclose(
        np.sort(exact.distances, axis=1),
        np.sort(exhaustive.distances, axis=1),
        rtol=1e-4,
        atol=1e-5,
    )

    # prune-stage time: PR 2's full (Q, N) sweep exactly as its search ran
    # it (per solve chunk: lower_bounds + the host-side argpartition seed
    # selection and threshold filtering this PR moved device-side) vs the
    # cascade's one-pass pipeline, both at the steady-state threshold
    full = resolve_pruner("wcd+rwmd")
    cascade = resolve_pruner("ivf+wcd+rwmd")
    staged = _chunks(engine, queries)
    thresh_c = [
        np.asarray(_steady_thresholds(engine, exhaustive, chunk, K))
        for (_, _, _, _, chunk) in staged
    ]
    sup_g, r_g, mask_g, qg, live_q = _global_stage(engine, queries)
    thresh_g = _steady_thresholds(engine, exhaustive, live_q, K)

    def run_full():
        for (sup, r, mask, qc, _), t in zip(staged, thresh_c):
            lb = np.asarray(full.lower_bounds(index, sup, r, mask))[:qc]
            seed = np.unique(np.argpartition(lb, K - 1, axis=1)[:, :K])
            keep = lb <= t[:, None]
            keep[:, seed] = False
            np.nonzero(keep.any(axis=0))

    def run_cascade():
        _cascade_prune_pass(cascade, index, sup_g, r_g, mask_g, qg, thresh_g, None)

    # interleave A/B reps and compare medians — this box's wall times are
    # noisy and back-to-back blocks confound the comparison with drift
    run_full(), run_cascade()
    t_f, t_c = [], []
    for _ in range(9):
        t0 = time.perf_counter()
        run_full()
        t_f.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_cascade()
        t_c.append(time.perf_counter() - t0)
    t_full = float(np.median(t_f))
    t_casc = float(np.median(t_c))
    out(row(f"fig9.prune_full_sweep_n{n_docs}", t_full * 1e6, f"Q={len(queries)}"))
    out(
        row(
            f"fig9.prune_cascade_n{n_docs}",
            t_casc * 1e6,
            f"speedup={t_full / t_casc:.2f}x nprobe={n_clusters}(all)",
        )
    )

    # end-to-end search + the recall/speed curve for partial probes
    t_search_full = timeit(
        lambda: engine.search(queries, K, prune="wcd+rwmd"), warmup=1, iters=3
    )
    t_search_casc = timeit(
        lambda: engine.search(queries, K, prune="ivf+wcd+rwmd"), warmup=1, iters=3
    )
    out(
        row(
            f"fig9.search_cascade_n{n_docs}",
            t_search_casc * 1e6,
            f"vs wcd+rwmd {t_search_full / t_search_casc:.2f}x "
            f"solved_frac={float(exact.solved.mean()) / n_docs:.4f}",
        )
    )
    for nprobe in (p for p in NPROBE_CURVE if p < n_clusters):
        res = engine.search(queries, K, prune="ivf+wcd+rwmd", nprobe=nprobe)
        t_np = timeit(
            lambda: engine.search(queries, K, prune="ivf+wcd+rwmd", nprobe=nprobe),
            warmup=1,
            iters=3,
        )
        out(
            row(
                f"fig9.search_nprobe{nprobe}_n{n_docs}",
                t_np * 1e6,
                f"recall@{K}={_recall(res, exhaustive, K):.3f} "
                f"solved_frac={float(res.solved.mean()) / n_docs:.4f}",
            )
        )


def main(out=print) -> None:
    sizes = (1024,) if os.environ.get("FIG9_SMOKE") else (1024, 8192)
    for n_docs in sizes:
        _bench_one(n_docs, out)


if __name__ == "__main__":
    main()
