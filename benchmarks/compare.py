"""Benchmark-trajectory gate: compare a current bench record to a baseline.

The ``bench-trajectory`` CI job commits ``benchmarks/run.py --json`` records
from ``main`` (``benchmarks/trajectory/BENCH_<shortsha>.json`` plus a
``latest.json`` pointer); the PR ``bench-smoke`` job reads the latest main
record and fails on a wall-time regression:

    python -m benchmarks.compare --baseline baseline.json \\
        --current BENCH_smoke.json --max-ratio 1.3 \\
        --prefixes fig7 fig8 fig10.solve fig10.iters

Only benchmarks whose name starts with one of ``--prefixes`` gate (the
rest are reported for context). ``fig10.iters`` records are realized
Sinkhorn iteration counts, not wall times — gating them catches
CONVERGENCE regressions (the adaptive solve suddenly needing more
iterations) that wall-clock noise would hide. A gate prefix whose
current records have no baseline counterpart passes with an explicit
``SEEDING (no baseline)`` marker (per prefix, covering both an empty
trajectory and a newly-added benchmark) — the first bench-trajectory
run on main seeds the comparison.

``--min-prefixes`` records gate in the OPPOSITE direction: they are
quality metrics (``fig13.recall_*`` stores recall@k * 100), so a DROP is
the regression — ``current/baseline < --min-ratio`` fails even though
the max-ratio gate would wave the smaller value through. A record
matching a min prefix is excluded from the max gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load(path: str) -> dict:
    p = Path(path)
    if not p.is_file():
        return {}
    try:
        return {str(k): float(v) for k, v in json.loads(p.read_text()).items()}
    except (ValueError, AttributeError):
        print(f"warning: could not parse {path}; treating as empty baseline")
        return {}


def compare(
    baseline: dict,
    current: dict,
    max_ratio: float,
    prefixes,
    min_ratio: float = 0.999,
    min_prefixes=(),
) -> list[str]:
    """Return the list of gating regressions (empty = pass)."""
    failures = []
    # a gate prefix matching NO current record means the benchmark never
    # ran (skipped step, renamed record, typo'd prefix) — warn loudly so
    # a silently-dead gate doesn't read as a pass
    for p in list(prefixes) + list(min_prefixes):
        cur = [name for name in current if name.startswith(p)]
        if not cur:
            print(
                f"warning: gate prefix '{p}' matches no current record — "
                f"that benchmark did not run or was renamed"
            )
        elif not any(n in baseline and baseline[n] > 0 for n in cur):
            # the gate exists but main's trajectory hasn't recorded this
            # benchmark yet (empty trajectory, or a newly-added record):
            # an explicit marker so "pass" is readable as "not yet
            # comparable" rather than "compared and fine"
            print(
                f"SEEDING (no baseline): gate prefix '{p}' — "
                f"{len(cur)} current record(s) await a baseline from "
                f"main's bench-trajectory job"
            )
    for name in sorted(current):
        if name not in baseline or baseline[name] <= 0:
            continue
        ratio = current[name] / baseline[name]
        min_gating = any(name.startswith(p) for p in min_prefixes)
        gating = not min_gating and any(name.startswith(p) for p in prefixes)
        marker = "GATE-MIN" if min_gating else ("GATE" if gating else "info")
        print(
            f"[{marker}] {name}: {baseline[name]:.1f} -> {current[name]:.1f} us "
            f"({ratio:.2f}x)"
        )
        if gating and ratio > max_ratio:
            failures.append(f"{name}: {ratio:.2f}x > {max_ratio:.2f}x")
        if min_gating and ratio < min_ratio:
            failures.append(
                f"{name}: {ratio:.4f}x < {min_ratio:.4f}x (quality metric dropped)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-ratio", type=float, default=1.3)
    ap.add_argument(
        "--prefixes",
        nargs="+",
        default=[
            "fig7",
            "fig8",
            "fig10.solve",
            "fig10.iters",
            "fig11.wall",
            "fig12.p50_low",
            "fig13.wall",
            "fig14.p50",
            "fig14.recovery_s",
            "fig15.p50",
        ],
        help="bench-name prefixes that gate (others are informational)",
    )
    ap.add_argument(
        "--min-ratio",
        type=float,
        default=0.999,
        help="min-direction gate threshold for quality metrics "
        "(current/baseline below this fails)",
    )
    ap.add_argument(
        "--min-prefixes",
        nargs="+",
        default=["fig13.recall", "fig15.hit_rate"],
        help="bench-name prefixes gated as quality metrics: a DROP "
        "relative to baseline fails (excluded from the max gate)",
    )
    args = ap.parse_args(argv)

    current = load(args.current)
    if not current:
        print(f"error: no current records in {args.current}")
        return 2
    baseline = load(args.baseline)
    if not baseline:
        # still run compare(): it prints the per-prefix SEEDING markers
        # (and dead-gate warnings) with an empty baseline, then passes
        print(f"no baseline records in {args.baseline}; seeding run")
    failures = compare(
        baseline,
        current,
        args.max_ratio,
        args.prefixes,
        args.min_ratio,
        args.min_prefixes,
    )
    if failures:
        print("bench-trajectory gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("bench-trajectory gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
