"""Beyond-paper Fig 15: the cross-request K-column cache under Zipfian
serving traffic (ISSUE 10).

Serving traffic is Zipfian over the vocabulary: the same hot query words
recur request after request, yet until this PR every dispatch recomputed
the full ``(V, Q*B)`` corpus-distance GEMM from scratch.
:mod:`repro.core.kcache` keeps hot words' ``(V,)`` cdist rows
device-resident across requests and GEMMs only the misses. This
benchmark proves the contract before it times anything:

1. *exactness FIRST*: a cache-on engine and a cache-off engine share one
   index and score the same Zipfian batches, cold AND warm; top-k
   indices and distances must be ``np.array_equal`` (bitwise — the
   cached rows are produced by the same GEMM kernel shape family, see
   the kcache module docstring). A speedup that changes answers is a
   bug, not a feature.
2. *hit rate SECOND*: a deterministic closed-loop replay (fixed batches
   of 8, seeded Zipf s=1.0 stream) must exceed 50% hits after warmup —
   otherwise the cache is decoration and the timing below is
   meaningless. This number is the gated ``fig15.hit_rate`` record
   (min-gated in CI like fig13.recall): fixed seeds + fixed batch
   composition make it reproducible, unlike the serving-path hit rate
   whose micro-batch boundaries depend on wall-clock arrival jitter.
3. *timing LAST*: an open-loop Zipfian stream through
   :class:`~repro.runtime.serving.ServingRuntime` (cache enabled by
   default there) yields the gated ``fig15.p50``; the serving-path hit
   rate rides along as an info record and is asserted > 0.5 as well.

``FIG15_SMOKE=1`` shrinks the corpus and request counts (CI smoke); the
exactness and hit-rate asserts still gate.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import WmdEngine, build_index
from repro.data.corpus import make_corpus
from repro.runtime.serving import (ServeConfig, ServingRuntime,
                                   poisson_arrivals, run_open_loop)

from .common import row

K = 10
PRUNE = "ivf+wcd+rwmd"
SLOTS = 512
ZIPF_S = 1.0
DEADLINE_S = 2.0
WINDOW_S = 0.01


def _setup(smoke: bool):
    n_docs = 256 if smoke else 2048
    corpus = make_corpus(vocab_size=1024 if smoke else 8192,
                         embed_dim=32 if smoke else 64,
                         n_docs=n_docs, n_queries=8, seed=0)
    index = build_index(corpus.docs, corpus.vecs)
    return corpus, index


def zipf_queries(n: int, vocab_size: int, words: int,
                 s: float = ZIPF_S, seed: int = 0) -> list[np.ndarray]:
    """``n`` L1-normalized query histograms whose words are drawn with
    probability proportional to 1/rank**s (explicit rank-power law:
    ``np.random.zipf`` requires s > 1, the serving literature's canonical
    skew is exactly s = 1). A seeded permutation decouples Zipf rank
    from word id so the stream doesn't accidentally align with the
    synthetic corpus's own id-ordered skew."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, vocab_size + 1, dtype=np.float64) ** s
    p /= p.sum()
    rank_to_word = rng.permutation(vocab_size)
    out = []
    for _ in range(n):
        ids = rank_to_word[rng.choice(vocab_size, size=words, p=p)]
        q = np.zeros(vocab_size, np.float32)
        np.add.at(q, ids, rng.random(words).astype(np.float32) + 0.1)
        q /= q.sum()
        out.append(q)
    return out


def _assert_exact(index, queries, batch: int = 8):
    """Cache-on == cache-off, bitwise, cold and warm. Returns the warm
    cache-on engine (deterministic state: fixed stream, fixed order) for
    the hit-rate replay."""
    eng_off = WmdEngine(index, lam=1.0, n_iter=15, impl="sparse")
    eng_on = WmdEngine(index, lam=1.0, n_iter=15, impl="sparse",
                       kcache_slots=SLOTS, kcache_min_hits=1)
    for _pass in ("cold", "warm"):
        for i in range(0, len(queries), batch):
            chunk = queries[i:i + batch]
            a = eng_off.search(chunk, K, prune=PRUNE)
            b = eng_on.search(chunk, K, prune=PRUNE)
            assert np.array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices)), (
                f"kcache changed top-k membership ({_pass} pass, "
                f"batch at {i})")
            assert np.array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances)), (
                f"kcache changed distances ({_pass} pass, batch at {i}): "
                "the bit-exact contract is broken")
    st = eng_on.kcache_stats()
    assert st["hits"] > 0, f"cache never hit during exactness sweep: {st}"
    return eng_on


def _closed_loop_hit_rate(engine, queries, batch: int = 8) -> float:
    """Deterministic fixed-batch replay on the (already warm) cache-on
    engine: the reproducible hit-rate the CI trajectory min-gates."""
    engine.reset_kcache_stats()
    for i in range(0, len(queries), batch):
        engine.search(queries[i:i + batch], K, prune=PRUNE)
    st = engine.kcache_stats()
    assert st["hits"] + st["misses"] > 0, f"no lookups recorded: {st}"
    return st["hits"] / (st["hits"] + st["misses"])


def _serving_drive(index, queries, n: int, seed: int = 1):
    """Open-loop Zipfian stream through the runtime (kcache on by
    default via ServeConfig): p50 plus the serving-path cache stats."""
    engine = WmdEngine(index, lam=1.0, n_iter=15, impl="sparse")
    runtime = ServingRuntime(
        engine,
        ServeConfig(max_batch=8, window_s=WINDOW_S, max_queue=64,
                    deadline_s=DEADLINE_S, prune=PRUNE,
                    backoff_s=0.005, seed=seed))
    assert engine.kcache_stats() is not None, (
        "ServingRuntime failed to enable the kcache by default")
    # warm every tier's executables outside the measured stream, then
    # estimate exact-tier capacity so the offered load is box-independent
    from repro.runtime.serving import rwmd_topk
    warm = [queries[i % len(queries)] for i in range(8)]
    engine.search(warm, K, prune=PRUNE)
    c = engine.index.clusters.n_clusters
    engine.search(warm, K, prune=PRUNE, nprobe=max(1, c // 4))
    rwmd_topk(engine, warm, K)
    t0 = time.perf_counter()
    engine.search(warm, K, prune=PRUNE)
    cap = 8 / max(time.perf_counter() - t0, 1e-6)
    # untimed open-loop pre-stream: the measured run's micro-batches come
    # in sizes 1..max_batch depending on arrival jitter, and each fresh
    # batch-size bucket compiles — warm those executables with a short
    # throwaway stream so the gated p50 measures serving, not compiles
    pre = [queries[i % len(queries)] for i in range(16)]
    run_open_loop(runtime, pre,
                  poisson_arrivals(16, rate_per_s=0.5 * cap, seed=99),
                  k=K)
    engine.reset_iter_stats()
    engine.reset_kcache_stats()
    reqs = [queries[i % len(queries)] for i in range(n)]
    arrivals = poisson_arrivals(n, rate_per_s=0.5 * cap, seed=seed)
    responses, stats = run_open_loop(runtime, reqs, arrivals, k=K)
    assert len(responses) == n, (
        f"runtime lost requests: {len(responses)}/{n} resolved")
    lat = np.asarray([r.queue_ms + r.service_ms for r in responses
                      if r.ok])
    return responses, stats, lat


def main(out=print) -> None:
    smoke = bool(os.environ.get("FIG15_SMOKE"))
    corpus, index = _setup(smoke)
    vocab = corpus.vecs.shape[0]
    words = 16 if smoke else 32
    n_req = 48 if smoke else 128

    stream = zipf_queries(n_req, vocab, words, s=ZIPF_S, seed=11)

    # 1. exactness gate — nothing gets timed until this holds
    eng_on = _assert_exact(index, stream[:16 if smoke else 32])

    # 2. reproducible hit rate (the min-gated record)
    hr = _closed_loop_hit_rate(eng_on, stream)
    assert hr > 0.5, (
        f"Zipf s={ZIPF_S} closed-loop hit rate {hr:.3f} <= 0.5: the "
        "cache is not earning its slots")
    out(row("fig15.hit_rate", 100.0 * hr,
            f"closed-loop Zipf s={ZIPF_S} hit percent, {SLOTS} slots, "
            f"vocab {vocab} (percent, not usec; min-gated)"))

    # 3. serving-path timing (the max-gated record)
    responses, stats, lat = _serving_drive(index, stream, n_req)
    kc = stats.get("kcache")
    assert kc is not None, f"runtime stats carry no kcache block: {stats}"
    shr = kc["hits"] / max(kc["hits"] + kc["misses"], 1)
    assert shr > 0.5, (
        f"serving-path hit rate {shr:.3f} <= 0.5 under Zipf s={ZIPF_S}: "
        f"{kc}")
    per_resp = [r.kcache for r in responses if r.ok and r.kcache]
    assert per_resp, "no response carried per-dispatch kcache deltas"
    out(row("fig15.p50", float(np.percentile(lat, 50)) * 1e3,
            f"end-to-end ms*1e3 at ~0.5x capacity n={n_req}, cache "
            f"hits={kc['hits']} misses={kc['misses']} "
            f"evictions={kc['evictions']}"))
    # named so the gated `fig15.hit_rate` prefix does NOT match it: the
    # serving-path number jitters with micro-batch boundaries
    out(row("fig15.serving_hit_rate", 100.0 * shr,
            "serving-path hit percent (info: micro-batch boundaries "
            "jitter with wall clock; the gated twin is closed-loop)"))


if __name__ == "__main__":
    main()
