"""Beyond-paper Fig 12: the async serving runtime under offered load
(ISSUE 6).

The paper's headline scenario — one query against a day of tweets — is a
SERVING workload, but until this PR the repo only had a one-shot CLI.
This benchmark drives :class:`repro.runtime.serving.ServingRuntime`
open-loop (arrivals scheduled independently of completions, so queueing
delay lands in the latency tail instead of silently throttling the
generator) and reports the serving-runtime contract:

1. *capacity estimate FIRST*: a closed-loop warmup measures the exact
   tier's batched service time; offered loads are utilization multiples
   of the implied capacity so the sweep is box-independent (this 2-vCPU
   box's absolute qps is meaningless; the SHAPE of the latency/degrade
   curve is the deliverable).
2. *low-load sweep* (~0.3x capacity): p50/p99 end-to-end latency and
   throughput. ``fig12.p50_low`` GATES in the CI trajectory — a serving
   regression at uncontended load is a real regression, while the p99
   and the overload points ride as info records (tail noise on a shared
   box would false-positive a gate).
3. *overload sweep* (~3x capacity): the degrade-don't-drop policy doing
   its job — degraded-tier fraction and rejected fraction are reported;
   the benchmark ASSERTS every submitted request resolved (result or
   structured error — the runtime's core invariant) and that degradation
   actually engaged (the ladder exists to be used, not to decorate).
4. *chaos drill* (``--chaos`` or always-on as the final scenario):
   seeded fault injection — stage latency, transient dispatch faults
   (retried), poison requests (isolated into structured errors) — under
   overload. ASSERTS zero unhandled exceptions, every request answered
   or structured-errored, degraded fraction > 0, and that the injected
   poison shows up as ``poison`` error codes (the isolation path ran).
   This is the CI ``serve-chaos`` job's entry point.

``FIG12_SMOKE=1`` shrinks the corpus and request counts (CI smoke); the
resolution/degradation asserts still gate.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import WmdEngine, build_index
from repro.data.corpus import make_corpus
from repro.runtime.serving import (FaultInjector, ServeConfig,
                                   ServingRuntime, poisson_arrivals,
                                   run_open_loop)

from .common import row

K = 10
PRUNE = "ivf+wcd+rwmd"   # IVF cascade: the full 3-tier ladder exists
DEADLINE_S = 2.0
WINDOW_S = 0.01


def _setup(smoke: bool):
    n_docs = 256 if smoke else 2048
    corpus = make_corpus(vocab_size=1024 if smoke else 8192,
                         embed_dim=32 if smoke else 64,
                         n_docs=n_docs, n_queries=16, seed=0)
    index = build_index(corpus.docs, corpus.vecs)
    engine = WmdEngine(index, lam=1.0, n_iter=15, impl="sparse")
    return corpus, engine


def _warm_and_capacity(engine, queries, max_batch: int) -> float:
    """Compile every tier's executables OUTSIDE the measured sweeps and
    estimate exact-tier capacity (queries/s) from a closed-loop rep."""
    from repro.runtime.serving import rwmd_topk
    batch = [queries[i % len(queries)] for i in range(max_batch)]
    engine.search(batch, K, prune=PRUNE)                 # exact
    c = engine.index.clusters.n_clusters
    engine.search(batch, K, prune=PRUNE, nprobe=max(1, c // 4))
    rwmd_topk(engine, batch, K)                          # bound tier
    t0 = time.perf_counter()
    engine.search(batch, K, prune=PRUNE)
    dt = time.perf_counter() - t0
    engine.reset_iter_stats()
    return max_batch / max(dt, 1e-6)


def _drive(engine, queries, n: int, rate: float, injector=None,
           max_queue: int = 64, seed: int = 1):
    runtime = ServingRuntime(
        engine,
        ServeConfig(max_batch=8, window_s=WINDOW_S, max_queue=max_queue,
                    deadline_s=DEADLINE_S, prune=PRUNE,
                    backoff_s=0.005, seed=seed),
        injector=injector)
    reqs = [queries[i % len(queries)] for i in range(n)]
    arrivals = poisson_arrivals(n, rate_per_s=rate, seed=seed)
    responses, stats = run_open_loop(runtime, reqs, arrivals, k=K)
    assert len(responses) == n, (
        f"runtime lost requests: {len(responses)}/{n} resolved")
    lat = np.asarray([r.queue_ms + r.service_ms for r in responses
                      if r.ok])
    span = float(arrivals[-1]) + max(
        (r.service_ms for r in responses), default=0.0) / 1e3
    return responses, stats, lat, span


def _frac(stats, *names) -> float:
    total = sum(stats["tiers"].values())
    return sum(stats["tiers"].get(x, 0) for x in names) / max(total, 1)


def run_chaos(out=print, smoke: bool | None = None) -> dict:
    """The CI serve-chaos drill: overload + injected latency/transient/
    poison faults; asserts the runtime's core invariants. Returns the
    stats dict so the CLI entry can print a verdict."""
    smoke = bool(os.environ.get("FIG12_SMOKE")) if smoke is None else smoke
    corpus, engine = _setup(smoke)
    queries = list(corpus.queries)
    cap = _warm_and_capacity(engine, queries, max_batch=8)
    n = 48 if smoke else 128
    injector = FaultInjector(latency_rate=0.2, latency_s=0.05,
                             transient_rate=0.25, poison_rate=0.08,
                             seed=7)
    responses, stats, lat, span = _drive(
        engine, queries, n, rate=3.0 * cap, injector=injector,
        max_queue=24, seed=7)
    # core invariant: every request answered or structured-errored
    unresolved = [r for r in responses
                  if not r.ok and r.error is None]
    assert not unresolved, f"unstructured failures: {unresolved}"
    codes = {r.error["code"] for r in responses if not r.ok}
    assert "poison" in codes, (
        f"injected poison never surfaced as a structured error: {codes}")
    degraded = 1.0 - _frac(stats, "exact")
    assert degraded > 0, (
        f"overload at 3x capacity never engaged the degradation ladder: "
        f"{stats['tiers']}")
    ok_n = sum(r.ok for r in responses)
    out(row("fig12.chaos_answered_frac", 100.0 * ok_n / n,
            f"{ok_n}/{n} ok; error codes={sorted(codes)}; "
            f"retries={stats['retries']} "
            f"isolations={stats['isolations']} (percent, not usec)"))
    out(row("fig12.chaos_degraded_frac", 100.0 * degraded,
            f"tiers={stats['tiers']} rejected={stats['rejected']} "
            f"(percent, not usec)"))
    return stats


def main(out=print) -> None:
    smoke = bool(os.environ.get("FIG12_SMOKE"))
    corpus, engine = _setup(smoke)
    queries = list(corpus.queries)
    cap = _warm_and_capacity(engine, queries, max_batch=8)
    n_low = 32 if smoke else 96
    n_over = 48 if smoke else 128

    # --- low load (~0.3x capacity): the GATED point
    _, stats, lat, span = _drive(engine, queries, n_low, rate=0.3 * cap)
    assert lat.size == n_low, "low-load run must answer every request"
    out(row("fig12.p50_low", float(np.percentile(lat, 50)) * 1e3,
            f"end-to-end ms*1e3 at 0.3x capacity (~{0.3 * cap:.1f} qps) "
            f"n={n_low}"))
    out(row("fig12.p99_low", float(np.percentile(lat, 99)) * 1e3,
            "tail at the same point (info: tail noise on a shared box)"))
    out(row("fig12.throughput_low", n_low / span,
            f"answered qps over the {span:.1f}s span (info, "
            "qps not usec)"))
    out(row("fig12.degraded_low", 100.0 * (1.0 - _frac(stats, "exact")),
            f"degraded-tier percent at 0.3x (tiers={stats['tiers']})"))

    # --- overload (~3x capacity): degrade-don't-drop engages
    responses, stats, lat, span = _drive(
        engine, queries, n_over, rate=3.0 * cap, max_queue=24)
    unresolved = [r for r in responses if not r.ok and r.error is None]
    assert not unresolved, f"unstructured failures: {unresolved}"
    degraded = 1.0 - _frac(stats, "exact")
    assert degraded > 0, (
        f"3x overload never degraded: {stats['tiers']}")
    out(row("fig12.p50_over", float(np.percentile(lat, 50)) * 1e3
            if lat.size else 0.0,
            f"end-to-end ms*1e3 at 3x capacity (info) n={n_over}"))
    out(row("fig12.p99_over", float(np.percentile(lat, 99)) * 1e3
            if lat.size else 0.0, "overload tail (info)"))
    out(row("fig12.degraded_over", 100.0 * degraded,
            f"degraded-tier percent at 3x (tiers={stats['tiers']} "
            f"rejected={stats['rejected']} "
            f"deadline_missed={stats['deadline_missed']})"))
    out(row("fig12.rejected_over",
            100.0 * stats["rejected"] / max(stats["submitted"], 1),
            "structured-rejection percent at 3x (bounded queue doing "
            "its job; degraded tiers absorb the rest)"))

    # --- chaos drill (the serve-chaos CI job runs this via --chaos)
    run_chaos(out=out, smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="run only the fault-injection drill (CI "
                         "serve-chaos job): asserts every request is "
                         "answered or structured-errored and degradation "
                         "engaged under injected overload")
    args = ap.parse_args()
    if args.chaos:
        stats = run_chaos()
        print(f"serve-chaos OK: {stats['submitted']} submitted, "
              f"{stats['errors']} structured errors, "
              f"{stats['retries']} retries, 0 unhandled")
    else:
        main()
