"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (paper mapping in each module doc).
``--json PATH`` additionally writes a ``{bench_name: usec}`` record file
(e.g. ``--json BENCH_fig6.json``) for the bench trajectory; ``--only`` runs
a subset of modules.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from . import (fig5_scaling, fig6_multi_query, fig7_cdist, fig8_topk_prune,
               fig9_ivf_prune, fig10_solve_adaptive, fig11_sharded,
               fig12_serving, fig13_pareto, fig14_shard_chaos, fig15_kcache,
               moe_router, python_baseline, roofline, table1_profile)

MODULES = [
    ("table1_profile", table1_profile),
    ("python_baseline", python_baseline),
    ("fig5_scaling", fig5_scaling),
    ("fig6_multi_query", fig6_multi_query),
    ("fig7_cdist", fig7_cdist),
    ("fig8_topk_prune", fig8_topk_prune),
    ("fig9_ivf_prune", fig9_ivf_prune),
    ("fig10_solve_adaptive", fig10_solve_adaptive),
    ("fig11_sharded", fig11_sharded),
    ("fig12_serving", fig12_serving),
    ("fig13_pareto", fig13_pareto),
    ("fig14_shard_chaos", fig14_shard_chaos),
    ("fig15_kcache", fig15_kcache),
    ("moe_router", moe_router),
    ("roofline", roofline),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {bench: usec} JSON records to PATH")
    ap.add_argument("--only", nargs="+", default=None,
                    choices=[name for name, _ in MODULES],
                    help="run only these modules")
    args = ap.parse_args(argv)

    records: dict[str, float] = {}

    def out(line: str) -> None:
        print(line)
        parts = str(line).split(",")
        if len(parts) >= 2:
            try:
                records[parts[0]] = float(parts[1])
            except ValueError:
                pass

    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        if args.only is not None and name not in args.only:
            continue
        try:
            mod.main(out=out)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
        print(f"wrote {len(records)} records to {args.json}", file=sys.stderr)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
