"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (paper mapping in each module doc).
"""
from __future__ import annotations

import sys
import traceback

from . import (fig5_scaling, fig6_multi_query, fig7_cdist, moe_router,
               python_baseline, roofline, table1_profile)

MODULES = [
    ("table1_profile", table1_profile),
    ("python_baseline", python_baseline),
    ("fig5_scaling", fig5_scaling),
    ("fig6_multi_query", fig6_multi_query),
    ("fig7_cdist", fig7_cdist),
    ("moe_router", moe_router),
    ("roofline", roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        try:
            mod.main(out=print)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
