"""Beyond-paper Fig 8: staged top-k retrieval (prune -> solve -> rank) vs
exhaustive scoring, at N in {1k, 8k}.

The paper's motivating workload is top-k ("is this tweet similar to any
tweet from today?") but its engine always scores every document; LC-RWMD
(Atasu et al.) and Werner & Laber show admissible lower bounds prune most
candidates first. This benchmark measures that win end to end through
``WmdEngine.search`` and ASSERTS the pruned top-k equals the exhaustive
top-k before any timing is reported (the staged pipeline's correctness
contract), plus reports the surviving-candidate fraction.

Corpus note: the paper's scenario is near-duplicate detection, so the
corpus must CONTAIN near-duplicates — on a corpus of i.i.d. random
documents every doc is equally (un)related to the query, the kth-best
distance sits inside the bulk, and *no* admissible bound can discriminate.
We build the tweet-dedup shape directly: ``DUP`` perturbed variants of each
base document (jittered counts, one substituted word), with queries drawn
as further perturbations — so each query has ~DUP genuinely-similar docs
and everything else is prunable. Set ``FIG8_SMOKE=1`` to run only the small
config (CI smoke).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import WmdEngine, build_index
from repro.core.sparse import padded_docs_from_lists
from repro.data.corpus import WmdCorpus, make_corpus
from .common import row, timeit

LAM = 2.0            # word distance scale ~ sqrt(2*64) ~ 11; dup dist ~ 0.5
N_ITER = 15
K = 10
N_QUERIES = 4
DUP = 16             # near-duplicate variants per base document


def dedup_corpus(n_docs: int, vocab: int = 8192, embed_dim: int = 64,
                 seed: int = 0) -> WmdCorpus:
    """Near-duplicate corpus: n_docs // DUP base docs, DUP variants each."""
    n_base = n_docs // DUP
    base = make_corpus(vocab_size=vocab, embed_dim=embed_dim, n_docs=n_base,
                       n_queries=0, words_per_doc=(19, 43), seed=seed)
    rng = np.random.default_rng(seed + 1)
    idx0 = np.asarray(base.docs.idx)
    val0 = np.asarray(base.docs.val)

    def perturb(j):
        live = val0[j] > 0
        ids = idx0[j][live].copy()
        counts = val0[j][live] * 100.0 + rng.uniform(0.0, 5.0, live.sum())
        ids[rng.integers(0, ids.size)] = rng.integers(0, vocab)  # swap 1 word
        return ids, counts

    lists = [perturb(j) for j in range(n_base) for _ in range(DUP)]
    docs = padded_docs_from_lists([i for i, _ in lists],
                                  [c for _, c in lists])
    queries = np.zeros((N_QUERIES, vocab), np.float32)
    for qi, j in enumerate(rng.choice(n_base, N_QUERIES, replace=False)):
        ids, counts = perturb(j)
        queries[qi, ids] = counts / counts.sum()
    return WmdCorpus(vecs=base.vecs, docs=docs, queries=queries)


def _bench_one(n_docs: int, out) -> None:
    corpus = dedup_corpus(n_docs)
    queries = list(corpus.queries)
    engine = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=LAM,
                       n_iter=N_ITER, impl="sparse")
    exhaustive = engine.search(queries, K, prune=None)
    pruned = engine.search(queries, K, prune="rwmd")
    # correctness gate: identical top-k sets before any timing is reported
    for qi in range(len(queries)):
        assert set(exhaustive.indices[qi]) == set(pruned.indices[qi]), (
            f"N={n_docs} query {qi}: pruned top-{K} diverged: "
            f"{sorted(exhaustive.indices[qi])} vs {sorted(pruned.indices[qi])}")
        np.testing.assert_allclose(
            np.sort(pruned.distances[qi]), np.sort(exhaustive.distances[qi]),
            rtol=1e-4, atol=1e-5)
    assert (pruned.solved < n_docs).all(), "prune stage excluded nothing"

    t_full = timeit(lambda: engine.search(queries, K, prune=None),
                    warmup=1, iters=3)
    t_prune = timeit(lambda: engine.search(queries, K, prune="rwmd"),
                     warmup=1, iters=3)
    frac = float(pruned.solved.mean()) / n_docs
    out(row(f"fig8.topk_exhaustive_n{n_docs}", t_full * 1e6,
            f"Q={len(queries)} k={K}"))
    out(row(f"fig8.topk_pruned_n{n_docs}", t_prune * 1e6,
            f"speedup={t_full / t_prune:.2f}x solved_frac={frac:.3f}"))


def main(out=print) -> None:
    sizes = (1024,) if os.environ.get("FIG8_SMOKE") else (1024, 8192)
    for n_docs in sizes:
        _bench_one(n_docs, out)


if __name__ == "__main__":
    main()
