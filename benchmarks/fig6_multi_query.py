"""Paper Fig 6: multiple source documents at once, runtime vs v_r (query
word count). The paper observes per-query cost growing with v_r and the
first query paying cold-miss overhead (for us: jit compile, excluded)."""
from __future__ import annotations

import numpy as np

from repro.core import one_to_many
from repro.data.corpus import make_corpus
from .common import row, timeit


def main(out=print) -> None:
    corpus = make_corpus(vocab_size=8192, embed_dim=64, n_docs=1024,
                         n_queries=6, words_per_doc=(19, 43), seed=1)
    for i, q in enumerate(corpus.queries):
        v_r = int((q > 0).sum())
        t = timeit(lambda q=q: one_to_many(q, corpus.docs, corpus.vecs,
                                           lam=9.0, n_iter=15, impl="sparse"),
                   warmup=1, iters=3)
        out(row(f"fig6.query{i}_vr{v_r}", t * 1e6, f"v_r={v_r}"))


if __name__ == "__main__":
    main()
