"""Paper Fig 6: multiple source documents at once, runtime vs v_r (query
word count). The paper observes per-query cost growing with v_r and the
first query paying cold-miss overhead (for us: jit compile, excluded).

Extended with the batched-engine comparison (ISSUE 1): the same Q-query
workload through (a) the SEED per-query Python loop — replicated verbatim
below and pinned so the baseline stays fixed across PRs (the library's own
loop path has since changed: GM is no longer materialized) — and (b) the
persistent-index bucketed engine (one corpus freeze, one solve per
v_r-bucket chunk, doc-length-grouped ELL). Compile is excluded from both
via warmup, and the engine's distances are asserted against the loop's on
every run before any timing is reported.

``LAM = 1.0`` everywhere (including the per-query rows, which kept the
seed's 9.0 until ISSUE 2): at this synthetic corpus's distance scale (~10)
a lam of 9 underflows K = exp(-lam*M) to all-zeros and the seed solver's
unguarded 1/x turns every distance into NaN — the seed benchmark was timing
NaN propagation, and ``one_to_many`` now *raises* ``LamUnderflowError`` for
that configuration instead of returning NaN. lam*M ~ 10 keeps the transport
well-posed so the engine-vs-loop distances can be asserted equal.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import WmdEngine, build_index, one_to_many, select_support
from repro.core.sinkhorn import cdist
from repro.data.corpus import make_corpus
from .common import row, timeit

N_QUERIES = 16
N_DOCS = 1024
LAM = 1.0


@functools.partial(jax.jit, static_argnames=("n_iter",))
def _seed_sinkhorn_sparse(r, vecs_sel, vecs, docs, lam, n_iter):
    """Verbatim replica of the SEED sparse solver (pre-ISSUE-1): three
    materialized nnz-sized arrays (G, G_over_r, GM). Pinned baseline."""
    M = cdist(vecs_sel, vecs)
    K = jnp.exp(-lam * M)
    G = jnp.take(K, docs.idx, axis=1)
    GM = jnp.take(K * M, docs.idx, axis=1)
    G_over_r = G / r[:, None, None]
    v_r, n = G.shape[0], G.shape[1]
    live = docs.val > 0
    x = jnp.full((v_r, n), 1.0 / v_r, dtype=G.dtype)

    def body(x, _):
        u = 1.0 / x
        t = jnp.einsum("knl,kn->nl", G, u)
        w = jnp.where(live, docs.val / t, 0.0)
        return jnp.einsum("knl,nl->kn", G_over_r, w), None

    x, _ = lax.scan(body, x, None, length=n_iter)
    u = 1.0 / x
    t = jnp.einsum("knl,kn->nl", G, u)
    w = jnp.where(live, docs.val / t, 0.0)
    return jnp.einsum("kn,knl,nl->n", u, GM, w)


def _seed_loop(queries, docs, vecs_np, lam, n_iter):
    """The seed many_to_many shape: per-query support selection, per-query
    embedding transfer, one jitted solve per distinct v_r."""
    out = []
    for q in queries:
        vecs = jnp.asarray(vecs_np, jnp.float32)
        r, vecs_sel, _ = select_support(q, vecs_np)
        out.append(_seed_sinkhorn_sparse(r, vecs_sel, vecs, docs, lam,
                                         n_iter))
    return out


def main(out=print) -> None:
    corpus = make_corpus(vocab_size=8192, embed_dim=64, n_docs=N_DOCS,
                         n_queries=N_QUERIES, words_per_doc=(19, 43), seed=1)
    for i, q in enumerate(corpus.queries[:6]):
        v_r = int((q > 0).sum())
        t = timeit(lambda q=q: one_to_many(q, corpus.docs, corpus.vecs,
                                           lam=LAM, n_iter=15, impl="sparse"),
                   warmup=1, iters=3)
        out(row(f"fig6.query{i}_vr{v_r}", t * 1e6, f"v_r={v_r}"))

    # batched vs seed loop: same Q queries, mixed v_r, one shared corpus
    queries = list(corpus.queries)
    t_loop = timeit(lambda: _seed_loop(queries, corpus.docs, corpus.vecs,
                                       LAM, 15),
                    warmup=1, iters=5)
    engine = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=LAM,
                       n_iter=15, impl="sparse")
    t_batch = timeit(lambda: engine.query_batch(queries), warmup=1, iters=5)
    # distances must agree before the timing means anything
    ref = _seed_loop(queries, corpus.docs, corpus.vecs, LAM, 15)
    got = np.asarray(engine.query_batch(queries))
    err = max(float(np.abs(got[i] - np.asarray(ref[i])).max())
              for i in range(len(queries)))
    assert err < 1e-3, f"batched/seed-loop distances diverge: {err}"
    out(row("fig6.multi_query_seed_loop", t_loop * 1e6, f"Q={len(queries)}"))
    out(row("fig6.multi_query_batched", t_batch * 1e6,
            f"Q={len(queries)} speedup={t_loop / t_batch:.2f}x "
            f"maxerr={err:.1e}"))


if __name__ == "__main__":
    main()
