"""Beyond-paper Fig 13: the rank-then-refine recall/latency Pareto.

The cascade's lower bounds already RANK well (LC-RWMD, Atasu et al.
arXiv 1711.07227), so ``mode="refine"`` turns them into a bounded solve
budget: rank every candidate by the cascade's tightest bound,
Sinkhorn-solve only each query's top ``refine_factor * k`` picks.
Distances returned for the reported top-k are exact truncated-Sinkhorn
scores — only MEMBERSHIP is approximate, and this benchmark measures it
the same way fig9 measures nprobe: recall@k against the exhaustive
oracle, swept over (nprobe x tier x refine_factor x lam) on the fig8
near-duplicate corpus.

Correctness gates run BEFORE any timing is reported:

1. recall@k is monotone non-decreasing in ``refine_factor`` (each
   query's pick set is nested by construction — a violation is a bug,
   not noise);
2. recall@k == 1.0 at the covering factor (``refine_factor * k >=
   n_docs``: refine degenerates to the exact path) with distances equal
   to the exhaustive oracle's;
3. the same covering-factor equivalence on a 1-shard
   :class:`ShardedWmdEngine` (per-shard refine, merge unchanged).

Emitted records: ``fig13.recall_*`` values are recall@k * 100 (gated
with a MIN direction in ``benchmarks/compare.py`` — a recall drop is a
regression even though its wall-ratio is < 1) and ``fig13.wall_*``
values are usec per search batch (gated with the usual max-ratio).

``FIG13_SMOKE=1`` runs the small config only (CI smoke); all three
gates still assert.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import WmdEngine, build_index

from .common import recall_at_k, row, timeit
from .fig8_topk_prune import LAM, N_ITER, dedup_corpus
from .fig9_ivf_prune import _n_clusters

K = 10
PRUNE = "ivf+pivot+wcd+rwmd"
RF_CURVE = (1, 2, 4, 8)


def _covering_factor(n_docs: int, k: int) -> int:
    """Smallest refine_factor whose per-query budget covers every doc."""
    return -(-n_docs // k)


def _assert_covering(res, exhaustive, n_docs, label):
    rec = recall_at_k(res.indices, exhaustive.indices, K)
    assert rec == 1.0, \
        f"{label}: refine recall@{K}={rec} at covering factor"
    np.testing.assert_allclose(
        np.sort(res.distances, axis=1),
        np.sort(exhaustive.distances, axis=1),
        rtol=1e-4, atol=1e-5)


def _bench_one(n_docs, lams, nprobes, out):
    corpus = dedup_corpus(n_docs)
    queries = list(corpus.queries)
    index = build_index(corpus.docs, corpus.vecs,
                        n_clusters=_n_clusters(n_docs))
    rf_cover = _covering_factor(n_docs, K)
    for lam in lams:
        engine = WmdEngine(index, lam=lam, n_iter=N_ITER, impl="sparse")
        exhaustive = engine.search(queries, K, prune=None)

        # ---- correctness gates FIRST (assert, then time) ----
        recalls = []
        for rf in RF_CURVE:
            res = engine.search(queries, K, prune=PRUNE, mode="refine",
                                refine_factor=rf)
            recalls.append(recall_at_k(res.indices, exhaustive.indices, K))
        for lo, hi in zip(recalls, recalls[1:]):
            assert hi >= lo, \
                f"lam={lam:g}: recall not monotone in refine_factor: " \
                f"{recalls} over {RF_CURVE}"
        cover = engine.search(queries, K, prune=PRUNE, mode="refine",
                              refine_factor=rf_cover)
        _assert_covering(cover, exhaustive, n_docs, f"lam={lam:g}")

        # ---- the Pareto curves ----
        for nprobe in nprobes:
            np_label = "all" if nprobe is None else str(nprobe)
            t_exact = timeit(
                lambda: engine.search(queries, K, prune=PRUNE,
                                      nprobe=nprobe),
                warmup=1, iters=3)
            out(row(f"fig13.wall_exact_np{np_label}_lam{lam:g}_n{n_docs}",
                    t_exact * 1e6, f"Q={len(queries)}"))
            for rf in RF_CURVE:
                res = engine.search(queries, K, prune=PRUNE,
                                    nprobe=nprobe, mode="refine",
                                    refine_factor=rf)
                rec = recall_at_k(res.indices, exhaustive.indices, K)
                t_rf = timeit(
                    lambda: engine.search(queries, K, prune=PRUNE,
                                          nprobe=nprobe, mode="refine",
                                          refine_factor=rf),
                    warmup=1, iters=3)
                out(row(
                    f"fig13.recall_rf{rf}_np{np_label}"
                    f"_lam{lam:g}_n{n_docs}",
                    rec * 100.0,
                    f"recall@{K}={rec:.3f} "
                    f"solved={float(res.solved.mean()):.1f}/{n_docs}"))
                out(row(
                    f"fig13.wall_refine_rf{rf}_np{np_label}"
                    f"_lam{lam:g}_n{n_docs}",
                    t_rf * 1e6,
                    f"vs exact {t_exact / t_rf:.2f}x"))
        from repro.runtime.serving import rwmd_topk
        idx_r, _ = rwmd_topk(engine, queries, K)
        t_rwmd = timeit(lambda: rwmd_topk(engine, queries, K),
                        warmup=1, iters=3)
        out(row(f"fig13.wall_rwmd_lam{lam:g}_n{n_docs}", t_rwmd * 1e6,
                f"recall@{K}="
                f"{recall_at_k(idx_r, exhaustive.indices, K):.3f} "
                "(bound-only, no solve)"))

    # ---- sharded covering-factor equivalence (1 shard, in-process) ----
    from repro.core import ShardedWmdEngine, shard_corpus
    sindex = shard_corpus(corpus.docs, corpus.vecs, 1,
                          n_clusters=_n_clusters(n_docs))
    seng = ShardedWmdEngine(sindex, lam=lams[0], n_iter=N_ITER)
    sexh = seng.search(queries, K, prune=None)
    scover = seng.search(queries, K, prune=PRUNE, mode="refine",
                         refine_factor=rf_cover)
    _assert_covering(scover, sexh, n_docs, "sharded(1)")


def main(out=print) -> None:
    if os.environ.get("FIG13_SMOKE"):
        _bench_one(512, lams=(LAM,), nprobes=(None,), out=out)
    else:
        _bench_one(2048, lams=(LAM, 2 * LAM), nprobes=(None, 4), out=out)


if __name__ == "__main__":
    main()
