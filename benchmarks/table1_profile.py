"""Paper Table 1: per-stage profile of the WMD pipeline.

The paper profiles the python/MKL implementation and finds the dense
``v = c.multiply(1/(K.T @ u))`` line takes 91.9% (+6.1% for the final one)
of runtime, motivating the sparse transformation. We reproduce the stage
split on the dense path and then measure the same stages on the sparse
path (corpus statistics scaled to CPU: V/L work ratio preserved in spirit).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.sinkhorn import cdist, select_support
from repro.core.sparse import padded_docs_to_dense
from repro.data.corpus import make_corpus
from .common import row, timeit

V, W, N = 16384, 64, 1024


def main(out=print) -> None:
    corpus = make_corpus(vocab_size=V, embed_dim=W, n_docs=N, n_queries=1,
                         words_per_doc=(19, 43), seed=0)
    q = corpus.queries[0]
    r, vecs_sel, _ = select_support(q, corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    c = jnp.asarray(padded_docs_to_dense(corpus.docs, V))
    lam = 9.0

    # --- dense stages (paper Fig 2 lines) --------------------------------
    f_cdist = jax.jit(lambda: cdist(vecs_sel, vecs))
    m = f_cdist()
    f_k = jax.jit(lambda: jnp.exp(-lam * m))
    k = f_k()
    u = jnp.full((r.shape[0], N), float(r.shape[0]))
    f_sddmm_line = jax.jit(lambda u: c * (1.0 / (k.T @ u)))   # Table 1 hot line
    v = f_sddmm_line(u)
    k_over_r = k / r[:, None]
    f_spmm_line = jax.jit(lambda v: k_over_r @ v)

    t_cdist = timeit(f_cdist)
    t_k = timeit(f_k)
    t_hot = timeit(f_sddmm_line, u)
    t_spmm = timeit(f_spmm_line, v)
    tot = t_cdist + t_k + 15 * (t_hot + t_spmm)
    out(row("table1.dense.cdist", t_cdist * 1e6,
            f"{100*t_cdist/tot:.1f}%_of_step"))
    out(row("table1.dense.exp_k", t_k * 1e6, f"{100*t_k/tot:.1f}%"))
    out(row("table1.dense.sddmm_line", t_hot * 1e6,
            f"{100*15*t_hot/tot:.1f}%_hot_line_paper_91.9%"))
    out(row("table1.dense.spmm_line", t_spmm * 1e6,
            f"{100*15*t_spmm/tot:.1f}%"))

    # --- sparse stages (paper §4 kernels, ELL form) ----------------------
    from repro.core.sinkhorn_sparse import precompute_sparse
    pre = precompute_sparse(r, vecs_sel, vecs, corpus.docs, lam)
    x = jnp.full((r.shape[0], N), float(r.shape[0]))

    @jax.jit
    def sparse_iter(x):
        u = 1.0 / x
        t = jnp.einsum("knl,kn->nl", pre.G, u)
        w = jnp.where(pre.val > 0, pre.val / t, 0.0)
        return jnp.einsum("knl,nl->kn", pre.G_over_r, w)

    t_sp = timeit(sparse_iter, x)
    out(row("table1.sparse.fused_iter", t_sp * 1e6,
            f"dense_iter/sparse_iter={((t_hot + t_spmm) / t_sp):.1f}x"))


if __name__ == "__main__":
    main()
