"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (s) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def recall_at_k(result_indices, oracle_indices, k: int) -> float:
    """Mean per-query recall@k of ``result_indices`` against the oracle's
    top-k id sets (set intersection: tie ORDER differences don't count as
    misses). Both arguments are (Q, >=k) id arrays; rows are compared
    query-by-query. This is the single recall definition shared by the
    fig9/fig13 curves and the oracle-recomputation tests."""
    per_q = [
        len(set(np.asarray(result_indices[qi])[:k].tolist())
            & set(np.asarray(oracle_indices[qi])[:k].tolist())) / k
        for qi in range(len(oracle_indices))
    ]
    return float(np.mean(per_q))
