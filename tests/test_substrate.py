"""Substrate tests: pipeline determinism, checkpoint atomicity/restore,
compression error-feedback, fault-tolerance policies, router balance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import DataConfig, batch_at_step
from repro.checkpoint import checkpointer as ckpt
from repro.runtime import compression as C
from repro.runtime.fault_tolerance import (Heartbeat, StepGuard, PoisonStep,
                                           scaled_global_batch)


def test_pipeline_deterministic_and_host_sharded():
    dc = DataConfig(vocab_size=1000, global_batch=8, seq_len=16, seed=3)
    b1 = batch_at_step(dc, step=17)
    b2 = batch_at_step(dc, step=17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(dc, step=18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host slices are disjoint streams
    h0 = batch_at_step(dc, 17, host_id=0, n_hosts=2)
    h1 = batch_at_step(dc, 17, host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are the next-token shift
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                  np.asarray(b1["labels"])[:, :-1])


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                        "b": jnp.ones((4,))},
             "extra": {"step": jnp.asarray(7)}}
    d = str(tmp_path)
    ckpt.save(d, 7, state)
    assert ckpt.latest_step(d) == 7
    got = ckpt.restore(d, 7, state)
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])
    # partial (tmp) checkpoints are invisible
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt.latest_step(d) == 7
    # corruption detection
    ckpt.save(d, 9, state)
    path = os.path.join(d, "step_00000009", "params.npz")
    with open(path, "r+b") as f:
        f.seek(200)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(Exception):
        ckpt.restore(d, 9, state)


def test_checkpoint_resume_exact(tmp_path):
    """restart from step k replays the identical training trajectory."""
    from repro.configs.base import get_config
    from repro.models import transformer as T, model as M
    from repro.optim import adamw
    cfg = get_config("granite_3_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(M.make_train_step(cfg))
    dc = DataConfig(cfg.vocab_size, 2, 32)

    for i in range(3):
        params, opt, _ = step(params, opt, batch_at_step(dc, i))
    ckpt.save(str(tmp_path), 3, {"params": params})
    saved = jax.tree.map(np.asarray, params)
    for i in range(3, 5):
        params, opt, _ = step(params, opt, batch_at_step(dc, i))
    final_a = jax.tree.map(np.asarray, params)

    # resume: restore at 3, replay steps 3-4 (opt state kept in this test
    # process; full restart path covered by the roundtrip test)
    params2 = ckpt.restore(str(tmp_path), 3, {"params": saved})["params"]
    opt2 = adamw.init(params2)
    # rebuild optimizer moments by replaying — here just assert params match
    np.testing.assert_allclose(jax.tree.leaves(params2)[0],
                               jax.tree.leaves(saved)[0])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), shape=st.sampled_from([(64,), (33,),
                                                         (128, 5), (7, 13)]))
def test_quantize_roundtrip_bounded_error(seed, shape):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 3)
    q, s = C.quantize_int8(x, block=32)
    y = C.dequantize_int8(q, s, x.shape, x.dtype)
    # error bounded by scale/2 per block = absmax/254
    err = np.abs(np.asarray(x - y))
    bound = np.abs(np.asarray(x)).max() / 127.0
    assert err.max() <= bound + 1e-6


def test_error_feedback_preserves_signal():
    """sum over steps of compressed grads ~ sum of true grads (EF property)."""
    g = {"w": jnp.full((100,), 0.003)}   # small values: big relative quant err
    res = C.zero_residual(g)
    tot = np.zeros(100, np.float32)
    for _ in range(50):
        cg, res = C.compress_grads_with_feedback(g, res)
        tot += np.asarray(cg["w"])
    want = 50 * 0.003
    np.testing.assert_allclose(tot, want, rtol=0.02)


def test_stepguard_retries_then_raises():
    calls = {"n": 0}
    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42
    assert StepGuard(backoff_s=0.0).run(flaky) == 42
    def poison():
        raise PoisonStep("nan loss")
    with pytest.raises(PoisonStep):
        StepGuard(backoff_s=0.0).run(poison)


def test_heartbeat_flags_stragglers():
    hb = Heartbeat(threshold=1.5, patience=2)
    for step in range(6):
        for host in range(4):
            hb.record(host, 1.0 if host != 2 else 3.0)
        out = hb.stragglers()
    assert out == [2]


def test_elastic_batch_policy():
    assert scaled_global_batch(256, 32, 31, keep_global=True) % 31 == 0
    assert scaled_global_batch(256, 32, 16, keep_global=False) == 128


def test_sinkhorn_router_reduces_drops():
    """The paper-technique router must drop fewer tokens at capacity than
    softmax top-k on skewed logits (the MoE integration claim)."""
    from repro.models.moe import init_moe, moe_dropped_fraction
    key = jax.random.PRNGKey(0)
    p = init_moe(key, d_model=32, d_ff=16, n_experts=8, n_shared=0, top_k=2)
    # skewed inputs -> skewed router logits
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32)) \
        + jax.random.normal(jax.random.PRNGKey(2), (1, 1, 32)) * 2.0
    d_topk = float(moe_dropped_fraction(p, x, 2, "topk"))
    d_sink = float(moe_dropped_fraction(p, x, 2, "sinkhorn"))
    assert d_sink <= d_topk + 1e-6, (d_sink, d_topk)
