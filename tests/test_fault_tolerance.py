"""Retry/poison/watchdog classification for the hardened guards
(ISSUE 6): StepGuard's non-finite poisoning + jittered backoff, and
DispatchGuard's watchdog, per-attempt hooks, and deterministic-failure
classification."""
import time

import numpy as np
import pytest

from repro.core.sinkhorn import LamUnderflowError
from repro.runtime.fault_tolerance import (DispatchFailed, DispatchGuard,
                                           Heartbeat, PoisonStep, StepGuard)


def test_stepguard_nonfinite_output_is_poison():
    """check_finite classifies a NaN output as PoisonStep on the FIRST
    attempt — a deterministic NaN re-runs identically, so retrying only
    burns the backoff schedule (the pre-hardening behavior)."""
    calls = {"n": 0}

    def nan_step():
        calls["n"] += 1
        return {"loss": np.float32("nan"), "ok": np.ones(3)}

    with pytest.raises(PoisonStep):
        StepGuard(backoff_s=0.0, check_finite=True).run(nan_step)
    assert calls["n"] == 1      # no retries burned on a deterministic NaN


def test_stepguard_finite_output_passes():
    out = StepGuard(backoff_s=0.0, check_finite=True).run(
        lambda: {"loss": np.float32(1.5), "ids": np.arange(3)})
    assert float(out["loss"]) == 1.5


def test_stepguard_check_finite_off_by_default():
    """Default guards must NOT pay the per-leaf device sync (train.py
    wraps full parameter trees) — NaN outputs pass through un-poisoned."""
    out = StepGuard(backoff_s=0.0).run(lambda: np.float32("nan"))
    assert np.isnan(out)


def test_stepguard_backoff_jittered_and_seeded(monkeypatch):
    """Backoff sleeps follow base * 2^attempt * (1 + jitter*U[0,1)) from
    a seed-deterministic stream: reproducible, never below the
    exponential floor, never above the jitter ceiling."""
    slept = []
    monkeypatch.setattr(time, "sleep", slept.append)

    def run_once():
        slept.clear()
        g = StepGuard(max_retries=3, backoff_s=0.1, jitter=0.5, seed=42)
        with pytest.raises(RuntimeError):
            g.run(lambda: (_ for _ in ()).throw(RuntimeError("transient")))
        return list(slept)

    a, b = run_once(), run_once()
    assert a == b                       # seeded: identical schedules
    assert len(a) == 3                  # sleeps between 4 attempts
    for attempt, s in enumerate(a):
        base = 0.1 * 2 ** attempt
        assert base <= s <= base * 1.5, (attempt, s)
    assert a[0] != a[1] / 2             # jitter actually applied


def test_dispatchguard_poison_never_retried():
    """PoisonStep subclasses AND FloatingPointError (LamUnderflowError)
    are deterministic per-request failures: re-raised on attempt 0 so
    the runtime can isolate, not retried."""
    for exc in (PoisonStep("injected"), LamUnderflowError("lam too hot"),
                FloatingPointError("underflow")):
        calls = {"n": 0}

        def bad():
            calls["n"] += 1
            raise exc

        g = DispatchGuard(backoff_s=0.0)
        with pytest.raises(type(exc)):
            g.run(bad)
        assert calls["n"] == 1, type(exc)
        assert g.retries == 0


def test_dispatchguard_transient_retried_to_success():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    g = DispatchGuard(backoff_s=0.0)
    assert g.run(flaky) == "ok"
    assert g.retries == 2


def test_dispatchguard_exhaustion_is_dispatchfailed():
    """Retries exhausted raises DispatchFailed — deliberately NOT a
    RuntimeError, so an outer guard cannot re-classify it transient and
    re-spend a second retry budget on the same dispatch."""
    g = DispatchGuard(max_retries=2, backoff_s=0.0)
    with pytest.raises(DispatchFailed) as ei:
        g.run(lambda: (_ for _ in ()).throw(RuntimeError("down")))
    assert not isinstance(ei.value, RuntimeError)
    assert "3 attempts" in str(ei.value)
    assert g.retries == 3


def test_dispatchguard_watchdog_counts_stragglers():
    g = DispatchGuard(watchdog_s=0.01, backoff_s=0.0)
    g.run(lambda: time.sleep(0.03) or "slow")
    assert g.watchdog_trips == 1
    g.run(lambda: "fast")
    assert g.watchdog_trips == 1        # fast dispatch: no trip


def test_dispatchguard_before_attempt_hook_inside_guard():
    """The injection hook runs INSIDE the guarded region: a hook that
    raises a transient error consumes a retry, and the hook sees the
    (tag, attempt) pair for each attempt."""
    seen = []

    def hook(tag, attempt):
        seen.append((tag, attempt))
        if attempt == 0:
            raise RuntimeError("injected")

    g = DispatchGuard(backoff_s=0.0, before_attempt=hook)
    assert g.run(lambda: "ok", tag=5) == "ok"
    assert seen == [(5, 0), (5, 1)]
    assert g.retries == 1


def test_heartbeat_ema_accessor():
    hb = Heartbeat(ema_alpha=0.5)
    assert hb.ema(0) is None            # no record yet
    hb.record(0, 2.0)
    assert hb.ema(0) == pytest.approx(2.0)
    hb.record(0, 4.0)
    assert hb.ema(0) == pytest.approx(3.0)
    assert hb.ema(1) is None            # lanes are independent
