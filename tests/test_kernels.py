"""Per-kernel allclose vs pure-jnp oracles, shape/dtype sweeps (interpret
mode on CPU; same call sites compile to Mosaic on TPU)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import (cdist_exp_ref, sddmm_spmm_step_ref,
                               sinkhorn_fused_all_ref)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- cdist_exp
@pytest.mark.parametrize("v_r,v,w,block_v", [
    (8, 256, 128, 128), (19, 512, 300, 256), (43, 384, 64, 128),
    (5, 128, 32, 128), (64, 1024, 256, 512),
])
def test_cdist_exp_shapes(rng, v_r, v, w, block_v):
    a, b = _rand(rng, v_r, w), _rand(rng, v, w)
    r = jnp.asarray(rng.uniform(0.01, 1.0, v_r).astype(np.float32))
    lam = 5.0
    m, k, kr = ops.cdist_exp(a, b, r, lam, block_v=block_v)
    mr, kref, krr = cdist_exp_ref(a, b, r, lam)
    assert m.shape == (v_r, v)
    np.testing.assert_allclose(m, mr, rtol=2e-3, atol=5e-3)
    np.testing.assert_allclose(k, kref, rtol=2e-3, atol=5e-3)
    np.testing.assert_allclose(kr, krr, rtol=2e-3, atol=5e-2)


def test_cdist_exp_k_only_matches_full(rng):
    """k_only mode (fused-solver path: no dead M/K_over_r stores) returns
    the same K as the full three-output kernel."""
    a, b = _rand(rng, 16, 128), _rand(rng, 256, 128)
    r = jnp.asarray(rng.uniform(0.1, 1.0, 16).astype(np.float32))
    _, k_full, _ = ops.cdist_exp(a, b, r, 4.0)
    k_only = ops.cdist_exp(a, b, r, 4.0, k_only=True)
    np.testing.assert_array_equal(np.asarray(k_only), np.asarray(k_full))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_cdist_exp_dtypes(rng, dtype):
    # skip on the actual capability probe, not a hardcoded marker: a box
    # running with JAX_ENABLE_X64=1 exercises the float64 path for real
    # instead of silently skipping it (ISSUE 5 hygiene fix)
    import jax
    if dtype == jnp.float64 and not jax.config.jax_enable_x64:
        pytest.skip("jax_enable_x64 is off on this box (fp32 is the TPU "
                    "target dtype); enable JAX_ENABLE_X64=1 to run this")
    a, b = _rand(rng, 16, 128), _rand(rng, 256, 128)
    r = jnp.asarray(rng.uniform(0.1, 1.0, 16).astype(np.float32))
    m, k, kr = ops.cdist_exp(a.astype(dtype), b.astype(dtype),
                             r.astype(dtype), 3.0)
    assert k.dtype == dtype


# ------------------------------------------------------------ sddmm_spmm step
@pytest.mark.parametrize("v_r,n,length,block_n", [
    (8, 128, 128, 128), (19, 64, 40, 32), (32, 256, 64, 128), (3, 32, 8, 32),
])
def test_sddmm_spmm_step_shapes(rng, v_r, n, length, block_n):
    g = jnp.abs(_rand(rng, v_r, n, length)) + 0.1
    gor = g * 1.7
    val = jnp.abs(_rand(rng, n, length))
    val = jnp.where(val > 0.8, val, 0.0)          # sparse pattern
    x = jnp.abs(_rand(rng, v_r, n)) + 0.5
    out = ops.sddmm_spmm_step(g, gor, val, x, block_n=block_n)
    ref = sddmm_spmm_step_ref(g, gor, val, x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- fused full solver
def _rand_g(rng, v_r, n, length):
    """G entries as the solver sees them: gathered K = exp(-lam*M) in (0, 1]."""
    return jnp.asarray(rng.uniform(0.02, 1.0,
                                   (v_r, n, length)).astype(np.float32))


@pytest.mark.parametrize("v_r,n,length,n_iter,block_n", [
    (19, 128, 40, 15, 64), (8, 64, 16, 5, 32), (43, 256, 64, 25, 128),
])
def test_sinkhorn_fused_all_shapes(rng, v_r, n, length, n_iter, block_n):
    g = _rand_g(rng, v_r, n, length)
    val = jnp.abs(_rand(rng, n, length))
    val = jnp.where(val > 0.5, val, 0.0)
    val = val.at[:, 0].set(1.0)                   # every doc has >=1 word
    r = jnp.asarray(rng.uniform(0.1, 1.0, v_r).astype(np.float32))
    lam = 7.0
    out = ops.sinkhorn_fused_all(g, val, r, lam, n_iter, block_n=block_n)
    ref = sinkhorn_fused_all_ref(g, val, r, lam, n_iter)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


def test_fused_all_handles_padded_rows(rng):
    """Padded query rows (G row == 0, r == 1) must be exactly inert."""
    v_r, n, length = 10, 64, 16
    g = _rand_g(rng, v_r, n, length)
    val = jnp.where(jnp.abs(_rand(rng, n, length)) > 0.5, 1.0, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, v_r).astype(np.float32))
    base = ops.sinkhorn_fused_all(g, val, r, 5.0, 10)
    # append 6 dead rows
    zpad = jnp.zeros((6, n, length))
    g2 = jnp.concatenate([g, zpad])
    r2 = jnp.concatenate([r, jnp.ones(6)])
    padded = ops.sinkhorn_fused_all(g2, val, r2, 5.0, 10)
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- property-based sweep
@settings(max_examples=15, deadline=None)
@given(v_r=st.integers(2, 24), n=st.integers(1, 6), length=st.integers(2, 24),
       seed=st.integers(0, 2**31 - 1))
def test_step_kernel_property(v_r, n, length, seed):
    rng = np.random.default_rng(seed)
    n *= 32
    g = jnp.asarray(np.abs(rng.standard_normal((v_r, n, length))) + 0.1,
                    dtype=jnp.float32)
    gor = g * 0.5
    val = jnp.asarray(
        np.where(rng.random((n, length)) > 0.6,
                 rng.random((n, length)), 0).astype(np.float32))
    x = jnp.asarray(np.abs(rng.standard_normal((v_r, n))) + 0.5,
                    dtype=jnp.float32)
    out = ops.sddmm_spmm_step(g, gor, val, x, block_n=32)
    ref = sddmm_spmm_step_ref(g, gor, val, x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_kernel_path_equals_library_path(small_corpus):
    from repro.core import one_to_many
    q = small_corpus.queries[0]
    a = one_to_many(q, small_corpus.docs, small_corpus.vecs, 9.0, 30,
                    impl="sparse")
    b = one_to_many(q, small_corpus.docs, small_corpus.vecs, 9.0, 30,
                    impl="kernel")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------- BSR kernel
@pytest.mark.parametrize("v,n,bv,bn,density", [
    (256, 128, 64, 32, 0.0008), (512, 256, 128, 128, 0.00004),
])
def test_bsr_sddmm(rng, v, n, bv, bn, density):
    """Block-sparse SDDMM (DESIGN.md §4 tile-granular adaptation) matches
    the dense product at retained tiles; zero tiles are never computed."""
    from repro.core.sparse import block_sparse_from_dense, block_density
    from repro.kernels.bsr_sddmm import bsr_sddmm, bsr_sddmm_ref
    c = np.where(rng.random((v, n)) < density,
                 rng.random((v, n)), 0.0).astype(np.float32)
    c_bsr = block_sparse_from_dense(c, bv, bn)
    assert block_density(c, bv, bn) < 1.0          # actually sparse in tiles
    v_r = 24
    kt = jnp.asarray(rng.standard_normal((v, v_r)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((v_r, n)).astype(np.float32))
    got = bsr_sddmm(kt, u, c_bsr, interpret=True)
    want = bsr_sddmm_ref(kt, u, c_bsr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
