"""Property-based tests on the system's mathematical invariants.

The paper (§2, citing Cuturi'13) claims the Sinkhorn distance is symmetric,
satisfies the triangle inequality, and approaches exact EMD for large lam.
These are checkable invariants of OUR implementation — hypothesis sweeps
random corpora."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import one_to_many
from repro.core.sparse import PaddedDocs
from repro.data.corpus import make_corpus


def _doc_as_query(docs: PaddedDocs, j: int, vocab: int) -> np.ndarray:
    q = np.zeros(vocab, np.float32)
    idx = np.asarray(docs.idx[j])
    val = np.asarray(docs.val[j])
    q[idx[val > 0]] = val[val > 0]
    return q


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_symmetry(seed):
    """WMD(a, b) == WMD(b, a) (the OT objective is symmetric in the
    marginals when M is symmetric)."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=4, n_queries=0,
                       seed=seed)
    qa = _doc_as_query(corp.docs, 0, 256)
    qb = _doc_as_query(corp.docs, 1, 256)
    dab = float(one_to_many(qa, corp.docs, corp.vecs, lam=20.0, n_iter=300,
                            impl="dense_stabilized")[1])
    dba = float(one_to_many(qb, corp.docs, corp.vecs, lam=20.0, n_iter=300,
                            impl="dense_stabilized")[0])
    assert abs(dab - dba) < 5e-3 * max(dab, 1.0), (dab, dba)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_triangle_inequality(seed):
    """d(a,c) <= d(a,b) + d(b,c) + eps (paper §2: Sinkhorn distance is a
    metric for large enough entropy)."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=3, n_queries=0,
                       seed=seed + 77)
    q = [_doc_as_query(corp.docs, j, 256) for j in range(3)]
    def d(i, j):
        return float(one_to_many(q[i], corp.docs, corp.vecs, lam=30.0,
                                 n_iter=400, impl="dense_stabilized")[j])
    dac, dab, dbc = d(0, 2), d(0, 1), d(1, 2)
    assert dac <= dab + dbc + 1e-2, (dac, dab, dbc)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(0.25, 4.0))
def test_scale_equivariance(seed, scale):
    """Scaling embeddings by c scales WMD by c (with lam rescaled by 1/c:
    the transport plan is invariant, the cost is linear in M)."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=8, n_queries=1,
                       seed=seed)
    q = corp.queries[0]
    d1 = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=8.0,
                                n_iter=200, impl="sparse"))
    d2 = np.asarray(one_to_many(q, corp.docs, corp.vecs * scale,
                                lam=8.0 / scale, n_iter=200, impl="sparse"))
    np.testing.assert_allclose(d2, d1 * scale, rtol=2e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_doc_permutation_equivariance(seed):
    rng = np.random.default_rng(seed)
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=16, n_queries=1,
                       seed=seed)
    q = corp.queries[0]
    perm = rng.permutation(16)
    shuffled = PaddedDocs(idx=corp.docs.idx[perm], val=corp.docs.val[perm])
    d1 = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=8.0, n_iter=60,
                                impl="sparse"))
    d2 = np.asarray(one_to_many(q, shuffled, corp.vecs, lam=8.0, n_iter=60,
                                impl="sparse"))
    np.testing.assert_allclose(d2, d1[perm], rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), lam=st.floats(2.0, 12.0))
def test_padding_invariance(seed, lam):
    """Extra ELL padding slots (val == 0) never change distances."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=8, n_queries=1,
                       seed=seed)
    q = corp.queries[0]
    d1 = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=lam, n_iter=40,
                                impl="sparse"))
    L = corp.docs.max_words
    padded = PaddedDocs(
        idx=jnp.pad(corp.docs.idx, ((0, 0), (0, 7))),
        val=jnp.pad(corp.docs.val, ((0, 0), (0, 7))))
    d2 = np.asarray(one_to_many(q, padded, corp.vecs, lam=lam, n_iter=40,
                                impl="sparse"))
    np.testing.assert_allclose(d2, d1, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(2, 6),
       e=st.sampled_from([4, 8, 16]))
def test_sinkhorn_router_marginals(seed, t, e):
    """Row sums == 1; column loads ~uniform — for ANY logits."""
    import jax
    from repro.core.router import sinkhorn_route
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t * 32, e)) * 5.0
    p = np.asarray(sinkhorn_route(logits, n_iter=12))
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-4)
    col = p.sum(0)
    assert col.max() / col.mean() < 1.05, col


def test_two_level_scan_matches_flat():
    """sqrt-remat grouping is numerically identical to the flat stack."""
    import jax
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("granite_3_2b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, num_layers=7)      # g*k + rem = 2*3 + 1
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    h_remat, _ = T.forward(cfg, params, tokens, remat=True)
    h_plain, _ = T.forward(cfg, params, tokens, remat=False)
    np.testing.assert_allclose(np.asarray(h_remat), np.asarray(h_plain),
                               rtol=1e-5, atol=1e-5)
