"""Optional-dependency shim for ``hypothesis``.

The property-based tests use a small subset of the hypothesis API
(``given`` / ``settings`` / three strategies). When the real package is
installed (``pip install -e .[test]``) it is used directly; otherwise this
module provides a tiny deterministic fallback so the tier-1 suite still
collects and exercises every property with seeded pseudo-random examples
(no shrinking, no failure database — coverage over convenience).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_at(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Record max_examples on the (already-wrapped) test function."""
        def apply(fn):
            fn._shim_max_examples = max_examples
            return fn
        return apply

    def given(**strategies):
        """Run the test body over deterministic strategy draws.

        The wrapper intentionally takes no parameters (and does not set
        ``__wrapped__``) so pytest never mistakes strategy arguments for
        fixtures.
        """
        def apply(fn):
            def run_examples():
                n = getattr(run_examples, "_shim_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {name: s.example_at(rng)
                             for name, s in strategies.items()}
                    fn(**drawn)
            run_examples.__name__ = fn.__name__
            run_examples.__doc__ = fn.__doc__
            run_examples.__module__ = fn.__module__
            return run_examples
        return apply
