"""ISSUE 5 metamorphic tests: per-query residual scoping + warm-started
survivor solves.

The tentpole reworks the adaptive loop's convergence machinery from one
chunk-global scalar into per-query scoping (each query's residual covers
only its own live candidate slots, converged queries freeze their
x-columns, the loop exits when every live query converged or the cap
hits) and warm-starts the cascade's survivor solve from the seed solve's
converged profile. These tests pin the metamorphic contracts:

- per-query exit == chunk-global exit top-k on the fig8 dedup corpus,
  with the scoped engine realizing strictly fewer iterations;
- a planted one-stubborn-query chunk exits the other queries early
  (realized per-query iters asserted), and query/doc padding is inert;
- warm-started survivor solves == cold solves bit-tolerant, with
  strictly fewer realized survivor iterations;
- the distributed per-query (Q,) ``lax.pmax`` path and the kernel
  ``resmask`` scoping agree with their unscoped selves where it matters.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import WmdEngine, build_index
from repro.core.index import _gather_g, _solve_gathered
from repro.kernels import ops


@pytest.fixture(scope="module")
def dedup():
    from benchmarks.fig8_topk_prune import dedup_corpus

    return dedup_corpus(256, vocab=1024, embed_dim=32, seed=5)


@pytest.fixture(scope="module")
def dedup_index(dedup):
    return build_index(dedup.docs, dedup.vecs)


def _topk_sets(res):
    return [set(row.tolist()) for row in res.indices]


# ------------------------------------------------- per-query == chunk top-k
def test_per_query_exit_matches_chunk_topk(dedup, dedup_index):
    """Scoping the exit test per query must not change WHAT is retrieved
    — only how many iterations each query pays. ``iter_stats`` charges a
    chunk-scoped dispatch's exit to every live query (that is its real
    cost), so the per-query mean must come out strictly below it once
    any query freezes before its slowest chunkmate."""
    qs = list(dedup.queries)
    chunk = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=1e-2,
                      check_every=2, scope="chunk")
    query = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=1e-2,
                      check_every=2, scope="query")
    r_c = chunk.search(qs, 10, prune="rwmd")
    r_q = query.search(qs, 10, prune="rwmd")
    assert _topk_sets(r_c) == _topk_sets(r_q)
    np.testing.assert_allclose(np.sort(r_q.distances, axis=1),
                               np.sort(r_c.distances, axis=1),
                               rtol=2e-2, atol=1e-3)
    it_c, it_q = chunk.iter_stats(), query.iter_stats()
    assert it_q.mean() < it_c.mean(), (it_c, it_q)
    assert it_q.max() <= it_c.max()


def test_per_query_matches_fixed_reference(dedup, dedup_index):
    """And against the fixed-iteration reference (the fig10 gate): same
    top-k, realized mean strictly below the cap."""
    qs = list(dedup.queries)
    fixed = WmdEngine(dedup_index, lam=1.0, n_iter=60)
    scoped = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=3e-3,
                       check_every=2)
    r_f = fixed.search(qs, 10, prune="rwmd")
    r_s = scoped.search(qs, 10, prune="rwmd")
    assert _topk_sets(r_f) == _topk_sets(r_s)
    iters = scoped.iter_stats()
    assert iters.mean() < 60 and iters.size > 0


# ----------------------------------------------- planted stubborn query
def _group_mask(engine, queries, doc_scopes, width):
    """Stage ``queries`` as ONE chunk against the whole corpus and build
    the (Q, N_pad) per-query candidate mask from ``doc_scopes`` (storage
    positions; None = all docs)."""
    index = engine.index
    n = index.n_docs
    sup, r, mask = engine._prep_chunk(queries, width)
    all_ids = np.arange(n, dtype=np.int32)
    grp = index.subset(all_ids, storage=True)
    n_pad = grp.docs.idx.shape[0]
    qdoc = np.zeros((sup.shape[0], n_pad), bool)
    for qi, scope in enumerate(doc_scopes):
        if scope is None:
            qdoc[qi, :n] = True
        else:
            qdoc[qi, scope] = True
    return sup, r, mask, grp, jnp.asarray(qdoc)


def test_stubborn_query_does_not_stall_chunkmates(dedup, dedup_index):
    """Plant a chunk with one stubborn query (a dedup query — its
    structured near-dup kernel converges slowly at lam=1) among
    fast-converging iid queries, and pin the metamorphic relation between
    the two scopes: the chunk-global exit is determined by the SLOWEST
    query (``iters_chunk == max(iters_q)`` — each query's trajectory is
    independent, so the slowest one's check sequence is identical in both
    modes), while per-query scoping freezes the fast members at their own
    counts (``min(iters_q) < iters_chunk``) instead of burning the
    chunk's full width until the stubborn one converges."""
    eng = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=1e-2,
                    check_every=2)
    rng = np.random.default_rng(0)

    def rand_q():
        q = np.zeros(dedup.queries.shape[1], np.float32)
        q[rng.choice(q.size, 24, replace=False)] = rng.random(24) + 0.1
        return q

    queries = [rand_q(), rand_q(), rand_q(), dedup.queries[0]]
    width = max(8, -(-max(int((q > 0).sum()) for q in queries) // 8) * 8)
    sup, r, mask, grp, _ = _group_mask(eng, queries, [None] * 4, width)
    kqk, mq = eng._kq(sup, mask)
    g = _gather_g(kqk, grp.docs.idx)
    args = (eng.lam, eng.n_iter, eng.tol, eng.check_every, "fp32", False)
    wmd, iters = _solve_gathered(g, mq, grp.docs.idx, grp.docs.val, r,
                                 mask, *args, scope="query")
    iters = np.asarray(iters)[:4]
    wmd_c, iters_c = _solve_gathered(g, mq, grp.docs.idx, grp.docs.val, r,
                                     mask, *args, scope="chunk")
    assert int(iters_c) == iters.max(), (iters, iters_c)
    assert iters.min() < iters.max(), iters    # the fast members froze early
    # frozen-early rows still match the chunk run at the solve tolerance
    n = dedup_index.n_docs
    np.testing.assert_allclose(np.asarray(wmd)[:4, :n],
                               np.asarray(wmd_c)[:4, :n],
                               rtol=5e-2, atol=1e-3)

    # padding inertness: two filler queries + 8 inert docs change nothing
    idx_p = jnp.concatenate([grp.docs.idx,
                             jnp.zeros((8, grp.docs.idx.shape[1]),
                                       jnp.int32)])
    val_p = jnp.concatenate([grp.docs.val,
                             jnp.zeros((8, grp.docs.val.shape[1]))])
    g_p = _gather_g(kqk, idx_p)
    g_p = jnp.concatenate([g_p, jnp.zeros((2,) + g_p.shape[1:])], axis=0)
    mq_p = jnp.concatenate([mq, mq[:2]], axis=0)
    r_p = jnp.concatenate([r, jnp.ones((2, r.shape[1]))])
    mask_p = jnp.concatenate([mask, jnp.zeros((2, mask.shape[1]))])
    wmd_p, iters_p = _solve_gathered(g_p, mq_p, idx_p, val_p, r_p, mask_p,
                                     *args, scope="query")
    np.testing.assert_array_equal(np.asarray(iters_p)[:4], iters)
    np.testing.assert_allclose(np.asarray(wmd_p)[:4, :n],
                               np.asarray(wmd)[:4, :n],
                               rtol=1e-6, atol=1e-7)


# --------------------------------------------------- warm-started survivors
def test_warm_survivor_matches_cold_with_fewer_iters(dedup, dedup_index):
    """Warm-starting the survivor solve from the seed solve's converged
    per-query profile returns the same distances (both inits land within
    tol of the same fixed point) in strictly fewer realized iterations."""
    qs = list(dedup.queries)
    cold = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=1e-2,
                     check_every=2, warm_start=False)
    warm = WmdEngine(dedup_index, lam=1.0, n_iter=60, tol=1e-2,
                     check_every=2, warm_start=True)
    r_c = cold.search(qs, 10, prune="rwmd")
    r_w = warm.search(qs, 10, prune="rwmd")
    np.testing.assert_allclose(np.sort(r_w.distances, axis=1),
                               np.sort(r_c.distances, axis=1),
                               rtol=5e-2, atol=1e-3)
    sc, sw = cold.iter_stats_by_stage(), warm.iter_stats_by_stage()
    # identical seed stage (warm start only applies to survivors)...
    np.testing.assert_array_equal(sw["seed"], sc["seed"])
    # ...and a strictly cheaper survivor stage
    assert sw["survivor"].mean() < sc["survivor"].mean(), (sc, sw)


def test_warm_start_inert_without_tol(dedup, dedup_index):
    """With tol=None (fixed-length loop) warm_start must change nothing —
    bit-for-bit, the PR 4 contract."""
    qs = list(dedup.queries[:2])
    a = WmdEngine(dedup_index, lam=1.0, n_iter=15, warm_start=False)
    b = WmdEngine(dedup_index, lam=1.0, n_iter=15, warm_start=True)
    r_a = a.search(qs, 8, prune="rwmd")
    r_b = b.search(qs, 8, prune="rwmd")
    np.testing.assert_array_equal(r_a.indices, r_b.indices)
    np.testing.assert_array_equal(r_a.distances, r_b.distances)


# ------------------------------------------------------- distributed (Q,)
def test_distributed_batched_per_query_exit(dedup):
    """Batched distributed solve: the residual all-reduce is a per-query
    (Q,) ``lax.pmax`` — still one collective — and per-query realized
    counts come back. A dup query (scoped pairs stationary fast at small
    lam) and its batchmates exit without waiting for the cap."""
    from repro.core import select_support
    from repro.core.distributed import sinkhorn_wmd_sparse_distributed
    from repro.core.sinkhorn_sparse import sinkhorn_wmd_sparse

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    vecs = jnp.asarray(dedup.vecs)
    rs, sels = [], []
    for qi in range(2):
        rq, sq, _ = select_support(dedup.queries[qi], dedup.vecs)
        rs.append(np.asarray(rq))
        sels.append(np.asarray(sq))
    b = max(r.shape[0] for r in rs)
    rpad = np.ones((2, b), np.float32)
    spad = np.zeros((2, b, sels[0].shape[1]), np.float32)
    qmask = np.zeros((2, b), np.float32)
    for qi in range(2):
        n = rs[qi].shape[0]
        rpad[qi, :n], spad[qi, :n], qmask[qi, :n] = rs[qi], sels[qi], 1.0
    for vshard in (False, True):
        out, iters = sinkhorn_wmd_sparse_distributed(
            jnp.asarray(rpad), jnp.asarray(spad), vecs, dedup.docs, 0.25,
            40, mesh, vshard_precompute=vshard, qmask=jnp.asarray(qmask),
            tol=1e-2, check_every=2, return_iters=True)
        assert out.shape == (2, 256)
        iters = np.asarray(iters)
        assert iters.shape == (2,) and (iters < 40).all(), iters
        # each row matches its own single-query solve at the same tol
        for qi in range(2):
            ref = sinkhorn_wmd_sparse(
                jnp.asarray(rs[qi]), jnp.asarray(sels[qi]), vecs,
                dedup.docs, 0.25, 40, tol=1e-2, check_every=2)
            np.testing.assert_allclose(np.asarray(out[qi]),
                                       np.asarray(ref),
                                       rtol=5e-2, atol=1e-3)


def test_sparse_solver_doc_mask_scoping(dedup):
    """``sinkhorn_wmd_sparse(doc_mask=...)``: scoping the single-query
    residual to the caller's candidate docs exits earlier, and the
    scoped docs' distances match the unscoped solve at tolerance."""
    from repro.core import select_support
    from repro.core.sinkhorn_sparse import sinkhorn_wmd_sparse

    vecs = jnp.asarray(dedup.vecs)
    r, vecs_sel, _ = select_support(dedup.queries[0], dedup.vecs)
    full, it_full = sinkhorn_wmd_sparse(
        r, vecs_sel, vecs, dedup.docs, 1.0, 60, tol=1e-2, check_every=2,
        return_iters=True)
    # scope to the single fastest-converging doc: a subset's residual max
    # can only be <= the full sweep's, so the exit is monotone in scope
    per_doc = []
    for j in range(8):
        dm1 = np.zeros(256, bool)
        dm1[j] = True
        _, itj = sinkhorn_wmd_sparse(
            r, vecs_sel, vecs, dedup.docs, 1.0, 60, tol=1e-2,
            check_every=2, doc_mask=dm1, return_iters=True)
        per_doc.append(int(itj))
        assert int(itj) <= int(it_full), (j, itj, it_full)
    assert min(per_doc) < int(it_full), (per_doc, it_full)
    near = int(np.argmin(per_doc))
    dm = np.zeros(256, bool)
    dm[near] = True
    scoped, it_scoped = sinkhorn_wmd_sparse(
        r, vecs_sel, vecs, dedup.docs, 1.0, 60, tol=1e-2, check_every=2,
        doc_mask=dm, return_iters=True)
    np.testing.assert_allclose(np.asarray(scoped)[near],
                               np.asarray(full)[near], rtol=2e-2,
                               atol=1e-3)
    # an empty scope has nothing to wait for: first check exits
    none, it_none = sinkhorn_wmd_sparse(
        r, vecs_sel, vecs, dedup.docs, 1.0, 60, tol=1e-2, check_every=2,
        doc_mask=np.zeros(256, bool), return_iters=True)
    assert int(it_none) == 3, it_none        # 1 seed + one check window


# ------------------------------------------------------------- kernel path
def test_kernel_resmask_scoping(rng):
    """Kernel resmask: an all-ones scope is identical to no scope; an
    empty scope exits at the first check; a candidate scope's docs match
    the unscoped solve at tolerance."""
    q_n, v_r, n, length = 2, 8, 64, 8
    g = jnp.asarray(rng.uniform(0.05, 1.0, (q_n, v_r, n, length)),
                    dtype=jnp.float32)
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.3, 0.7, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, (q_n, v_r)).astype(np.float32))
    kw = dict(block_n=32, tol=1e-3, check_every=3, with_iters=True)
    base, it_b = ops.sinkhorn_fused_all_batched(g, val, r, 4.0, 40, **kw)
    ones, it_o = ops.sinkhorn_fused_all_batched(
        g, val, r, 4.0, 40, resmask=jnp.ones((q_n, n)), **kw)
    np.testing.assert_array_equal(np.asarray(it_o), np.asarray(it_b))
    np.testing.assert_array_equal(np.asarray(ones), np.asarray(base))
    # empty scope for query 1: its blocks exit at the first check
    rm = np.ones((q_n, n), np.float32)
    rm[1] = 0.0
    part, it_p = ops.sinkhorn_fused_all_batched(
        g, val, r, 4.0, 40, resmask=jnp.asarray(rm), **kw)
    it_p = np.asarray(it_p)
    assert (it_p[1] == 4).all(), it_p          # 1 seed + one check window
    assert (it_p[0] == np.asarray(it_b)[0]).all()
    np.testing.assert_allclose(np.asarray(part)[0], np.asarray(base)[0],
                               rtol=1e-6, atol=1e-6)
