"""Sharded corpus serving: invariance, structure, and diagnostics.

Quick tests run in the main process on the single default device (a
1-shard mesh needs no forced devices). Multi-device invariance and
collective-structure tests run in subprocesses so XLA_FLAGS never
pollutes the main test process (smoke tests must see exactly 1 device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def _run(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


# ---------------------------------------------------------------- quick ----

def test_bin_pack_clusters_covers_and_balances():
    from repro.core import bin_pack_clusters

    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 200, size=37)
    for n_shards in (1, 2, 4, 7):
        shard_of = bin_pack_clusters(sizes, n_shards)
        assert shard_of.shape == (37,)
        assert shard_of.min() >= 0 and shard_of.max() < n_shards
        loads = np.bincount(shard_of, weights=sizes, minlength=n_shards)
        # LPT greedy bound: no shard exceeds the ideal by a whole cluster
        assert loads.max() <= sizes.sum() / n_shards + sizes.max()


def test_single_shard_bitcompat_and_id_partition():
    from repro.core import (ShardedWmdEngine, WmdEngine, build_index,
                            shard_corpus)
    from repro.data.corpus import make_corpus

    c = make_corpus(vocab_size=256, embed_dim=16, n_docs=48, n_queries=2,
                    seed=3)
    index = build_index(c.docs, c.vecs, n_clusters=6)
    ref = WmdEngine(index, lam=8.0, n_iter=25).search(
        list(c.queries), 5, prune="ivf+wcd+rwmd")

    sindex = shard_corpus(c.docs, c.vecs, 1, n_clusters=6)
    # global ids partition [0, N) and owner agrees with the partition
    ids = np.sort(np.concatenate(sindex.global_ids))
    assert np.array_equal(ids, np.arange(48))
    for s, gid in enumerate(sindex.global_ids):
        assert np.all(sindex.owner[gid] == s)

    res = ShardedWmdEngine(sindex, lam=8.0, n_iter=25).search(
        list(c.queries), 5, prune="ivf+wcd+rwmd")
    # shard-count-1 is bit-compatible with the single-device engine
    assert np.array_equal(ref.indices, res.indices)
    np.testing.assert_array_equal(ref.distances, res.distances)
    assert np.array_equal(ref.solved, res.solved)


def test_merge_is_exactly_one_all_gather():
    import jax

    from repro.core import ShardedWmdEngine, count_collectives, shard_corpus
    from repro.data.corpus import make_corpus

    c = make_corpus(vocab_size=256, embed_dim=16, n_docs=32, n_queries=1,
                    seed=4)
    engine = ShardedWmdEngine(shard_corpus(c.docs, c.vecs, 1, n_clusters=4),
                              lam=8.0, n_iter=10)
    k = 3
    packed = np.zeros((1, 2, 2 * k), np.float32)
    colls = count_collectives(jax.make_jaxpr(engine._merge_fn(k))(packed))
    n_ag = sum(v for p, v in colls.items() if "all_gather" in p)
    assert n_ag == 1 and sum(colls.values()) == 1, colls


def test_underflow_report_names_shard_and_external_ids():
    import jax
    import jax.numpy as jnp

    from repro.core import select_support
    from repro.core.distributed import sinkhorn_wmd_sparse_distributed
    from repro.core.sinkhorn import LamUnderflowError
    from repro.data.corpus import make_corpus

    c = make_corpus(vocab_size=256, embed_dim=16, n_docs=16, n_queries=1,
                    seed=5)
    r, vs, _ = select_support(c.queries[0], c.vecs)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ext = np.arange(16, dtype=np.int64) + 7000
    with pytest.raises(LamUnderflowError) as ei:
        sinkhorn_wmd_sparse_distributed(r, vs, jnp.asarray(c.vecs), c.docs,
                                        500.0, 10, mesh, doc_ids=ext)
    msg = str(ei.value)
    assert "owning shard(s)" in msg
    assert "external doc ids" in msg
    assert "70" in msg          # quoted ids are the external ones


# --------------------------------------------------------- multi-device ----

SCRIPT_INVARIANCE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import (ShardedWmdEngine, WmdEngine, append_docs_sharded,
                            build_index, shard_corpus)
    from repro.core.sparse import PaddedDocs
    from repro.data.corpus import make_corpus

    assert len(jax.devices()) == 8
    c = make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=3,
                    seed=2)
    queries, k = list(c.queries), 5
    kw = dict(lam=8.0, n_iter=25)
    ref = WmdEngine(build_index(c.docs, c.vecs, n_clusters=12), **kw).search(
        queries, k, prune="ivf+wcd+rwmd")

    def tie_equal(a, b, rtol=2e-4):
        for qi in range(a.indices.shape[0]):
            assert np.allclose(np.sort(a.distances[qi]),
                               np.sort(b.distances[qi]), rtol=rtol,
                               equal_nan=True), qi
        return True

    # 1/2/4 shards == single device at nprobe=None (exactness contract)
    engines = {}
    for s in (1, 2, 4):
        sindex = shard_corpus(c.docs, c.vecs, s, n_clusters=12)
        engines[s] = ShardedWmdEngine(sindex, **kw)
        res = engines[s].search(queries, k, prune="ivf+wcd+rwmd")
        tie_equal(ref, res)
        if s == 1:
            assert np.array_equal(ref.indices, res.indices)
            assert np.array_equal(ref.distances, res.distances)

    # recall vs exact top-k is monotone in nprobe, per shard count
    def recall(res):
        return np.mean([len(set(ref.indices[qi]) & set(res.indices[qi])) / k
                        for qi in range(len(queries))])
    for s in (2, 4):
        prev = -1.0
        for nprobe in (1, 2, 4, None):
            r = recall(engines[s].search(queries, k, prune="ivf+wcd+rwmd",
                                         nprobe=nprobe))
            assert r >= prev - 1e-12, (s, nprobe, r, prev)
            prev = r
        assert prev == 1.0, (s, prev)   # nprobe=None is exact

    # append-then-search == build-everything-then-search at nprobe=None
    head = PaddedDocs(c.docs.idx[:64], c.docs.val[:64])
    tail = PaddedDocs(c.docs.idx[64:], c.docs.val[64:])
    sindex = shard_corpus(head, c.vecs, 4, n_clusters=12)
    sindex = append_docs_sharded(sindex, tail)
    eng = ShardedWmdEngine(sindex, **kw)
    assert eng.n_docs == 96
    ids = np.sort(np.concatenate(sindex.global_ids))
    assert np.array_equal(ids, np.arange(96))
    tie_equal(ref, eng.search(queries, k, prune="ivf+wcd+rwmd"))
    print("SHARD_INVARIANCE_OK")
""")


SCRIPT_STRUCTURE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import (ShardedWmdEngine, count_collectives,
                            select_support, shard_corpus)
    from repro.core.distributed import sinkhorn_wmd_sparse_distributed
    from repro.core.sinkhorn import LamUnderflowError
    from repro.data.corpus import make_corpus

    assert len(jax.devices()) == 8
    c = make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=2,
                    seed=2)
    engine = ShardedWmdEngine(shard_corpus(c.docs, c.vecs, 4, n_clusters=12),
                              lam=8.0, n_iter=10)

    # cross-shard communication on the serving path: EXACTLY one top-k
    # merge all_gather, no other collective
    k = 5
    packed = np.zeros((4, 2, 2 * k), np.float32)
    colls = count_collectives(jax.make_jaxpr(engine._merge_fn(k))(packed))
    assert sum(colls.values()) == 1, colls
    assert all("all_gather" in p for p in colls), colls

    # the distributed solve path adds only the per-query residual pmax
    r, vs, _ = select_support(c.queries[0], c.vecs)
    mesh = jax.make_mesh((8,), ("data",))
    fixed = jax.make_jaxpr(
        lambda: sinkhorn_wmd_sparse_distributed(
            r, vs, jnp.asarray(c.vecs), c.docs, 8.0, 10, mesh,
            vshard_precompute=False, check_underflow=False))()
    assert sum(count_collectives(fixed).values()) == 0, \\
        count_collectives(fixed)
    adaptive = jax.make_jaxpr(
        lambda: sinkhorn_wmd_sparse_distributed(
            r, vs, jnp.asarray(c.vecs), c.docs, 8.0, 10, mesh,
            vshard_precompute=False, check_underflow=False, tol=1e-3))()
    acolls = count_collectives(adaptive)
    assert sum(acolls.values()) >= 1, acolls
    assert all("pmax" in p for p in acolls), acolls

    # a poisoning lam names the owning shard in the engine diagnosis
    try:
        engine_hot = ShardedWmdEngine(
            shard_corpus(c.docs, c.vecs, 2, n_clusters=12),
            lam=500.0, n_iter=10)
        engine_hot.search(list(c.queries), 3, prune=None)
        raise AssertionError("expected LamUnderflowError")
    except LamUnderflowError as e:
        assert "owning shard" in str(e), str(e)
    print("SHARD_STRUCTURE_OK")
""")


SCRIPT_FAULT = textwrap.dedent("""
    import os, tempfile, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.core import (ShardedWmdEngine, WmdEngine, build_index,
                            shard_corpus)
    from repro.data.corpus import make_corpus

    assert len(jax.devices()) == 2
    c = make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=3,
                    seed=2)
    queries, k = list(c.queries), 5
    kw = dict(lam=8.0, n_iter=25)
    engine = ShardedWmdEngine(
        shard_corpus(c.docs, c.vecs, 2, n_clusters=12),
        shard_timeout_s=30.0, shard_retries=0, fail_threshold=3,
        snapshot_dir=tempfile.mkdtemp(), **kw)
    baseline = engine.search(queries, k, prune="ivf+wcd+rwmd")
    assert engine.last_coverage.full
    engine.snapshot()

    # degenerate merge, full coverage: k exceeds the smallest shard's doc
    # count — that shard contributes a SHORT lane and the merged result
    # still matches the single-device engine (tie-tolerant)
    big_k = min(engine.docs_per_shard) + 3
    ref = WmdEngine(build_index(c.docs, c.vecs, n_clusters=12),
                    **kw).search(queries, big_k, prune="ivf+wcd+rwmd")
    got = engine.search(queries, big_k, prune="ivf+wcd+rwmd")
    for qi in range(len(queries)):
        assert np.allclose(np.sort(ref.distances[qi]),
                           np.sort(got.distances[qi]), rtol=2e-4,
                           equal_nan=True), qi

    # zero-survivor rows: nprobe=1 can starve a query on some shard; the
    # merge must still return well-formed (-1 / NaN padded) rows
    r1 = engine.search(queries, k, prune="ivf+wcd+rwmd", nprobe=1)
    assert r1.indices.shape == (len(queries), k)
    assert r1.indices.max() < engine.n_docs
    assert np.all(np.isnan(r1.distances[r1.indices < 0]))

    # one shard raising RAW mid-fan-out: the response is a PARTIAL top-k
    # over the surviving shard only, with honest coverage accounting
    orig = engine.engines[1].search
    def boom(*a, **kws):
        raise ValueError("injected shard death")
    engine.engines[1].search = boom
    res = engine.search(queries, k, prune="ivf+wcd+rwmd")
    cov = engine.last_coverage
    assert cov.missing_shards == (1,), cov
    frac0 = engine.docs_per_shard[0] / engine.n_docs
    assert abs(cov.fraction - frac0) < 1e-9, cov
    assert "ValueError" in cov.reasons[1], cov.reasons
    shard0 = set(engine.sindex.global_ids[0].tolist())
    returned = res.indices[res.indices >= 0]
    assert set(returned.tolist()) <= shard0, "partial leaked dead-shard ids"

    # hang -> fan-out deadline excludes the shard with reason "timeout";
    # snapshot restore then returns the mesh to BIT-EXACT full coverage
    engine.shard_timeout_s = 0.2
    def hang(*a, **kws):
        time.sleep(2.0)
        return orig(*a, **kws)
    engine.engines[1].search = hang
    engine.search(queries, k, prune="ivf+wcd+rwmd")
    assert engine.last_coverage.reasons.get(1) == "timeout", \\
        engine.last_coverage
    time.sleep(2.5)                  # drain the hung background future
    engine.shard_timeout_s = 30.0
    engine.restore_shard(1)          # rebuild also discards the patch
    res = engine.search(queries, k, prune="ivf+wcd+rwmd")
    assert engine.last_coverage.full
    assert np.array_equal(baseline.indices, res.indices)
    assert np.array_equal(baseline.distances, res.distances)
    print("SHARD_FAULT_OK")
""")


@pytest.mark.slow
def test_shard_invariance_multidevice():
    res = _run(SCRIPT_INVARIANCE)
    assert "SHARD_INVARIANCE_OK" in res.stdout, res.stdout + res.stderr


@pytest.mark.slow
def test_shard_collective_structure_multidevice():
    res = _run(SCRIPT_STRUCTURE)
    assert "SHARD_STRUCTURE_OK" in res.stdout, res.stdout + res.stderr


@pytest.mark.slow
def test_shard_fault_partials_and_recovery_multidevice():
    """ISSUE 9 on a real 2-device mesh: degenerate merges (short shard
    lanes, zero-survivor rows), raw-exception and timeout partials with
    coverage accounting, and bit-exact snapshot recovery."""
    res = _run(SCRIPT_FAULT)
    assert "SHARD_FAULT_OK" in res.stdout, res.stdout + res.stderr
