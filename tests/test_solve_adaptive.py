"""ISSUE 4 solve-stage overhaul: convergence-adaptive early-exit Sinkhorn,
SolvePrecision (bf16 GEMMs / log-domain stabilization), and the
cluster-major corpus layout.

Covers the contracts the overhaul rides on: early-exit == fixed-iteration
top-k on the fig8 near-duplicate corpus, residual masking inertness (padded
docs/queries can neither stall nor early-release the loop), bf16 within
tolerance and distance-monotone on ranked output, log-domain == linear at
small lam and underflow-free at any lam, and cluster-major append + search
== rebuild.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    LamUnderflowError,
    SolvePrecision,
    WmdEngine,
    append_docs,
    auto_n_clusters,
    build_index,
    select_support,
)
from repro.core.distributed import sinkhorn_wmd_sparse_distributed
from repro.core.index import _gather_g, _solve_gathered
from repro.core.sinkhorn_sparse import sinkhorn_wmd_sparse
from repro.core.sparse import PaddedDocs
from repro.data.corpus import make_corpus
from repro.kernels import ops


@pytest.fixture(scope="module")
def dedup():
    from benchmarks.fig8_topk_prune import dedup_corpus

    return dedup_corpus(256, vocab=1024, embed_dim=32, seed=5)


@pytest.fixture(scope="module")
def dedup_index(dedup):
    return build_index(dedup.docs, dedup.vecs)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        vocab_size=512,
        embed_dim=16,
        n_docs=96,
        n_queries=6,
        words_per_doc=(3, 60),
        seed=11,
    )


def _topk_sets(dists, k):
    return [set(np.argsort(dists[qi])[:k]) for qi in range(dists.shape[0])]


# ----------------------------------------------------------- SolvePrecision
def test_solve_precision_parse():
    assert SolvePrecision.parse(None) == SolvePrecision("fp32", False)
    assert SolvePrecision.parse("bf16").gemm == "bf16"
    assert SolvePrecision.parse("log").log_domain
    both = SolvePrecision.parse("bf16+log")
    assert both.gemm == "bf16" and both.log_domain
    assert SolvePrecision.parse("log+bf16") == both
    assert both.name == "bf16+log"
    p = SolvePrecision.parse("fp32")
    assert SolvePrecision.parse(p) is p
    assert p.gemm_dtype is None
    assert both.gemm_dtype == jnp.bfloat16
    with pytest.raises(ValueError):
        SolvePrecision.parse("fp64")


# ------------------------------------------------------- adaptive early exit
def test_early_exit_matches_fixed_topk(dedup, dedup_index):
    """Early exit == fixed-iteration top-k on the fig8 corpus, and the
    realized iteration counts show the exit actually happened."""
    queries = list(dedup.queries)
    fixed = WmdEngine(dedup_index, lam=0.25, n_iter=15)
    adaptive = WmdEngine(
        dedup_index, lam=0.25, n_iter=15, tol=3e-2, check_every=2
    )
    d_f = np.asarray(fixed.query_batch(queries))
    adaptive.reset_iter_stats()
    d_a = np.asarray(adaptive.query_batch(queries))
    for a, b in zip(_topk_sets(d_f, 8), _topk_sets(d_a, 8)):
        assert a == b
    iters = adaptive.iter_stats()
    assert iters.size > 0 and (iters <= 15).all()
    assert (iters < 15).any(), iters  # the exit did fire somewhere


def test_adaptive_at_cap_equals_fixed(corpus):
    """tol=0 never exits early: the while loop runs to the cap and matches
    the fixed scan (realized counts land on 1 + k*check_every, so
    n_iter = 13 with check_every = 4 hits the cap exactly)."""
    index = build_index(corpus.docs, corpus.vecs)
    fixed = WmdEngine(index, lam=4.0, n_iter=13)
    capped = WmdEngine(index, lam=4.0, n_iter=13, tol=0.0, check_every=4)
    qs = list(corpus.queries[:3])
    np.testing.assert_allclose(
        np.asarray(capped.query_batch(qs)),
        np.asarray(fixed.query_batch(qs)),
        rtol=1e-6,
        atol=1e-7,
    )
    assert (capped.iter_stats() == 13).all()
    assert (fixed.iter_stats() == 13).all()  # fixed path reports the cap


def test_iter_stats_reset(corpus):
    index = build_index(corpus.docs, corpus.vecs)
    eng = WmdEngine(index, lam=4.0, n_iter=7)
    eng.query_batch(list(corpus.queries[:2]))
    assert eng.iter_stats().size > 0
    eng.reset_iter_stats()
    assert eng.iter_stats().size == 0


def test_residual_padding_inert(corpus):
    """Padded docs (all-zero rows) and filler queries can neither stall the
    adaptive loop nor release it early: same realized iterations and same
    distances on the real slice."""
    index = build_index(corpus.docs, corpus.vecs)
    eng = WmdEngine(index, lam=4.0, n_iter=40, tol=1e-3, check_every=5)
    qs = list(corpus.queries[:2])
    _, chunks = eng._plan(qs)
    chunk, width = chunks[0]
    sup, r, mask = eng._prep_chunk([qs[qi] for qi in chunk], width)
    kqk, mq = eng._kq(sup, mask)
    grp = index.groups[0]
    g = _gather_g(kqk, grp.docs.idx)
    args = (eng.lam, eng.n_iter, eng.tol, eng.check_every, "fp32", False)
    wmd, iters = _solve_gathered(g, mq, grp.docs.idx, grp.docs.val, r, mask, *args)
    qc = len(chunk)
    n_real = grp.cols.shape[0]

    # pad 8 inert docs (idx 0 / val 0) and 2 filler queries (g rows 0,
    # r == 1, mask == 0)
    idx_p = jnp.concatenate(
        [grp.docs.idx, jnp.zeros((8, grp.docs.idx.shape[1]), jnp.int32)]
    )
    val_p = jnp.concatenate([grp.docs.val, jnp.zeros((8, grp.docs.val.shape[1]))])
    g_p = _gather_g(kqk, idx_p)
    g_p = jnp.concatenate([g_p, jnp.zeros((2,) + g_p.shape[1:])], axis=0)
    mq_p = jnp.concatenate([mq, mq[:2]], axis=0)
    r_p = jnp.concatenate([r, jnp.ones((2, r.shape[1]))])
    mask_p = jnp.concatenate([mask, jnp.zeros((2, mask.shape[1]))])
    wmd_p, iters_p = _solve_gathered(g_p, mq_p, idx_p, val_p, r_p, mask_p, *args)
    assert int(iters_p) == int(iters), "padding changed the exit iteration"
    np.testing.assert_allclose(
        np.asarray(wmd_p)[:qc, :n_real],
        np.asarray(wmd)[:qc, :n_real],
        rtol=1e-6,
        atol=1e-7,
    )


# ------------------------------------------------------------ bf16 policy
def test_bf16_within_tolerance_and_monotone(dedup, dedup_index):
    queries = list(dedup.queries)
    fixed = WmdEngine(dedup_index, lam=0.25, n_iter=15)
    bf = WmdEngine(dedup_index, lam=0.25, n_iter=15, precision="bf16")
    d_f = np.asarray(fixed.query_batch(queries))
    d_b = np.asarray(bf.query_batch(queries))
    np.testing.assert_allclose(d_b, d_f, rtol=5e-2, atol=1e-3)
    # ranked output is distance-monotone, and every returned doc is within
    # the documented tolerance of truly top-k under the fp32 reference
    k = 8
    res = bf.search(queries, k, prune="rwmd")
    for qi in range(len(queries)):
        row = res.distances[qi]
        assert (np.diff(row[~np.isnan(row)]) >= 0).all()
        kth = np.sort(d_f[qi])[k - 1]
        assert d_f[qi, res.indices[qi]].max() <= kth * 1.05 + 1e-3


# ------------------------------------------------------- log-domain policy
def test_log_domain_equals_linear_small_lam(corpus):
    index = build_index(corpus.docs, corpus.vecs)
    lin = WmdEngine(index, lam=2.0, n_iter=12)
    log = WmdEngine(index, lam=2.0, n_iter=12, precision="log")
    qs = list(corpus.queries[:3])
    np.testing.assert_allclose(
        np.asarray(log.query_batch(qs)),
        np.asarray(lin.query_batch(qs)),
        rtol=5e-4,
        atol=5e-4,
    )
    # and at the solver level
    r, vecs_sel, _ = select_support(corpus.queries[0], corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    a = np.asarray(sinkhorn_wmd_sparse(r, vecs_sel, vecs, corpus.docs, 2.0, 12))
    b = np.asarray(
        sinkhorn_wmd_sparse(
            r, vecs_sel, vecs, corpus.docs, 2.0, 12, precision="log"
        )
    )
    np.testing.assert_allclose(b, a, rtol=5e-4, atol=5e-4)


def test_log_domain_large_lam_no_underflow(corpus):
    """lam far beyond the fp32 exp cutoff: the legacy path raises, the
    log-domain policy completes with finite distances on engine AND
    solver paths."""
    index = build_index(corpus.docs, corpus.vecs)
    qs = list(corpus.queries[:2])
    with pytest.raises(LamUnderflowError):
        WmdEngine(index, lam=80.0, n_iter=5).query_batch(qs)
    d = np.asarray(
        WmdEngine(index, lam=80.0, n_iter=5, precision="log").query_batch(qs)
    )
    assert np.isfinite(d).all()
    r, vecs_sel, _ = select_support(corpus.queries[0], corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    with pytest.raises(LamUnderflowError):
        sinkhorn_wmd_sparse(r, vecs_sel, vecs, corpus.docs, 80.0, 5)
    out, iters = sinkhorn_wmd_sparse(
        r,
        vecs_sel,
        vecs,
        corpus.docs,
        80.0,
        5,
        precision="log",
        return_iters=True,
    )
    assert np.isfinite(np.asarray(out)).all()
    assert int(iters) == 5


def test_log_domain_adaptive_engine_search(dedup, dedup_index):
    """The composed policy (log + adaptive) keeps the pruned-search
    contract: pruned top-k == its own exhaustive top-k."""
    eng = WmdEngine(
        dedup_index,
        lam=0.25,
        n_iter=15,
        tol=3e-2,
        check_every=2,
        precision="log",
    )
    queries = list(dedup.queries)
    ex = eng.search(queries, 8, prune=None)
    pr = eng.search(queries, 8, prune="ivf+wcd+rwmd")
    for qi in range(len(queries)):
        assert set(ex.indices[qi]) == set(pr.indices[qi])


# ------------------------------------------------------------- kernel path
def test_kernel_adaptive_matches_fixed(rng):
    q_n, v_r, n, length = 2, 8, 64, 8
    g = jnp.asarray(
        rng.uniform(0.05, 1.0, (q_n, v_r, n, length)), dtype=jnp.float32
    )
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.3, 0.7, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, (q_n, v_r)).astype(np.float32))
    base = ops.sinkhorn_fused_all_batched(g, val, r, 4.0, 9, block_n=32)
    capped, iters = ops.sinkhorn_fused_all_batched(
        g,
        val,
        r,
        4.0,
        9,
        block_n=32,
        tol=0.0,
        check_every=4,
        with_iters=True,
    )
    assert iters.shape == (q_n, n // 32)
    assert (np.asarray(iters) == 9).all()  # 1 + 2*check_every == the cap
    np.testing.assert_allclose(
        np.asarray(capped), np.asarray(base), rtol=1e-6, atol=1e-6
    )


def test_kernel_pad_query_block_exits_first_check(rng):
    """An all-pad query's grid blocks are inert (w == 0 throughout), so
    they exit at the FIRST residual check — per-block early exit."""
    q_n, v_r, n, length = 1, 8, 32, 8
    g = jnp.asarray(
        rng.uniform(0.05, 1.0, (q_n, v_r, n, length)), dtype=jnp.float32
    )
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.3, 0.7, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, (q_n, v_r)).astype(np.float32))
    g2 = jnp.concatenate([g, jnp.zeros((1, v_r, n, length))])
    r2 = jnp.concatenate([r, jnp.ones((1, v_r))])
    wmd, iters = ops.sinkhorn_fused_all_batched(
        g2,
        val,
        r2,
        4.0,
        20,
        block_n=32,
        tol=1e-4,
        check_every=3,
        with_iters=True,
    )
    iters = np.asarray(iters)
    # pad query: the FIRST check exits (1 seed iter + one check window)
    assert (iters[1] == 4).all(), iters
    base = ops.sinkhorn_fused_all_batched(g, val, r, 4.0, 20, block_n=32)
    np.testing.assert_allclose(
        np.asarray(wmd)[:1], np.asarray(base), rtol=1e-3, atol=1e-4
    )


def test_kernel_log_domain_matches_linear(rng):
    """Log-domain kernel (g = log K, pad rows -inf) == linear kernel."""
    v_r, n, length = 6, 32, 8
    m = jnp.asarray(rng.uniform(0.1, 3.0, (v_r, n, length)), jnp.float32)
    lam = 2.0
    g = jnp.exp(-lam * m)
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.3, 0.5, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, v_r).astype(np.float32))
    base = ops.sinkhorn_fused_all(g, val, r, lam, 10, block_n=32)
    got = ops.sinkhorn_fused_all(
        -lam * m, val, r, lam, 10, block_n=32, log_domain=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(base), rtol=5e-4, atol=5e-4
    )


def test_engine_kernel_impl_adaptive():
    """Kernel engine path with the adaptive/precision knobs stays close to
    the sparse fixed reference (tiny corpus; interpret mode)."""
    small = make_corpus(
        vocab_size=256, embed_dim=16, n_docs=32, n_queries=2, seed=4
    )
    index = build_index(small.docs, small.vecs)
    ref = WmdEngine(index, lam=4.0, n_iter=13)
    ker = WmdEngine(
        index,
        lam=4.0,
        n_iter=13,  # 1 + 3*check_every: the capped while hits it exactly
        impl="kernel",
        block_n=32,
        tol=0.0,
        check_every=4,
    )
    d_ref = np.asarray(ref.query_batch(list(small.queries)))
    d_ker = np.asarray(ker.query_batch(list(small.queries)))
    np.testing.assert_allclose(d_ker, d_ref, rtol=5e-4, atol=5e-4)
    assert (ker.iter_stats() == 13).all()


# ------------------------------------------------------------- distributed
def test_distributed_adaptive_at_cap_matches_fixed(corpus):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r, vecs_sel, _ = select_support(corpus.queries[0], corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    base = sinkhorn_wmd_sparse_distributed(
        r, vecs_sel, vecs, corpus.docs, 4.0, 13, mesh
    )
    capped = sinkhorn_wmd_sparse_distributed(
        r, vecs_sel, vecs, corpus.docs, 4.0, 13, mesh, tol=0.0, check_every=4
    )
    np.testing.assert_allclose(
        np.asarray(capped), np.asarray(base), rtol=1e-6, atol=1e-7
    )
    # a genuinely adaptive run stays finite and consistent with the fixed
    # solve at loose tolerance (the pmax residual all-reduce path)
    loose = sinkhorn_wmd_sparse_distributed(
        r, vecs_sel, vecs, corpus.docs, 4.0, 13, mesh, tol=5e-2, check_every=4
    )
    np.testing.assert_allclose(
        np.asarray(loose), np.asarray(base), rtol=0.2, atol=1e-3
    )


# ------------------------------------------------ cluster-major layout/auto
def test_cluster_major_storage_invariants(corpus):
    index = build_index(corpus.docs, corpus.vecs)
    n = index.n_docs
    cl = index.clusters
    assert (np.diff(cl.assign) >= 0).all()  # storage is cluster-major
    np.testing.assert_array_equal(cl.order, np.arange(n))  # slices == rows
    np.testing.assert_array_equal(np.sort(index.ext_ids), np.arange(n))
    np.testing.assert_array_equal(index.ext_ids[index.remap], np.arange(n))
    for grp in index.groups:
        cols = np.asarray(grp.cols)
        assert (np.diff(cols) >= 0).all()  # cluster-major within the group
    # public subset() takes caller-order ids
    ids = np.asarray([5, 17, 3], np.int32)
    grp = index.subset(ids)
    np.testing.assert_array_equal(np.asarray(grp.cols), ids)
    want_rows = index.remap[ids]
    np.testing.assert_array_equal(
        np.asarray(grp.docs.idx)[: ids.size],
        np.asarray(index.docs.idx)[want_rows][:, : grp.docs.idx.shape[1]],
    )


def test_cluster_major_append_search_matches_rebuild():
    full = make_corpus(
        vocab_size=512,
        embed_dim=16,
        n_docs=128,
        n_queries=5,
        words_per_doc=(3, 60),
        seed=23,
    )
    head = PaddedDocs(idx=full.docs.idx[:96], val=full.docs.val[:96])
    tail = PaddedDocs(idx=full.docs.idx[96:], val=full.docs.val[96:])
    appended = append_docs(build_index(head, full.vecs), tail)
    rebuilt = build_index(full.docs, full.vecs)
    # the grown group keeps the cluster-major invariant
    for grp in appended.groups:
        cols = np.asarray(grp.cols)
        assert (np.diff(appended.clusters.assign[cols]) >= 0).all()
    # appended ids extend the caller space
    np.testing.assert_array_equal(
        np.sort(appended.ext_ids), np.arange(128)
    )
    qs = list(full.queries)
    ea = WmdEngine(appended, lam=8.0, n_iter=10, tol=1e-3, check_every=5)
    er = WmdEngine(rebuilt, lam=8.0, n_iter=10, tol=1e-3, check_every=5)
    sa = ea.search(qs, 5, prune="ivf+wcd+rwmd")
    sr = er.search(qs, 5, prune="ivf+wcd+rwmd")
    for qi in range(len(qs)):
        assert set(sa.indices[qi]) == set(sr.indices[qi])


def test_auto_n_clusters(dedup):
    from repro.core.index import default_n_clusters

    index = build_index(dedup.docs, dedup.vecs, n_clusters="auto")
    n = index.n_docs
    # dedup-style corpora want far MORE clusters than sqrt(N): the radius
    # statistic must push past the default
    assert index.clusters.n_clusters > default_n_clusters(n)
    assert index.clusters.n_clusters <= n
    # direct call is deterministic in the seed
    cents = np.asarray(index.centroids)
    assert auto_n_clusters(cents, seed=0) == auto_n_clusters(cents, seed=0)
    with pytest.raises(ValueError):
        build_index(dedup.docs, dedup.vecs, n_clusters="autoo")
