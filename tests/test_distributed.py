"""Distributed Sinkhorn correctness on a multi-(fake-)device mesh.

Runs in a subprocess so XLA_FLAGS device-count never pollutes the main test
process (smoke tests must see exactly 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.data.corpus import make_corpus, shard_balanced
    from repro.core import one_to_many, select_support
    from repro.core.sparse import padded_docs_to_dense
    from repro.core.distributed import (sinkhorn_wmd_dense_distributed,
                                        sinkhorn_wmd_sparse_distributed)

    assert len(jax.devices()) == 8
    c = make_corpus(vocab_size=512, embed_dim=16, n_docs=64, n_queries=1,
                    seed=2)
    q = c.queries[0]
    ref = np.asarray(one_to_many(q, c.docs, c.vecs, lam=8.0, n_iter=40,
                                 impl="sparse"))
    r, vs, _ = select_support(q, c.vecs)

    for shape, names in (((2, 4), ("data", "model")),
                         ((2, 2, 2), ("pod", "data", "model"))):
        mesh = jax.make_mesh(shape, names)
        cd = jnp.asarray(padded_docs_to_dense(c.docs, 512))
        dd = np.asarray(sinkhorn_wmd_dense_distributed(
            r, vs, jnp.asarray(c.vecs), cd, 8.0, 40, mesh))
        assert np.abs(dd - ref).max() < 1e-3, ("dense", names)
        for vp in (False, True):
            ds = np.asarray(sinkhorn_wmd_sparse_distributed(
                r, vs, jnp.asarray(c.vecs), c.docs, 8.0, 40, mesh,
                vshard_precompute=vp))
            assert np.abs(ds - ref).max() < 1e-3, ("sparse", names, vp)

    # nnz-balanced sharding preserves the distance multiset
    sb = shard_balanced(c.docs, 8)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    db = np.asarray(sinkhorn_wmd_sparse_distributed(
        r, vs, jnp.asarray(c.vecs), sb, 8.0, 40, mesh,
        vshard_precompute=True))
    assert np.allclose(np.sort(db), np.sort(ref), atol=1e-3)
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_all_variants():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DISTRIBUTED_OK" in res.stdout, res.stdout + res.stderr
