"""Batched multi-query WMD engine (repro.core.index) correctness.

Covers the ISSUE-1 contract: bucketed batched solves bit-match the
per-query oracle, query padding and doc-length grouping are inert,
a CorpusIndex is reusable across calls, and the in-VMEM GM reconstruction
equals the materialized (K*M) gather on both solver paths.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (WmdEngine, build_index, bucket_size, many_to_many,
                        one_to_many, reconstruct_gm, select_support)
from repro.core.sinkhorn import cdist
from repro.data.corpus import make_corpus
from repro.kernels import ops
from repro.kernels.ref import (reconstruct_gm_ref, sinkhorn_fused_all_ref,
                               sinkhorn_fused_all_materialized_ref)


@pytest.fixture(scope="module")
def engine_corpus():
    # mixed v_r across several buckets (v_r spans ~2..30)
    return make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=10,
                       words_per_doc=(3, 60), seed=11)


def _oracle(corpus, q, lam, n_iter):
    return np.asarray(one_to_many(q, corpus.docs, corpus.vecs, lam, n_iter,
                                  impl="sparse"))


def test_bucket_size_policy():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(17) == 32
    assert bucket_size(33, min_bucket=8) == 64
    assert bucket_size(3, min_bucket=4) == 4


@pytest.mark.parametrize("impl", ["sparse", "kernel"])
def test_batched_matches_per_query_oracle(engine_corpus, impl):
    """Engine distances == per-query sparse oracle, across buckets."""
    c = engine_corpus
    eng = WmdEngine(build_index(c.docs, c.vecs), lam=8.0, n_iter=15,
                    impl=impl)
    got = np.asarray(eng.query_batch(list(c.queries)))
    assert got.shape == (len(c.queries), c.docs.n_docs)
    for i, q in enumerate(c.queries):
        ref = _oracle(c, q, 8.0, 15)
        np.testing.assert_allclose(got[i], ref, rtol=5e-4, atol=5e-4)


def test_many_to_many_batched_equals_looped(engine_corpus):
    c = engine_corpus
    qs = list(c.queries[:4])
    batched = many_to_many(qs, c.docs, c.vecs, lam=8.0, n_iter=12,
                           impl="sparse", batched=True)
    looped = many_to_many(qs, c.docs, c.vecs, lam=8.0, n_iter=12,
                          impl="sparse", batched=False)
    for b, l in zip(batched, looped):
        np.testing.assert_allclose(np.asarray(b), np.asarray(l),
                                   rtol=5e-4, atol=5e-4)


def test_bucket_padding_inert(engine_corpus):
    """Padding a query to a larger bucket never changes its distances."""
    c = engine_corpus
    q = c.queries[0]
    small = WmdEngine(build_index(c.docs, c.vecs), lam=8.0, n_iter=10,
                      min_bucket=8)
    huge = WmdEngine(build_index(c.docs, c.vecs), lam=8.0, n_iter=10,
                     min_bucket=128)   # forces ~4x more pad rows
    d_small = np.asarray(small.query(q))
    d_huge = np.asarray(huge.query(q))
    np.testing.assert_allclose(d_huge, d_small, rtol=1e-5, atol=1e-6)


def test_doc_grouping_inert(engine_corpus):
    """Doc-length grouping (1 vs many groups) never changes distances."""
    c = engine_corpus
    outs = []
    for dg in (1, 2, 5):
        eng = WmdEngine(build_index(c.docs, c.vecs, doc_groups=dg),
                        lam=8.0, n_iter=10)
        outs.append(np.asarray(eng.query_batch(list(c.queries[:3]))))
    np.testing.assert_allclose(outs[1], outs[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[2], outs[0], rtol=1e-5, atol=1e-6)


def test_index_reuse_identical(engine_corpus):
    """One frozen index, many calls: results are bitwise-identical, and
    single-query calls agree with the batch path."""
    c = engine_corpus
    eng = WmdEngine(build_index(c.docs, c.vecs), lam=8.0, n_iter=10)
    qs = list(c.queries[:4])
    first = np.asarray(eng.query_batch(qs))
    second = np.asarray(eng.query_batch(qs))
    np.testing.assert_array_equal(first, second)
    single = np.asarray(eng.query(qs[2]))
    np.testing.assert_array_equal(single, first[2])


def test_empty_batch(engine_corpus):
    c = engine_corpus
    eng = WmdEngine(build_index(c.docs, c.vecs))
    assert np.asarray(eng.query_batch([])).shape == (0, c.docs.n_docs)


# ------------------------------------------------- GM reconstruction proofs
def test_reconstruct_gm_equals_materialized(engine_corpus, rng):
    """-G*log(G)/lam == the materialized (K*M) gather, including pad zeros."""
    c = engine_corpus
    lam = 6.0
    r, vecs_sel, _ = select_support(c.queries[0], c.vecs)
    m = cdist(vecs_sel, jnp.asarray(c.vecs))
    k = jnp.exp(-lam * m)
    g = jnp.take(k, c.docs.idx, axis=1)
    gm_mat = jnp.take(k * m, c.docs.idx, axis=1)
    for recon in (reconstruct_gm(g, lam), reconstruct_gm_ref(g, lam)):
        np.testing.assert_allclose(np.asarray(recon), np.asarray(gm_mat),
                                   rtol=2e-4, atol=1e-6)
    # zero entries (pads / exp underflow) reconstruct to exactly 0
    gz = g.at[0, 0, 0].set(0.0)
    assert float(reconstruct_gm(gz, lam)[0, 0, 0]) == 0.0


def test_kernel_path_gm_reconstruction(rng):
    """Fused kernel (interpret) with in-VMEM GM reconstruction matches the
    explicit materialized-GM oracle."""
    v_r, n, length, lam, n_iter = 12, 64, 16, 4.0, 12
    g = jnp.asarray(rng.uniform(0.02, 1.0, (v_r, n, length)) ** 2,
                    dtype=jnp.float32)
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.4,
                    jnp.asarray(rng.random((n, length)), jnp.float32), 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, v_r).astype(np.float32))
    gm_mat = reconstruct_gm_ref(g, lam)     # == -g*log(g)/lam, materialized
    out = ops.sinkhorn_fused_all(g, val, r, lam, n_iter)
    want = sinkhorn_fused_all_materialized_ref(g, gm_mat, val, r, n_iter)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-5, atol=5e-5)
    # and the lam-only ref is the same thing
    np.testing.assert_allclose(
        np.asarray(sinkhorn_fused_all_ref(g, val, r, lam, n_iter)),
        np.asarray(want), rtol=1e-6, atol=1e-6)


def test_sparse_precompute_sheds_gm(engine_corpus):
    """SparsePrecompute holds exactly two nnz-sized arrays (G, G_over_r)."""
    from repro.core.sinkhorn_sparse import SparsePrecompute, precompute_sparse
    assert set(SparsePrecompute._fields) == {"G", "G_over_r", "val"}
    c = engine_corpus
    r, vecs_sel, _ = select_support(c.queries[0], c.vecs)
    pre = precompute_sparse(r, vecs_sel, jnp.asarray(c.vecs), c.docs, 5.0)
    nnz_shaped = [f for f in pre if f.ndim == 3]
    assert len(nnz_shaped) == 2


# -------------------------------------------------- batched kernel vs einsum
def test_batched_kernel_matches_per_query_kernel(rng):
    """sinkhorn_fused_all_batched == Q independent sinkhorn_fused_all."""
    q_n, v_r, n, length, lam, n_iter = 3, 10, 64, 16, 5.0, 10
    g = jnp.asarray(rng.uniform(0.02, 1.0, (q_n, v_r, n, length)),
                    dtype=jnp.float32)
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.4, 0.5, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, (q_n, v_r)).astype(np.float32))
    batched = ops.sinkhorn_fused_all_batched(g, val, r, lam, n_iter)
    assert batched.shape == (q_n, n)
    for qi in range(q_n):
        single = ops.sinkhorn_fused_all(g[qi], val, r[qi], lam, n_iter)
        np.testing.assert_allclose(np.asarray(batched[qi]),
                                   np.asarray(single), rtol=5e-5, atol=5e-5)


def test_batched_kernel_pad_query_inert(rng):
    """Appending an all-pad query (G == 0, r == 1) leaves the others
    untouched — the engine's q-padding contract."""
    q_n, v_r, n, length = 2, 8, 32, 8
    g = jnp.asarray(rng.uniform(0.05, 1.0, (q_n, v_r, n, length)),
                    dtype=jnp.float32)
    val = jnp.where(jnp.asarray(rng.random((n, length))) > 0.3, 0.7, 0.0)
    val = val.at[:, 0].set(1.0)
    r = jnp.asarray(rng.uniform(0.1, 1.0, (q_n, v_r)).astype(np.float32))
    base = ops.sinkhorn_fused_all_batched(g, val, r, 4.0, 8)
    g2 = jnp.concatenate([g, jnp.zeros((1, v_r, n, length))])
    r2 = jnp.concatenate([r, jnp.ones((1, v_r))])
    padded = ops.sinkhorn_fused_all_batched(g2, val, r2, 4.0, 8)
    np.testing.assert_allclose(np.asarray(padded[:q_n]), np.asarray(base),
                               rtol=1e-6, atol=1e-6)
