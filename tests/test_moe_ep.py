"""Expert-parallel (shard_map) MoE == single-device MoE, numerically.

Subtlety tested: EP computes ranks/capacity PER DATA SHARD (capacity
C_loc = C_global / n_shards), so with a balanced router and divisible
shapes the kept-token set matches the global computation; we verify the
full outputs agree on a small mesh against the pjit/single-device layer.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.models.moe import init_moe, moe_apply, moe_apply_ep

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # jax >= 0.5 global-mesh API; older jax relies on the `with mesh:` below
    getattr(jax, "set_mesh", lambda m: None)(mesh)
    e, d, ff, k = 8, 32, 16, 2
    p = init_moe(jax.random.PRNGKey(0), d, ff, e, 1, k, tp=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, d)) * 0.5

    # topk router: per-token stateless -> local == global routing decisions.
    # (The sinkhorn router INTENTIONALLY differs: it balances over the token
    # set it sees — per data shard in EP, the scalable semantics — so exact
    # equivalence is only defined for stateless routers.)
    # generous capacity so neither path drops tokens -> exact agreement
    ref, aux_ref = moe_apply(p, x, k, "topk", capacity_factor=8.0)
    with mesh:
        out, aux = jax.jit(lambda p, x: moe_apply_ep(
            p, x, k, "topk", 8.0, 6, e, mesh, ("data",), "model"))(p, x)
    err = float(jnp.abs(out - ref).max())
    scale = float(jnp.abs(ref).max())
    assert err < 5e-5 * max(scale, 1.0), (err, scale)
    # aux: EP averages per-shard switch losses; reference is global — equal
    # in expectation, compare loosely
    assert abs(float(aux) - float(aux_ref)) < 0.3
    print("MOE_EP_OK", err)
""")


@pytest.mark.slow
def test_ep_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
                         capture_output=True, text=True, timeout=600)
    assert "MOE_EP_OK" in res.stdout, res.stdout + res.stderr
