"""Core solver behaviour: faithfulness to the paper's Algorithm 1 and to
exact optimal transport."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import one_to_many, padded_docs_to_dense, select_support
from repro.core.exact_ot import exact_emd
from repro.core.sinkhorn import cdist
from repro.data.corpus import make_corpus

LAM, N_ITER = 9.0, 40


@pytest.mark.parametrize("impl", ["sparse", "sparse_unfused", "kernel"])
def test_sparse_impls_match_dense(small_corpus, impl):
    """Paper §4: the sparse transformation computes the SAME distances."""
    q = small_corpus.queries[0]
    ref = one_to_many(q, small_corpus.docs, small_corpus.vecs, LAM, N_ITER,
                      impl="dense")
    got = one_to_many(q, small_corpus.docs, small_corpus.vecs, LAM, N_ITER,
                      impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_stabilized_matches_dense(small_corpus):
    """In the fp32-safe regime (lam*max(M) well below -log(fp32 tiny) ~ 87)
    the log-domain and scaling-vector iterations agree."""
    q = small_corpus.queries[1]
    ref = one_to_many(q, small_corpus.docs, small_corpus.vecs, 4.0, 800,
                      impl="dense")
    got = one_to_many(q, small_corpus.docs, small_corpus.vecs, 4.0, 800,
                      impl="dense_stabilized")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_dense_fp32_underflow_vs_stabilized():
    """Beyond-paper finding: the paper's scaling-vector iteration silently
    loses accuracy in fp32 once lam*M ~ 80 (K = exp(-lam*M) underflows);
    the log-domain variant stays within a few permil of the exact LP.
    (The paper ran fp64 on CPU and never hits this; TPU fp32 does.)"""
    corp = make_corpus(vocab_size=512, embed_dim=32, n_docs=64, n_queries=3,
                       seed=7)
    q = corp.queries[1]
    r, vecs_sel, _ = select_support(q, corp.vecs)
    m = np.asarray(cdist(vecs_sel, jnp.asarray(corp.vecs)))
    c_dense = padded_docs_to_dense(corp.docs, 512)
    dd = np.asarray(one_to_many(q, corp.docs, corp.vecs, 9.0, 800,
                                impl="dense"))
    ds = np.asarray(one_to_many(q, corp.docs, corp.vecs, 9.0, 800,
                                impl="dense_stabilized"))
    j = int(np.argmax(np.abs(dd - ds)))
    col = c_dense[:, j]
    sel = np.nonzero(col > 0)[0]
    exact = exact_emd(np.asarray(r), col[sel], m[:, sel])
    # stabilized is near the LP optimum; plain fp32 dense is measurably off
    assert abs(ds[j] - exact) / exact < 5e-3
    assert abs(dd[j] - exact) / exact > 1e-2


def test_matches_exact_ot():
    """Cuturi'13 / paper §2: Sinkhorn distance -> exact EMD as lam grows."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=8, n_queries=1,
                       seed=11)
    q = corp.queries[0]
    r, vecs_sel, _ = select_support(q, corp.vecs)
    m = np.asarray(cdist(vecs_sel, jnp.asarray(corp.vecs)))
    c_dense = padded_docs_to_dense(corp.docs, 256)
    approx = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=40.0,
                                    n_iter=400, impl="dense_stabilized"))
    for j in range(c_dense.shape[1]):
        col = c_dense[:, j]
        sel = np.nonzero(col > 0)[0]
        exact = exact_emd(np.asarray(r), col[sel], m[:, sel])
        assert abs(approx[j] - exact) / exact < 5e-3, (j, approx[j], exact)


def test_sinkhorn_upper_bounds_emd():
    """Entropic penalty => Sinkhorn cost >= exact transport cost."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=6, n_queries=1,
                       seed=13)
    q = corp.queries[0]
    r, vecs_sel, _ = select_support(q, corp.vecs)
    m = np.asarray(cdist(vecs_sel, jnp.asarray(corp.vecs)))
    c_dense = padded_docs_to_dense(corp.docs, 256)
    approx = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=10.0,
                                    n_iter=300, impl="dense_stabilized"))
    for j in range(c_dense.shape[1]):
        col = c_dense[:, j]
        sel = np.nonzero(col > 0)[0]
        exact = exact_emd(np.asarray(r), col[sel], m[:, sel])
        assert approx[j] >= exact - 1e-3


def test_identical_documents_near_zero():
    """WMD(doc, doc) ~ 0: moving a distribution onto itself costs ~nothing."""
    corp = make_corpus(vocab_size=256, embed_dim=8, n_docs=4, n_queries=1,
                       seed=5)
    # build a query equal to target doc 0
    idx = np.asarray(corp.docs.idx[0])
    val = np.asarray(corp.docs.val[0])
    q = np.zeros(256, dtype=np.float32)
    q[idx[val > 0]] = val[val > 0]
    d = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=40.0, n_iter=400,
                               impl="dense_stabilized"))
    others = np.delete(d, 0)
    assert d[0] < 0.05 * others.min(), (d[0], others.min())


def test_more_iterations_converge(small_corpus):
    q = small_corpus.queries[2]
    runs = [np.asarray(one_to_many(q, small_corpus.docs, small_corpus.vecs,
                                   4.0, it, impl="sparse"))
            for it in (50, 100, 200, 400)]
    d1 = np.abs(runs[1] - runs[0]).max()
    d2 = np.abs(runs[2] - runs[1]).max()
    d3 = np.abs(runs[3] - runs[2]).max()
    assert d3 <= d2 <= d1 + 1e-5    # geometric contraction
    assert d3 < 0.5 * d1            # and materially so


def test_wmd_positive_and_finite(small_corpus):
    for q in small_corpus.queries:
        d = np.asarray(one_to_many(q, small_corpus.docs, small_corpus.vecs,
                                   LAM, N_ITER, impl="sparse"))
        assert np.all(np.isfinite(d))
        assert np.all(d > 0)
