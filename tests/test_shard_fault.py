"""Shard-level fault-tolerance tests (ISSUE 9): the circuit breaker's
deterministic cadence, snapshot save/load integrity + bit-compatible
restore, structured fan-out failures, timeout-driven circuit opening and
probe re-admission, and the serving runtime's partial-coverage tagging.

Everything here runs on the single real CPU device with a 1-shard
``ShardedWmdEngine`` (the fan-out/health/snapshot machinery is identical
at any shard count); the true multi-device partial-merge paths live in
``tests/test_shard_index.py``'s subprocess scripts."""
import asyncio
import time

import numpy as np
import pytest

from repro.core import (ShardCoverage, ShardSearchError, ShardedWmdEngine,
                        SearchResult, WmdEngine, append_docs_sharded,
                        build_index, load_index, save_index, shard_corpus)
from repro.runtime.fault_tolerance import ShardHealth
from repro.runtime.serving import (FaultInjector, ServeConfig, ServeRequest,
                                   ServingRuntime)

LAM = 1.0
N_ITER = 10
PRUNE = "rwmd"


@pytest.fixture()
def sharded_engine(small_corpus):
    sindex = shard_corpus(small_corpus.docs, small_corpus.vecs, 1,
                          n_clusters=8)
    return ShardedWmdEngine(sindex, lam=LAM, n_iter=N_ITER,
                            shard_retries=1, shard_backoff_s=0.001)


def _dist_equal(a, b) -> bool:
    return np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)


# -------------------------------------------------------- circuit breaker
def test_health_opens_at_consecutive_threshold():
    h = ShardHealth(2, fail_threshold=3)
    for _ in range(2):
        h.record_failure(0)
    assert not h.is_open(0)
    h.record_success(0, 0.01)          # success resets the strike count
    for _ in range(2):
        h.record_failure(0)
    assert not h.is_open(0)
    h.record_failure(0)
    assert h.is_open(0) and h.opened[0] == 1
    assert h.open_shards == (0,)
    assert not h.is_open(1)            # per-shard state, not global


def test_health_probe_cadence_is_deterministic():
    h = ShardHealth(1, fail_threshold=1, probe_every=3)
    h.record_failure(0)
    admits = [h.admit(0) for _ in range(6)]
    assert admits == [False, False, True, False, False, True]
    assert h.probes[0] == 2


def test_health_successful_probe_closes_circuit():
    h = ShardHealth(1, fail_threshold=1, probe_every=1)
    h.record_failure(0)
    assert h.is_open(0) and h.admit(0)     # probe admitted
    h.record_success(0, 0.02)
    assert not h.is_open(0)
    assert all(h.admit(0) for _ in range(4))


def test_health_ema_reset_and_stats():
    h = ShardHealth(2, ema_alpha=0.5)
    assert h.ema(0) is None
    h.record_success(0, 0.1)
    assert h.ema(0) == pytest.approx(0.1)
    h.record_success(0, 0.3)
    assert h.ema(0) == pytest.approx(0.2)   # 0.5*0.1 + 0.5*0.3
    h.record_failure(1)
    st = h.stats()
    assert st["successes"] == [2, 0] and st["failures"] == [0, 1]
    h.reset(0)
    assert h.ema(0) is None and not h.is_open(0)


# ------------------------------------------------------- index snapshots
def test_index_save_load_search_bitcompat(small_corpus, tmp_path):
    index = build_index(small_corpus.docs, small_corpus.vecs, n_clusters=8)
    path = tmp_path / "index.npz"
    index.save(path)
    loaded = load_index(path)
    assert np.array_equal(np.asarray(index.docs.idx),
                          np.asarray(loaded.docs.idx))
    assert _dist_equal(index.docs.val, loaded.docs.val)
    assert len(index.groups) == len(loaded.groups)
    q = list(small_corpus.queries)
    a = WmdEngine(index, lam=LAM, n_iter=N_ITER).search(q, 5, prune=PRUNE)
    b = WmdEngine(loaded, lam=LAM, n_iter=N_ITER).search(q, 5, prune=PRUNE)
    assert np.array_equal(a.indices, b.indices)
    assert _dist_equal(a.distances, b.distances)


def test_index_snapshot_corruption_detected(small_corpus, tmp_path):
    index = build_index(small_corpus.docs, small_corpus.vecs, n_clusters=8)
    path = tmp_path / "index.npz"
    save_index(index, path)
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    arrays["val"] = arrays["val"] + 1e-3       # bit-flip, checksum kept
    with open(path, "wb") as f:
        np.savez(f, **arrays)
    with pytest.raises(ValueError, match="integrity"):
        load_index(path)


def test_sharded_snapshot_restore_bitcompat(sharded_engine, small_corpus,
                                            tmp_path):
    engine = sharded_engine
    queries = list(small_corpus.queries)
    baseline = engine.search(queries, 5, prune=PRUNE)
    engine.snapshot(tmp_path)
    engine.health.record_failure(0)            # pretend the shard died
    engine.restore_shard(0)
    assert not engine.health.is_open(0)
    assert engine.health.ema(0) is None        # clean record post-restore
    res = engine.search(queries, 5, prune=PRUNE)
    assert engine.last_coverage.full
    assert np.array_equal(baseline.indices, res.indices)
    assert _dist_equal(baseline.distances, res.distances)


def test_snapshot_requires_directory(sharded_engine):
    with pytest.raises(ValueError, match="snapshot directory"):
        sharded_engine.snapshot()
    with pytest.raises(ValueError, match="snapshot directory"):
        sharded_engine.restore_shard(0)


def test_stale_snapshot_rejected_after_append(small_corpus, tmp_path):
    from repro.core.sparse import PaddedDocs
    sindex = shard_corpus(small_corpus.docs, small_corpus.vecs, 1,
                          n_clusters=8)
    engine = ShardedWmdEngine(sindex, lam=LAM, n_iter=N_ITER,
                              snapshot_dir=tmp_path)
    engine.snapshot()
    grow = PaddedDocs(idx=small_corpus.docs.idx[:4],
                      val=small_corpus.docs.val[:4])
    engine.sindex = append_docs_sharded(engine.sindex, grow)
    with pytest.raises(ValueError, match="STALE"):
        engine.restore_shard(0)


# ------------------------------------------------------ fan-out failures
def test_raw_shard_exception_becomes_structured(sharded_engine,
                                                small_corpus):
    engine = sharded_engine

    def boom(*a, **kw):
        raise ValueError("boom")

    engine.engines[0].search = boom
    with pytest.raises(ShardSearchError, match="shard 0") as ei:
        engine.search(list(small_corpus.queries), 5, prune=PRUNE)
    assert ei.value.shard_reasons == {0: "ValueError: boom"}


def test_transient_shard_failure_retried_to_success(sharded_engine,
                                                    small_corpus):
    engine = sharded_engine
    orig = engine.engines[0].search
    calls = []

    def flaky(*a, **kw):
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("transient device loss")
        return orig(*a, **kw)

    engine.engines[0].search = flaky
    res = engine.search(list(small_corpus.queries), 5, prune=PRUNE)
    assert len(calls) == 2                  # retry inside _guarded_shard
    assert engine.last_coverage.full
    assert res.indices.shape == (3, 5)
    assert engine.health.failures[0] == 0   # retried failures don't strike


def test_timeout_opens_circuit_then_probe_readmits(small_corpus):
    sindex = shard_corpus(small_corpus.docs, small_corpus.vecs, 1,
                          n_clusters=8)
    engine = ShardedWmdEngine(sindex, lam=LAM, n_iter=N_ITER,
                              shard_timeout_s=0.05, shard_retries=0,
                              fail_threshold=2, probe_every=2)
    queries = list(small_corpus.queries)
    baseline = engine.search(queries, 5, prune=PRUNE)   # warm compile
    orig = engine.engines[0].search

    def hang(*a, **kw):
        time.sleep(0.3)
        return orig(*a, **kw)

    engine.engines[0].search = hang
    for _ in range(2):
        with pytest.raises(ShardSearchError, match="timeout"):
            engine.search(queries, 5, prune=PRUNE)
    assert engine.health.is_open(0)
    assert engine.health.failures[0] == 2
    engine.engines[0].search = orig
    time.sleep(0.8)                  # drain the hung background futures
    # 1-shard mesh with every circuit open: the fan-out force-probes (it
    # never refuses to serve on breaker state alone), and the successful
    # probe closes the circuit
    res = engine.search(queries, 5, prune=PRUNE)
    assert not engine.health.is_open(0)
    assert engine.last_coverage.full
    assert np.array_equal(baseline.indices, res.indices)


def test_injected_shard_transient_retried(sharded_engine, small_corpus):
    """Site-5 injection at rate 1.0 fails every FIRST attempt; the shard
    retry absorbs it and the request still succeeds at full coverage."""
    engine = sharded_engine
    injector = FaultInjector(shard_transient_rate=1.0,
                             shard_transient_attempts=1, seed=3)
    engine.shard_fault_hook = injector.before_shard_attempt
    res = engine.search(list(small_corpus.queries), 5, prune=PRUNE)
    assert engine.last_coverage.full
    assert res.indices.shape == (3, 5)
    assert any(t[0] == "shard_transient" for t in injector.trace)


# ----------------------------------------------- serving runtime surface
def _run_serving(engine, queries, injector=None, k=5):
    rt = ServingRuntime(
        engine,
        ServeConfig(max_batch=2, window_s=0.02, max_queue=64,
                    deadline_s=None, backoff_s=0.001, prune=PRUNE),
        injector=injector)

    async def go():
        await rt.start()
        futs = [rt.submit(q, k=k) for q in queries]
        out = await asyncio.gather(*futs)
        await rt.stop()
        return list(out)

    return asyncio.run(go()), rt


def test_crashed_only_shard_serves_structured_errors(sharded_engine,
                                                     small_corpus):
    """With the mesh's ONLY shard crashed, every request must still
    resolve — to a structured ``shard_failed`` error, not a hang."""
    engine = sharded_engine
    injector = FaultInjector(crash_shard=0, crash_after=0, seed=1)
    resps, rt = _run_serving(engine, list(small_corpus.queries),
                             injector=injector)
    assert len(resps) == 3
    assert all(not r.ok for r in resps)
    assert {r.error["code"] for r in resps} == {"shard_failed"}
    assert all("shard" in r.error["message"] for r in resps)
    stats = rt.stats()
    assert stats["shard_health"]["failures"][0] > 0


def test_recovered_shard_serves_clean_after_crash(sharded_engine,
                                                  small_corpus, tmp_path):
    engine = sharded_engine
    engine.snapshot(tmp_path)
    injector = FaultInjector(crash_shard=0, crash_after=0, seed=1)
    resps, _ = _run_serving(engine, list(small_corpus.queries),
                            injector=injector)
    assert all(not r.ok for r in resps)
    injector.revive_shard()
    engine.restore_shard(0)
    resps, rt = _run_serving(engine, list(small_corpus.queries),
                             injector=injector)
    assert all(r.ok and not r.partial for r in resps)
    assert rt.stats()["partial"] == 0


class _FakePartialEngine:
    """Duck-typed sharded engine: reports half the corpus missing so the
    runtime's coverage tagging can be tested on the real single device
    (true multi-device partials run in test_shard_index.py)."""
    min_bucket = 8
    dtype = np.float32
    iter_stats_dropped = 0
    n_shards = 2
    docs_per_shard = (4, 4)
    shard_fault_hook = None

    def reset_iter_stats(self):
        pass

    def iter_stats_by_stage(self):
        return {}

    def search(self, queries, k, **kw):
        self.last_coverage = ShardCoverage(0.5, 4, (1,), {1: "timeout"})
        nq = len(queries)
        return SearchResult(np.zeros((nq, k), np.int32),
                            np.zeros((nq, k), np.float32),
                            np.zeros(nq, np.int64))


def test_partial_coverage_tags_response_and_blocks_exactness():
    rt = ServingRuntime(_FakePartialEngine(), ServeConfig(prune=PRUNE))
    req = ServeRequest(rid=0, query=np.ones(4), k=3, deadline=None,
                       enqueue_t=time.monotonic(), v_r=4)
    resp = rt._score([req], rt.tiers[0])[req.rid]
    assert resp.ok and resp.partial
    assert not resp.exact, "partial response must never claim exactness"
    assert resp.coverage == pytest.approx(0.5)
    assert resp.missing_shards == [1]
    assert "PARTIAL" in resp.caveat and "timeout" in resp.caveat
    j = resp.to_json()
    assert j["partial"] and j["coverage"] == pytest.approx(0.5)
    assert j["missing_shards"] == [1]


def test_shard_search_error_classified(small_corpus):
    index = build_index(small_corpus.docs, small_corpus.vecs, n_clusters=8)
    rt = ServingRuntime(WmdEngine(index, lam=LAM, n_iter=N_ITER),
                        ServeConfig(prune=PRUNE))
    req = ServeRequest(rid=1, query=np.ones(4), k=3, deadline=None,
                       enqueue_t=time.monotonic(), v_r=4)
    resp = rt._classify_error(
        req, ShardSearchError("search: all 2 shards failed", {0: "x"}))
    assert not resp.ok and resp.error["code"] == "shard_failed"
    assert "shards" in resp.error["diagnostics"]


# ------------------------------------------------------- compare.py gate
def test_compare_warns_on_dead_gate_prefix(capsys):
    from benchmarks.compare import compare
    base = {"fig7.cdist": 100.0}
    cur = {"fig7.cdist": 110.0}
    failures = compare(base, cur, max_ratio=1.3,
                       prefixes=["fig7", "fig99.gone"],
                       min_prefixes=["fig98.recall"])
    out = capsys.readouterr().out
    assert failures == []
    assert "gate prefix 'fig99.gone' matches no current record" in out
    assert "gate prefix 'fig98.recall' matches no current record" in out
    assert "'fig7'" not in out       # live prefixes don't warn
