"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step + one decode step on CPU; shapes + finiteness.
The FULL configs are exercised via the dry-run only (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import adamw

B, SEQ = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, SEQ), 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    hidden, aux = T.forward(cfg, params, batch["tokens"])
    assert hidden.shape == (B, SEQ, cfg.d_model)
    assert np.isfinite(np.asarray(hidden)).all()

    step = jax.jit(M.make_train_step(cfg))
    opt = adamw.init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, B, max_len=16)
    step = jax.jit(M.make_serve_step(cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(3):
        tok, logits, cache = step(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["granite_3_2b", "rwkv6_3b", "zamba2_7b"])
def test_prefill_matches_decode(arch):
    """Decoding token-by-token must reproduce the prefill logits (the
    serve-path correctness invariant)."""
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                                cfg.vocab_size)
    hidden, _ = T.forward(cfg, params, tokens, remat=False)
    head = T.lm_head_matrix(cfg, params)
    full_logits = np.asarray((hidden @ head).astype(jnp.float32))

    cache = T.init_cache(cfg, B, max_len=8)
    outs = []
    for t in range(8):
        logits, cache = T.decode_step(cfg, params, cache, tokens[:, t:t + 1])
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full_logits, rtol=2e-3, atol=2e-3)


def test_loss_decreases_tiny_overfit():
    """Integration: 30 steps on one repeated batch must cut the loss."""
    cfg = get_config("granite_3_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    from repro.models.model import TrainHParams
    step = jax.jit(M.make_train_step(
        cfg, hp=TrainHParams(peak_lr=1e-3, warmup_steps=5, total_steps=50)))
    opt = adamw.init(params)
    first = None
    for i in range(30):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["ce"])
    assert float(m["ce"]) < 0.7 * first, (first, float(m["ce"]))


def test_param_counts_match_config_estimate():
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.n_params()
        assert abs(actual - est) / actual < 0.25, (arch, actual, est)
