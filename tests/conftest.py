import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches
# must see the real single CPU device. Multi-device tests spawn subprocesses
# (see tests/test_distributed.py) so the 512-device dry-run env never leaks.


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data.corpus import make_corpus
    return make_corpus(vocab_size=512, embed_dim=32, n_docs=64, n_queries=3,
                       seed=7)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
