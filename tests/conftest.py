import zlib

import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches
# must see the real single CPU device. Multi-device tests spawn subprocesses
# (see tests/test_distributed.py) so the 512-device dry-run env never leaks.


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data.corpus import make_corpus
    return make_corpus(vocab_size=512, embed_dim=32, n_docs=64, n_queries=3,
                       seed=7)


@pytest.fixture()
def rng(request):
    """Deterministic per-TEST generator (ISSUE 5 hygiene fix).

    The old session-scoped generator was shared mutable state: each test
    drew from wherever the previous consumer left the stream, so the
    values any one test saw depended on which other tests ran before it
    (``-k`` selections, ``-x`` aborts, and new tests all reshuffled the
    draws — the ordering sensitivity behind the test_prune/test_ivf
    dedup-corpus constructions). Seeding from the test's own nodeid makes
    every test's stream a pure function of its name: stable under
    insertion, selection, and reordering.
    """
    return np.random.default_rng(zlib.adler32(request.node.nodeid.encode()))
