"""IVF cascade (ISSUE 3): exactness at nprobe=all, frozen-cluster appends,
recall monotonicity in nprobe, the candidate-subset RWMD kernel, and the
underflow guards folded into the low-level solvers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (CascadePruner, LamUnderflowError, WmdEngine,
                        append_docs, build_index, resolve_pruner,
                        select_support)
from repro.core.distributed import sinkhorn_wmd_sparse_distributed
from repro.core.index import _assign_clusters
from repro.core.prune import RwmdPruner, _min_cdist_xla, _pad_pow2_ids
from repro.core.sinkhorn_sparse import sinkhorn_wmd_sparse
from repro.core.sparse import PaddedDocs
from repro.data.corpus import make_corpus
from repro.kernels import ops
from repro.kernels.ref import rwmd_min_cdist_ref


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=8,
                       words_per_doc=(3, 60), seed=11)


@pytest.fixture(scope="module")
def engine(corpus):
    return WmdEngine(build_index(corpus.docs, corpus.vecs), lam=8.0,
                     n_iter=15)


def _recall(result, exhaustive, k):
    return float(np.mean([
        len(set(result.indices[qi]) & set(exhaustive.indices[qi])) / k
        for qi in range(result.indices.shape[0])]))


# -------------------------------------------------------------- exactness
@pytest.mark.parametrize("prune", ["ivf+wcd+rwmd", "ivf+rwmd", "ivf+wcd"])
@pytest.mark.parametrize("k", [1, 5])
def test_cascade_nprobe_all_equals_exhaustive(corpus, engine, prune, k):
    """nprobe = n_clusters (the default) keeps the exact-top-k contract."""
    queries = list(corpus.queries)
    ex = engine.search(queries, k, prune=None)
    pr = engine.search(queries, k, prune=prune)
    for qi in range(len(queries)):
        assert set(ex.indices[qi]) == set(pr.indices[qi]), (prune, k, qi)
        np.testing.assert_allclose(np.sort(pr.distances[qi]),
                                   np.sort(ex.distances[qi]),
                                   rtol=1e-4, atol=1e-5)


def test_cascade_solves_strict_subset_on_separable_corpus():
    """On the fig8 near-duplicate corpus the cascade must also PRUNE (the
    sub-O(N) contract), not just stay correct."""
    from benchmarks.fig8_topk_prune import dedup_corpus
    corpus = dedup_corpus(256, vocab=1024, embed_dim=32, seed=5)
    eng = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=2.0,
                    n_iter=15)
    queries = list(corpus.queries)
    ex = eng.search(queries, 8, prune=None)
    pr = eng.search(queries, 8, prune="ivf+wcd+rwmd")
    for qi in range(len(queries)):
        assert set(ex.indices[qi]) == set(pr.indices[qi])
    assert (pr.solved < 128).all(), pr.solved


# ------------------------------------------------------------ cluster state
def test_cluster_invariants(corpus):
    index = build_index(corpus.docs, corpus.vecs)
    cl = index.clusters
    n = index.n_docs
    # Lloyd fixed point of the final pass: assign == nearest center
    want = np.asarray(_assign_clusters(index.centroids, cl.centers))
    np.testing.assert_array_equal(cl.assign, want)
    # membership arrays are consistent
    assert np.array_equal(np.sort(cl.order), np.arange(n))
    for c in range(cl.n_clusters):
        members = cl.order[cl.starts[c]:cl.starts[c + 1]]
        assert (cl.assign[members] == c).all()
    # radii dominate every member's distance to its center
    own = np.asarray(cl.centers)[cl.assign]
    d = np.linalg.norm(np.asarray(index.centroids) - own, axis=1)
    assert (d <= cl.radii[cl.assign] + 1e-5).all()


def test_append_assigns_to_nearest_cluster_without_rebuild(corpus):
    full = make_corpus(vocab_size=512, embed_dim=16, n_docs=128,
                       n_queries=6, words_per_doc=(3, 60), seed=23)
    head = PaddedDocs(idx=full.docs.idx[:96], val=full.docs.val[:96])
    tail = PaddedDocs(idx=full.docs.idx[96:], val=full.docs.val[96:])
    base = build_index(head, full.vecs)
    appended = append_docs(base, tail)
    # clusters are FROZEN: centers reused by identity, radii only grow
    assert appended.clusters.centers is base.clusters.centers
    assert (appended.clusters.radii >= base.clusters.radii - 1e-7).all()
    # new docs sit in their nearest existing cluster
    new_assign = appended.clusters.assign[96:]
    want = np.asarray(_assign_clusters(appended.centroids[96:],
                                       base.clusters.centers))
    np.testing.assert_array_equal(new_assign, want)
    # membership stays consistent after the re-sort
    for c in range(appended.clusters.n_clusters):
        members = appended.clusters.order[
            appended.clusters.starts[c]:appended.clusters.starts[c + 1]]
        assert (appended.clusters.assign[members] == c).all()
    # and append == rebuild through the exact cascade (nprobe = all)
    rebuilt = build_index(full.docs, full.vecs)
    queries = list(full.queries)
    ea = WmdEngine(appended, lam=8.0, n_iter=12)
    er = WmdEngine(rebuilt, lam=8.0, n_iter=12)
    sa = ea.search(queries, 5, prune="ivf+wcd+rwmd")
    sr = er.search(queries, 5, prune="ivf+wcd+rwmd")
    for qi in range(len(queries)):
        assert set(sa.indices[qi]) == set(sr.indices[qi])


# ------------------------------------------------------------------ recall
def test_recall_monotone_in_nprobe():
    from benchmarks.fig8_topk_prune import dedup_corpus
    corpus = dedup_corpus(256, vocab=1024, embed_dim=32, seed=5)
    index = build_index(corpus.docs, corpus.vecs)
    eng = WmdEngine(index, lam=2.0, n_iter=15)
    queries = list(corpus.queries)
    k = 8
    ex = eng.search(queries, k, prune=None)
    c = index.clusters.n_clusters
    recalls = []
    for nprobe in [1, 2, 4, max(8, c // 2), c]:
        res = eng.search(queries, k, prune="ivf+wcd+rwmd",
                         nprobe=min(nprobe, c))
        recalls.append(_recall(res, ex, k))
    # probe sets are nested, so the probed universe (and with it recall)
    # can only grow; the full probe is exact
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0, recalls


def test_small_nprobe_pads_result_rows(corpus):
    """A query whose probed cluster holds fewer than k docs pads its row
    with -1 / NaN instead of inventing candidates."""
    index = build_index(corpus.docs, corpus.vecs, n_clusters=48)
    eng = WmdEngine(index, lam=8.0, n_iter=8)
    k = 30
    res = eng.search(list(corpus.queries[:2]), k, prune="ivf+wcd+rwmd",
                     nprobe=1)
    for qi in range(2):
        got = res.indices[qi]
        n_real = int((got >= 0).sum())
        assert n_real <= int(res.solved[qi])
        assert np.isnan(res.distances[qi][n_real:]).all()
        assert (got[:n_real] >= 0).all()


# ------------------------------------------------- candidate-subset kernel
def test_rwmd_subset_kernel_matches_full_sweep(rng):
    a = jnp.asarray(rng.standard_normal((3, 12, 40)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((300, 40)).astype(np.float32))
    mask = jnp.asarray((rng.random((3, 12)) > 0.3).astype(np.float32))
    mask = mask.at[:, 0].set(1.0)
    vids = np.unique(rng.integers(0, 300, 70)).astype(np.int32)
    want = np.asarray(rwmd_min_cdist_ref(a, mask, b))[:, vids]
    got = ops.rwmd_min_cdist(a, mask, b, block_v=128,
                             vocab_ids=jnp.asarray(vids))
    assert got.shape == (3, vids.size)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    got_xla = np.asarray(_min_cdist_xla(a, mask, jnp.take(b,
                                        jnp.asarray(vids), axis=0)))
    np.testing.assert_allclose(got_xla, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_cascade_rwmd_stage_matches_full_pruner(corpus, engine, use_kernel):
    """The cascade's vocab-subset RWMD bounds == the full-sweep RwmdPruner
    columns for the same docs (the bound itself must not change when the
    vocabulary shrinks to the candidates' support)."""
    queries = list(corpus.queries[:4])
    index = engine.index
    _, chunks = engine._plan(queries)
    chunk, width = chunks[0]
    sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk], width)
    full = np.asarray(RwmdPruner().lower_bounds(index, sup, r, mask))
    casc = CascadePruner(use_kernel=use_kernel,
                         interpret=True if use_kernel else None)
    ids = np.asarray([3, 17, 41, 90, 5], np.int32)
    sp = _pad_pow2_ids(ids)
    qm = casc.id_qmask(index, None, sp, ids.size, qp=sup.shape[0])
    lb = np.asarray(casc.stage_bounds("rwmd", index, sup, r, mask, sp,
                                      ids.size, qm))
    np.testing.assert_allclose(lb[:len(chunk), :ids.size],
                               full[:len(chunk)][:, ids],
                               rtol=5e-5, atol=5e-5)


def test_resolve_cascade_specs():
    p = resolve_pruner("ivf+wcd+rwmd", nprobe=3)
    assert isinstance(p, CascadePruner)
    assert p.stages == ("wcd", "rwmd") and p.nprobe == 3
    assert resolve_pruner("ivf").stages == ("wcd", "rwmd")
    assert resolve_pruner("ivf+rwmd").stages == ("rwmd",)
    assert resolve_pruner(p) is p
    with pytest.raises(ValueError):
        resolve_pruner(p, nprobe=7)      # conflicting override
    with pytest.raises(ValueError):
        resolve_pruner("rwmd", nprobe=4)  # nprobe needs a cascade
    with pytest.raises(ValueError):
        CascadePruner(stages=("nope",))


# -------------------------------------------------------- underflow guards
def test_sinkhorn_sparse_underflow_raises(corpus):
    r, vecs_sel, _ = select_support(corpus.queries[0], corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    with pytest.raises(LamUnderflowError, match="underflowed"):
        sinkhorn_wmd_sparse(r, vecs_sel, vecs, corpus.docs, 80.0, 5)
    out = sinkhorn_wmd_sparse(r, vecs_sel, vecs, corpus.docs, 80.0, 5,
                              check_underflow=False)
    assert np.isnan(np.asarray(out)).any()


@pytest.mark.parametrize("vshard", [False, True])
def test_distributed_underflow_raises(corpus, vshard):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r, vecs_sel, _ = select_support(corpus.queries[0], corpus.vecs)
    vecs = jnp.asarray(corpus.vecs)
    with pytest.raises(LamUnderflowError, match="underflowed"):
        sinkhorn_wmd_sparse_distributed(r, vecs_sel, vecs, corpus.docs,
                                        80.0, 5, mesh,
                                        vshard_precompute=vshard)
    out = sinkhorn_wmd_sparse_distributed(r, vecs_sel, vecs, corpus.docs,
                                          80.0, 5, mesh,
                                          vshard_precompute=vshard,
                                          check_underflow=False)
    assert np.isnan(np.asarray(out)).any()
