"""Trajectory-gate plumbing tests (ISSUE 10 satellite) — no jax needed.

The bench-trajectory pipeline silently broke once already: an unanchored
``BENCH_*.json`` gitignore pattern made CI's ``git add`` skip the
per-sha records, so main's trajectory stayed empty and every PR gate
"passed" against a missing baseline. These tests pin the repo-side
pieces: compare.py must say ``SEEDING (no baseline)`` per gated prefix
(readable as "not yet comparable", never as "compared and fine"), and
the ignore pattern must stay root-anchored so committed trajectory
records are trackable.
"""
import json
import subprocess
from pathlib import Path

from benchmarks.compare import compare, load, main as compare_main

REPO = Path(__file__).resolve().parent.parent


def test_seeding_marker_per_prefix_on_empty_baseline(capsys):
    failures = compare({}, {"fig15.p50": 50.0, "fig15.hit_rate": 80.0},
                       max_ratio=1.3, prefixes=["fig15.p50"],
                       min_prefixes=["fig15.hit_rate"])
    out = capsys.readouterr().out
    assert failures == []
    assert "SEEDING (no baseline): gate prefix 'fig15.p50'" in out
    assert "SEEDING (no baseline): gate prefix 'fig15.hit_rate'" in out


def test_seeding_marker_for_newly_added_benchmark_only(capsys):
    """A baseline that predates a new benchmark: the new prefix seeds,
    the established one gates normally (and still fails on regression)."""
    base = {"fig7.cdist": 100.0}
    cur = {"fig7.cdist": 150.0, "fig15.p50": 50.0}
    failures = compare(base, cur, max_ratio=1.3,
                       prefixes=["fig7", "fig15.p50"])
    out = capsys.readouterr().out
    assert "SEEDING (no baseline): gate prefix 'fig15.p50'" in out
    assert "'fig7'" not in out          # established prefix: no marker
    assert failures and "fig7.cdist" in failures[0]


def test_dead_prefix_warns_not_seeds(capsys):
    """No current record at all is a DEAD gate (benchmark didn't run) —
    a different failure mode than awaiting a baseline."""
    compare({}, {"fig15.p50": 50.0}, max_ratio=1.3,
            prefixes=["fig15.p50", "fig99.gone"])
    out = capsys.readouterr().out
    assert "gate prefix 'fig99.gone' matches no current record" in out
    assert "SEEDING (no baseline): gate prefix 'fig99.gone'" not in out
    assert "SEEDING (no baseline): gate prefix 'fig15.p50'" in out


def test_main_passes_and_marks_seeding_on_missing_baseline(tmp_path,
                                                           capsys):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps({"fig15.p50": 50.0, "fig15.hit_rate": 80.0}))
    rc = compare_main(["--baseline", str(tmp_path / "absent.json"),
                       "--current", str(cur)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "seeding run" in out
    assert "SEEDING (no baseline): gate prefix 'fig15.p50'" in out
    assert "SEEDING (no baseline): gate prefix 'fig15.hit_rate'" in out
    assert load(str(tmp_path / "absent.json")) == {}


def test_default_gates_cover_fig15_both_directions(tmp_path, capsys):
    """The CLI defaults must gate fig15.p50 (max direction) and
    fig15.hit_rate (min direction) — ci.yml lists them explicitly, the
    defaults are what ad-hoc local runs get."""
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps({"fig15.p50": 100.0,
                                "fig15.hit_rate": 80.0}))
    cur.write_text(json.dumps({"fig15.p50": 200.0,    # 2x slower
                               "fig15.hit_rate": 40.0}))  # hit rate halved
    rc = compare_main(["--baseline", str(base), "--current", str(cur)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "fig15.p50: 2.00x > 1.30x" in out
    assert "fig15.hit_rate: 0.5000x < 0.9990x" in out


def test_trajectory_records_not_gitignored():
    """The root cause of the empty trajectory: an unanchored
    ``BENCH_*.json`` ignore rule swallowed
    ``benchmarks/trajectory/BENCH_<sha>.json`` during CI's ``git add``.
    Runner outputs at the repo root must stay ignored; committed
    trajectory records must not be."""
    def ignored(path):
        return subprocess.run(
            ["git", "check-ignore", "-q", path], cwd=REPO).returncode == 0

    assert ignored("BENCH_smoke.json")
    assert not ignored("benchmarks/trajectory/BENCH_abc1234.json")
    assert not ignored("benchmarks/trajectory/latest.json")
