"""Property-based oracle layer over rank-then-refine retrieval (ISSUE 8).

In the PR 5 oracle style (``test_properties_search.py``): drive the full
``WmdEngine.search(mode="refine")`` stack — bound ranking, per-query pick
sets, union solve with residual scoping, own-picks rank — and assert the
invariants the mode's contract promises:

- refine == exact at the covering factor (``refine_factor * k >=
  n_docs``): identical retrieved sets AND distances, on both
  :class:`WmdEngine` and a 1-shard :class:`ShardedWmdEngine`;
- recall@k against the exhaustive oracle is monotone non-decreasing in
  ``refine_factor`` (pick sets are nested by construction);
- the bench's ``recall_at_k`` (``benchmarks/common.py`` — what fig13
  records) matches an independent set-based oracle recomputation;
- ``solved`` reports each query's own pick count, bounded by
  ``refine_factor * k``;
- the pivot triangle prestage (``ivf+pivot+...``) is admissible — its
  bound never exceeds the true centroid distance — and leaves exact
  search exact;
- argument validation: refine without a pruner / bad factors / unknown
  modes raise ``ValueError``.

Runs under real ``hypothesis`` when installed, else the deterministic
``tests/_hypothesis_compat.py`` shim (tier-1). Shapes are constant across
examples so each property compiles once.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from benchmarks.common import recall_at_k
from benchmarks.fig8_topk_prune import dedup_corpus
from repro.core import WmdEngine, build_index

K = 5
N_DOCS = 64
PRUNE = "ivf+pivot+wcd+rwmd"


def _mk_engine(seed, lam=1.0):
    corp = dedup_corpus(N_DOCS, vocab=512, embed_dim=16, seed=seed)
    index = build_index(corp.docs, corp.vecs, n_clusters=8)
    return WmdEngine(index, lam=lam, n_iter=12), list(corp.queries), corp


def _cover(n_docs=N_DOCS, k=K):
    return -(-n_docs // k)


def _oracle_recall(res_idx, truth_idx, k):
    """Independent recall recomputation: per-query intersection of the
    plain python id sets, no shared code with benchmarks.common."""
    total = 0
    for qi in range(len(truth_idx)):
        got = {int(i) for i in list(res_idx[qi])[:k]}
        want = {int(i) for i in list(truth_idx[qi])[:k]}
        total += len(got & want)
    return total / (k * len(truth_idx))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refine_equals_exact_at_covering_factor(seed):
    """At ``refine_factor * k >= n_docs`` every query's pick set covers
    the corpus — refine degenerates to the exact path: same ids, same
    distances (the refine path's distances are ALWAYS exact truncated-
    Sinkhorn scores; at covering, membership is exact too)."""
    eng, qs, _ = _mk_engine(seed)
    exact = eng.search(qs, K, prune=PRUNE)
    ref = eng.search(qs, K, prune=PRUNE, mode="refine",
                     refine_factor=_cover())
    for qi in range(len(qs)):
        assert set(ref.indices[qi].tolist()) == \
            set(exact.indices[qi].tolist())
        np.testing.assert_allclose(np.sort(ref.distances[qi]),
                                   np.sort(exact.distances[qi]),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refine_recall_monotone_and_oracle_checked(seed):
    """Recall@k vs the exhaustive oracle is monotone in refine_factor
    (nested pick sets), reaches 1.0 at the covering factor, and the
    bench's ``recall_at_k`` agrees with an independent recomputation at
    every point of the curve (the fig13 records measure what they say)."""
    eng, qs, _ = _mk_engine(seed)
    truth = eng.search(qs, K, prune=None)
    recalls = []
    for rf in (1, 2, 4, _cover()):
        res = eng.search(qs, K, prune=PRUNE, mode="refine",
                         refine_factor=rf)
        r_bench = recall_at_k(res.indices, truth.indices, K)
        r_oracle = _oracle_recall(res.indices, truth.indices, K)
        assert r_bench == pytest.approx(r_oracle, abs=1e-12)
        recalls.append(r_bench)
    assert all(b >= a for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0, recalls


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refine_solved_is_own_pick_count(seed):
    """``solved`` reports the query's OWN rank-selected pick count — at
    most ``refine_factor * k`` (and never more than the corpus)."""
    eng, qs, _ = _mk_engine(seed)
    for rf in (1, 3):
        res = eng.search(qs, K, prune=PRUNE, mode="refine",
                         refine_factor=rf)
        assert (res.solved <= min(rf * K, N_DOCS)).all(), res.solved
        assert (res.solved > 0).all(), res.solved


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pivot_cascade_keeps_exact_search_exact(seed):
    """The pivot triangle prestage is a PRUNE, not an approximation: the
    full cascade with the pivot rung returns the exhaustive result."""
    eng, qs, _ = _mk_engine(seed)
    truth = eng.search(qs, K, prune=None)
    res = eng.search(qs, K, prune=PRUNE)
    for qi in range(len(qs)):
        assert set(res.indices[qi].tolist()) == \
            set(truth.indices[qi].tolist())
        np.testing.assert_allclose(np.sort(res.distances[qi]),
                                   np.sort(truth.distances[qi]),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pivot_bound_admissible(seed):
    """Reverse triangle inequality: ``max_p |d(a,p) - d(b,p)| <= d(a,b)``
    for every (query centroid, doc centroid) pair — the pivot rung's
    bound never exceeds the true centroid distance it stands in for, so
    a threshold that admits the true distance admits the bound."""
    from repro.core.index import _pivot_dists
    eng, qs, _ = _mk_engine(seed)
    index = eng.index
    assert index.pivots is not None and index.doc_pivot_d is not None
    rng = np.random.default_rng(seed)
    qcent = np.asarray(index.centroids)[
        rng.integers(0, index.n_docs, size=3)]
    qd = np.asarray(_pivot_dists(qcent, index.pivots))
    dd = np.asarray(index.doc_pivot_d)
    bound = np.abs(qd[:, None, :] - dd[None, :, :]).max(axis=2)
    true = np.asarray(_pivot_dists(qcent, index.centroids))
    assert (bound <= true + 1e-4).all(), float((bound - true).max())


def test_refine_argument_validation():
    eng, qs, _ = _mk_engine(0)
    with pytest.raises(ValueError, match="refine"):
        eng.search(qs, K, prune=None, mode="refine")
    with pytest.raises(ValueError, match="refine_factor"):
        eng.search(qs, K, prune=PRUNE, mode="refine", refine_factor=0)
    with pytest.raises(ValueError, match="mode"):
        eng.search(qs, K, prune=PRUNE, mode="turbo")


def test_sharded_refine_covering_equals_exact():
    """Acceptance: refine is exact-equivalent at the covering factor on
    the sharded engine too (per-shard refine, merge unchanged) — 1 shard
    in-process, the multidevice suite covers real meshes."""
    from repro.core import ShardedWmdEngine, shard_corpus
    corp = dedup_corpus(N_DOCS, vocab=512, embed_dim=16, seed=3)
    sindex = shard_corpus(corp.docs, corp.vecs, 1, n_clusters=8)
    seng = ShardedWmdEngine(sindex, lam=1.0, n_iter=12)
    qs = list(corp.queries)
    exact = seng.search(qs, K, prune=PRUNE)
    ref = seng.search(qs, K, prune=PRUNE, mode="refine",
                      refine_factor=_cover())
    for qi in range(len(qs)):
        assert set(ref.indices[qi].tolist()) == \
            set(exact.indices[qi].tolist())
        np.testing.assert_allclose(np.sort(ref.distances[qi]),
                                   np.sort(exact.distances[qi]),
                                   rtol=1e-4, atol=1e-5)
