"""Cross-request K-column cache tests (ISSUE 10).

The cache's whole value proposition is "faster, bitwise identical" — so
the oracle here is the uncached engine, compared with ``np.array_equal``
(not allclose) across prune cascades, both precision domains, eviction
pressure, and streaming appends. The Zipfian tests pin the reuse model:
hit rate must rise with traffic skew, because skew is the reason the
cache exists.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import PaddedDocs, WmdEngine, append_docs, build_index
from repro.core.kcache import KCache, _cdist_rows
from repro.data.corpus import make_corpus

LAM = 1.0
N_ITER = 10
VOCAB = 512


@pytest.fixture(scope="module")
def index(small_corpus):
    return build_index(small_corpus.docs, small_corpus.vecs)


def _engine(index, cached, precision="fp32", slots=256, min_hits=1, **kw):
    return WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse",
                     precision=precision,
                     kcache_slots=slots if cached else None,
                     kcache_min_hits=min_hits, **kw)


def _hist(ids, vocab=VOCAB, seed=0):
    """Query histogram with exactly ``ids`` as support."""
    rng = np.random.default_rng(seed)
    q = np.zeros(vocab, np.float32)
    q[np.asarray(ids)] = rng.random(len(ids)).astype(np.float32) + 0.1
    return q / q.sum()


def _assert_same(a, b, ctx=""):
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices)), \
        f"top-k membership differs {ctx}"
    assert np.array_equal(np.asarray(a.distances),
                          np.asarray(b.distances)), \
        f"distances differ {ctx} (bit-exact contract broken)"


# -------------------------------------------------------------- unit level
def test_kcache_rejects_zero_slots(small_corpus, index):
    with pytest.raises(ValueError):
        KCache(index.vecs, index.vecs_sq, 0)


def test_kernel_impl_refuses_cache(index):
    with pytest.raises(ValueError):
        WmdEngine(index, lam=LAM, impl="kernel", kcache_slots=8)
    eng = WmdEngine(index, lam=LAM, impl="kernel")
    # serving's enable-by-default path must be a quiet no-op here
    assert eng.enable_kcache(8) is False
    assert eng.kcache_stats() is None


def test_rows_match_direct_cdist_and_lru_evicts_oldest(rng):
    vecs = jnp.asarray(rng.standard_normal((24, 4)).astype(np.float32))
    vecs_sq = jnp.sum(vecs * vecs, axis=-1)
    cache = KCache(vecs, vecs_sq, slots=4)

    def ref(ids):
        return np.asarray(_cdist_rows(jnp.asarray(np.asarray(ids,
                                                             np.int32)),
                                      vecs, vecs_sq))

    ids = np.asarray([3, 7, 11])
    assert cache.lookup(ids) == 0
    got = np.asarray(cache.rows(ids))[:3]
    assert np.array_equal(got, ref(ids))
    assert cache.stats()["used"] == 3 and cache.inserts == 3

    # fill the last slot, then miss twice: the two least-recently used
    # words (3 and 7 were touched before 20) are the victims
    cache.rows(np.asarray([20]))
    assert cache.stats()["used"] == 4
    cache.rows(np.asarray([1, 2]))
    assert cache.evictions == 2
    assert set(cache._slot_of) == {11, 20, 1, 2}
    # evicted words recompute correctly on re-entry
    back = np.asarray(cache.rows(np.asarray([3])))[:1]
    assert np.array_equal(back, ref([3]))
    st_ = cache.stats()
    assert st_["hits"] == 0 and st_["misses"] == 3 and st_["lookups"] == 1


def test_warm_fills_free_slots_only(rng):
    vecs = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    vecs_sq = jnp.sum(vecs * vecs, axis=-1)
    cache = KCache(vecs, vecs_sq, slots=4)
    cache.rows(np.asarray([0, 1, 2]))          # 3 resident, 1 free

    sup = np.asarray([[5, 6, 7]])              # fallback chunk, 3 cold
    mq = jnp.asarray(np.stack(
        [np.asarray(_cdist_rows(jnp.asarray(sup[0].astype(np.int32)),
                                vecs, vecs_sq)).T]))     # (1, V, 3)
    cache.warm(sup, mq)
    # warming never evicts: only the single free slot was filled
    assert cache.evictions == 0
    assert cache.stats()["used"] == 4
    assert 5 in cache._slot_of
    w = np.asarray(cache.rows(np.asarray([5])))[:1]
    assert np.array_equal(
        w, np.asarray(_cdist_rows(jnp.asarray(np.asarray([5], np.int32)),
                                  vecs, vecs_sq)))


def test_rebind_drops_entries_keeps_counters(rng):
    vecs = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    vecs_sq = jnp.sum(vecs * vecs, axis=-1)
    cache = KCache(vecs, vecs_sq, slots=8)
    cache.lookup(np.asarray([1, 2]))
    cache.rows(np.asarray([1, 2]))
    fresh = cache.rebind(vecs * 2.0, vecs_sq * 4.0)
    assert fresh.stats()["used"] == 0
    assert fresh.misses == 2 and fresh.inserts == 2
    assert fresh.vecs is not cache.vecs


# --------------------------------------------------- engine-level oracle
@settings(max_examples=6, deadline=None)
@given(prune=st.sampled_from(["rwmd", "wcd+rwmd", "ivf+wcd+rwmd"]),
       precision=st.sampled_from(["fp32", "bf16", "log", "bf16+log"]),
       slots=st.sampled_from([32, 128, 512]),
       min_hits=st.integers(min_value=1, max_value=6))
def test_cache_on_equals_cache_off(prune, precision, slots, min_hits):
    """The exactness contract, property-swept: any prune cascade, either
    precision domain, any capacity (including ones small enough to force
    the oversize fallback), any dispatch-economy threshold — cache-on
    search results are BITWISE the cache-off results, cold and warm."""
    corpus = make_corpus(vocab_size=VOCAB, embed_dim=32, n_docs=64,
                         n_queries=3, seed=7)
    index = build_index(corpus.docs, corpus.vecs)
    off = _engine(index, cached=False, precision=precision)
    on = _engine(index, cached=True, precision=precision, slots=slots,
                 min_hits=min_hits)
    queries = list(corpus.queries)
    for pass_ in ("cold", "warm"):
        _assert_same(off.search(queries, 5, prune=prune),
                     on.search(queries, 5, prune=prune),
                     f"({pass_}, {prune}, {precision}, slots={slots}, "
                     f"min_hits={min_hits})")
    stats = on.kcache_stats()
    assert stats["lookups"] > 0


def test_eviction_pressure_keeps_exactness(index):
    """Capacity pressure: a stream whose working set exceeds the slot
    count forces LRU evictions mid-stream — and every answer along the
    way still matches the uncached engine bit for bit."""
    on = _engine(index, cached=True, slots=24, min_hits=1)
    off = _engine(index, cached=False)
    a = _hist(range(40, 52), seed=1)               # 12 words
    b = _hist(list(range(40, 44)) + list(range(200, 216)), seed=2)
    for step, q in enumerate([a, b, a, b]):
        _assert_same(off.search([q], 5, prune="rwmd"),
                     on.search([q], 5, prune="rwmd"), f"(step {step})")
    stats = on.kcache_stats()
    assert stats["evictions"] > 0, stats
    assert stats["hits"] > 0, stats


def test_oversize_chunk_falls_back_exactly(index):
    """A chunk with more unique words than slots can't be cached — it
    must take the one-shot GEMM (counted ``oversize``) and stay exact."""
    on = _engine(index, cached=True, slots=8, min_hits=1)
    off = _engine(index, cached=False)
    q = _hist(range(100, 120), seed=3)             # 20 words > 8 slots
    _assert_same(off.search([q], 5, prune="rwmd"),
                 on.search([q], 5, prune="rwmd"), "(oversize)")
    stats = on.kcache_stats()
    assert stats["oversize"] > 0 and stats["fallbacks"] > 0
    assert stats["used"] <= 8


def test_append_then_search_matches_rebuild_with_warm_cache():
    """``append_docs`` reuses the embedding table by object identity, so
    a WARM cache sails through the append untouched (no rebind, hits keep
    landing) and post-append answers match both the uncached engine on
    the same index (bitwise) and a from-scratch rebuild (numerically)."""
    full = make_corpus(vocab_size=VOCAB, embed_dim=32, n_docs=96,
                       n_queries=4, seed=11)
    head = PaddedDocs(idx=full.docs.idx[:64], val=full.docs.val[:64])
    tail = PaddedDocs(idx=full.docs.idx[64:], val=full.docs.val[64:])
    queries = list(full.queries)

    on = _engine(build_index(head, full.vecs), cached=True, min_hits=1)
    on.search(queries, 5, prune="rwmd")            # warm the cache
    cache_obj = on._kcache
    assert cache_obj.stats()["used"] > 0

    on.index = append_docs(on.index, tail)
    on.reset_kcache_stats()
    appended = on.search(queries, 5, prune="rwmd")
    assert on._kcache is cache_obj                 # no rebind on append
    assert on.kcache_stats()["hits"] > 0           # warm rows survived

    off = _engine(on.index, cached=False)
    _assert_same(off.search(queries, 5, prune="rwmd"), appended,
                 "(post-append)")
    rebuilt = _engine(build_index(full.docs, full.vecs),
                      cached=False).search(queries, 5, prune="rwmd")
    for qi in range(len(queries)):
        assert set(np.asarray(appended.indices[qi]).tolist()) == \
            set(np.asarray(rebuilt.indices[qi]).tolist())
        np.testing.assert_allclose(np.asarray(appended.distances[qi]),
                                   np.asarray(rebuilt.distances[qi]),
                                   rtol=1e-5, atol=1e-6)


def test_swapped_index_rebinds_cache(small_corpus, index):
    """A DIFFERENT embedding table object (rebuilt index, reloaded
    snapshot) invalidates every resident row: the engine swaps in a
    fresh cache on its next staged chunk, results stay correct."""
    on = _engine(index, cached=True, min_hits=1)
    queries = list(small_corpus.queries)
    on.search(queries, 5, prune="rwmd")
    old = on._kcache
    assert old.stats()["used"] > 0

    on.index = build_index(small_corpus.docs, small_corpus.vecs)
    res = on.search(queries, 5, prune="rwmd")
    assert on._kcache is not old                   # rebound
    off = _engine(on.index, cached=False)
    _assert_same(off.search(queries, 5, prune="rwmd"), res, "(rebound)")


def test_zipf_hit_rate_monotone_in_skew(index):
    """The reuse model itself: hit rate must RISE with traffic skew
    (seeded streams; s=0 is uniform — the cache's worst case)."""
    from benchmarks.fig15_kcache import zipf_queries
    rates = []
    for s in (0.0, 0.8, 1.6):
        eng = _engine(index, cached=True, slots=64, min_hits=1)
        stream = zipf_queries(24, VOCAB, words=10, s=s, seed=5)
        for i in range(0, len(stream), 4):
            eng.search(stream[i:i + 4], 5, prune="rwmd")
        rates.append(eng.kcache_stats()["hit_rate"])
    assert rates == sorted(rates), rates
    assert rates[-1] > rates[0], rates
