"""Serving-runtime tests (ISSUE 6): coalescer deadline-or-full dispatch,
backpressure, degradation-tier ordering, per-request poison isolation,
lam-underflow structured errors, injector seed-determinism, and the
RWMD degraded tier's admissibility.

All async paths run through ``asyncio.run`` inside sync tests (no
pytest-asyncio in the image). Timing assertions stay loose — this box is
2 vCPUs and shared."""
import asyncio
import time

import numpy as np
import pytest

from repro.core.index import WmdEngine, build_index
from repro.runtime.serving import (FaultInjector, ServeConfig, ServeRequest,
                                   ServingRuntime, default_tiers,
                                   poisson_arrivals, run_open_loop,
                                   rwmd_topk)

LAM = 1.0
N_ITER = 10


@pytest.fixture(scope="module")
def engine(small_corpus):
    index = build_index(small_corpus.docs, small_corpus.vecs)
    return WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse")


@pytest.fixture(scope="module")
def queries(small_corpus):
    return list(small_corpus.queries)


def _cfg(**kw):
    base = dict(max_batch=2, window_s=0.02, max_queue=64, deadline_s=None,
                backoff_s=0.001, prune="ivf+wcd+rwmd")
    base.update(kw)
    return ServeConfig(**base)


def _serve(engine, reqs, cfg=None, injector=None, k=5, deadline_s=...):
    """Submit all requests in one loop tick, gather every future."""
    rt = ServingRuntime(engine, cfg or _cfg(), injector=injector)

    async def go():
        await rt.start()
        futs = [rt.submit(q, k=k, deadline_s=deadline_s) for q in reqs]
        out = await asyncio.gather(*futs)
        await rt.stop()
        return list(out)

    return asyncio.run(go()), rt


# ------------------------------------------------------------- coalescer
def test_full_batch_dispatches_immediately(engine, queries):
    """max_batch requests in one bucket dispatch WITHOUT waiting out the
    window (the FULL half of deadline-or-full)."""
    cfg = _cfg(max_batch=2, window_s=30.0)     # window absurdly long
    t0 = time.monotonic()
    resps, _ = _serve(engine, [queries[0], queries[0]], cfg)
    assert time.monotonic() - t0 < 20.0        # did not wait the window
    assert all(r.ok for r in resps)
    assert {r.batch_size for r in resps} == {2}
    assert resps[0].dispatch_id == resps[1].dispatch_id


def test_partial_batch_flushes_at_window(engine, queries):
    """A lone request dispatches once its window expires (the DEADLINE
    half): latency stays bounded at low offered load."""
    cfg = _cfg(max_batch=8, window_s=0.02)
    resps, _ = _serve(engine, [queries[0]], cfg)
    assert resps[0].ok and resps[0].batch_size == 1


def test_buckets_never_share_a_dispatch(engine, queries):
    """Distinct pow2 v_r buckets coalesce separately — one dispatch is
    one compiled chunk shape."""
    small = np.zeros_like(queries[0])
    nz = np.flatnonzero(queries[0])[:3]
    small[nz] = 1.0 / len(nz)                  # v_r=3 -> bucket 8
    big = queries[1]                           # corpus query: v_r >> 8
    assert int((big > 0).sum()) > 8
    cfg = _cfg(max_batch=2, window_s=0.02)
    resps, _ = _serve(engine, [small, big, small, big], cfg)
    assert all(r.ok for r in resps)
    assert resps[0].dispatch_id == resps[2].dispatch_id
    assert resps[1].dispatch_id == resps[3].dispatch_id
    assert resps[0].dispatch_id != resps[1].dispatch_id


def test_empty_query_structured_error(engine, queries):
    resps, _ = _serve(engine, [np.zeros_like(queries[0])])
    assert not resps[0].ok
    assert resps[0].error["code"] == "empty_query"


# ----------------------------------------------------------- backpressure
def test_backpressure_rejects_structured(engine, queries):
    """Arrivals beyond max_queue get an immediate structured rejection
    (no silent drop, no exception), and depth drains back to zero."""
    cfg = _cfg(max_batch=1, window_s=0.001, max_queue=1)
    resps, rt = _serve(engine, [queries[0]] * 4, cfg)
    codes = [None if r.ok else r.error["code"] for r in resps]
    assert codes[0] is None                    # first admitted
    assert codes.count("rejected_overload") >= 1
    assert "retry after" in next(r for r in resps if not r.ok
                                 ).error["message"]
    assert rt._depth == 0                      # drained after stop
    assert rt.counters["rejected"] >= 1
    assert rt.counters["submitted"] == 4


# ------------------------------------------------------------ degradation
def test_tier_ladder_shape(engine):
    tiers = default_tiers(engine, "ivf+wcd+rwmd")
    assert [t.name for t in tiers] == \
        ["exact", "reduced_nprobe", "refine", "rwmd"]
    assert tiers[0].nprobe is None and tiers[0].solve
    assert tiers[0].mode == "exact"
    assert tiers[1].nprobe < engine.index.clusters.n_clusters
    assert tiers[2].solve and tiers[2].mode == "refine"
    assert tiers[2].refine_factor >= 1
    assert not tiers[3].solve
    # non-IVF prune: no nprobe knob, ladder skips the reduced rung
    assert [t.name for t in default_tiers(engine, "rwmd")] == \
        ["exact", "refine", "rwmd"]
    # caveats name their semantics (they ship in every response)
    assert "exact" in tiers[0].caveat
    assert "recall" in tiers[2].caveat and "fig13" in tiers[2].caveat
    assert "lower bound" in tiers[3].caveat


def test_refine_tier_response_caveat_and_distances(engine, queries):
    """A dispatch served at the refine tier tags its responses with the
    measured-recall caveat, is NOT marked exact, and returns distances
    matching the engine's own mode='refine' search (exact truncated-
    Sinkhorn scores over the bound-ranked candidate set)."""
    rt = ServingRuntime(engine, _cfg())
    refine_i = next(i for i, t in enumerate(rt.tiers)
                    if t.name == "refine")
    tier = rt.tiers[refine_i]
    req = ServeRequest(rid=0, query=queries[0], k=5, deadline=None,
                       enqueue_t=time.monotonic(),
                       v_r=int((queries[0] > 0).sum()))
    out = rt._score([req], tier)
    r = out[0]
    assert r.ok and r.tier == "refine" and not r.exact
    assert "recall" in r.caveat and "fig13" in r.caveat
    res = engine.search([queries[0]], 5, prune=rt.cfg.prune,
                        mode="refine",
                        refine_factor=tier.refine_factor)
    assert r.indices == np.asarray(res.indices[0]).tolist()
    np.testing.assert_allclose(r.distances,
                               np.asarray(res.distances[0]),
                               rtol=1e-4, atol=1e-5)
    assert r.to_json()["caveat"] == tier.caveat


def test_choose_tier_orders_by_queue_depth(engine):
    """Deeper queue -> lower tier, monotonically (the load-shedding
    watermarks), independent of deadlines."""
    rt = ServingRuntime(engine, _cfg(max_queue=10,
                                     degrade_depth=(0.5, 0.8)))
    req = ServeRequest(rid=0, query=None, k=5, deadline=None,
                       enqueue_t=0.0, v_r=4)
    picks = []
    for depth in (0, 4, 5, 7, 8, 9):
        rt._depth = depth
        picks.append(rt._choose_tier([req], now=0.0))
    assert picks == sorted(picks)              # monotone degradation
    assert picks[0] == 0                       # idle -> exact
    assert picks[-1] == 2                      # saturated -> cheapest


def test_blown_deadline_serves_cheapest_tier(engine, queries):
    """A request whose budget is already spent degrades to the cheapest
    tier instead of being dropped — and is tagged deadline_missed."""
    resps, _ = _serve(engine, [queries[0]], deadline_s=0.0)
    r = resps[0]
    assert r.ok                                # degraded, NOT dropped
    assert r.tier == "rwmd" and not r.exact
    assert r.deadline_missed
    assert "lower bound" in r.caveat


def test_overload_engages_degradation(engine, queries):
    """Open-loop overload: every request resolves and degraded tiers
    absorb the excess (degrade-don't-drop end to end)."""
    rt = ServingRuntime(engine, _cfg(max_batch=2, window_s=0.005,
                                     max_queue=6, deadline_s=5.0,
                                     degrade_depth=(0.3, 0.6)))
    n = 16
    reqs = [queries[i % len(queries)] for i in range(n)]
    resps, stats = run_open_loop(rt, reqs, poisson_arrivals(
        n, rate_per_s=500.0, seed=2), k=5)
    assert len(resps) == n
    assert all(r.ok or r.error is not None for r in resps)
    served = [r for r in resps if r.ok]
    assert any(r.tier != "exact" for r in served), stats["tiers"]
    assert stats["degraded_frac"] > 0


# ------------------------------------------------- fault injection paths
def test_poison_isolated_batchmates_answered(engine, queries):
    """A poisoned request inside a coalesced batch gets a structured
    error; its batchmates still get ranked results (per-request
    isolation, the satellite-(a) contract)."""
    probe = FaultInjector(poison_rate=0.3, seed=18)
    rids = list(range(4))
    poisoned = {r for r in rids if probe.poison(r)}
    assert poisoned and set(rids) - poisoned   # seed chosen: mixed batch
    inj = FaultInjector(poison_rate=0.3, seed=18)
    cfg = _cfg(max_batch=4, window_s=0.02)
    resps, rt = _serve(engine, [queries[0]] * 4, cfg, injector=inj)
    for r in resps:
        if r.rid in poisoned:
            assert not r.ok and r.error["code"] == "poison"
        else:
            assert r.ok and len(r.indices) == 5
    assert rt.counters["isolations"] >= 1


def test_lam_underflow_structured_diagnostics(small_corpus, queries):
    """A lam that underflows fp32 K yields per-request lam_underflow
    errors with the underflow_report diagnostics attached — the server
    answers, it does not crash (and precision='log' is the documented
    fix, so the message must say so)."""
    index = build_index(small_corpus.docs, small_corpus.vecs)
    hot = WmdEngine(index, lam=50.0, n_iter=5, impl="sparse")
    resps, _ = _serve(hot, [queries[0], queries[1]])
    for r in resps:
        assert not r.ok
        assert r.error["code"] == "lam_underflow"
        assert "precision" in r.error["message"]
        assert r.error["diagnostics"]          # underflow_report text


def test_transient_faults_retried_to_success(engine, queries):
    """transient_attempts=1 (default): only first attempts can fault, so
    the retry path recovers every dispatch."""
    inj = FaultInjector(transient_rate=1.0, seed=3)
    resps, rt = _serve(engine, [queries[0]], injector=inj)
    assert resps[0].ok
    assert rt.guard.retries >= 1
    assert ("transient", 0, 0) in inj.trace


def test_retry_exhaustion_structured_error(engine, queries):
    """Faults on EVERY attempt exhaust the budget into a structured
    retries_exhausted error — never an unhandled exception."""
    inj = FaultInjector(transient_rate=1.0, transient_attempts=99, seed=3)
    cfg = _cfg(max_retries=1)
    resps, rt = _serve(engine, [queries[0]], cfg, injector=inj)
    assert not resps[0].ok
    assert resps[0].error["code"] == "retries_exhausted"
    assert "2 attempts" in resps[0].error["message"]


def test_injector_replays_identically_from_seed(engine, queries):
    """The chaos layer is deterministic: same seed -> identical decision
    trace and identical per-request outcomes; a different seed diverges
    somewhere (rates chosen to make that overwhelming)."""
    def drill(seed):
        inj = FaultInjector(latency_rate=0.3, latency_s=0.001,
                            transient_rate=0.5, poison_rate=0.3,
                            seed=seed)
        resps, _ = _serve(engine, [queries[i % 3] for i in range(6)],
                          _cfg(max_batch=2), injector=inj)
        outcome = [(r.rid, r.ok, None if r.ok else r.error["code"])
                   for r in resps]
        return sorted(inj.trace), outcome

    t1, o1 = drill(5)
    t2, o2 = drill(5)
    assert t1 == t2 and o1 == o2
    t3, _ = drill(6)
    assert t1 != t3


def test_injector_draws_order_independent():
    """Injection decisions are pure functions of (seed, site) — calling
    order cannot change them (the property the replay test rests on)."""
    a = FaultInjector(poison_rate=0.5, seed=9)
    fwd = [a.poison(r) for r in range(8)]
    b = FaultInjector(poison_rate=0.5, seed=9)
    rev = [b.poison(r) for r in reversed(range(8))]
    assert fwd == rev[::-1]


# ------------------------------------------------------- degraded scoring
def test_rwmd_topk_admissible_and_shaped(engine, queries):
    """The degraded tier's reported values are true lower bounds on the
    engine's exact WMD (LC-RWMD admissibility), shaped like search()."""
    k = 8
    idx, bounds = rwmd_topk(engine, queries, k)
    assert idx.shape == (len(queries), k) == bounds.shape
    exact = np.asarray(engine.query_batch(queries))
    for qi in range(len(queries)):
        assert bounds[qi, 0] <= bounds[qi, -1] + 1e-6   # sorted ascending
        for j in range(k):
            assert bounds[qi, j] <= exact[qi, idx[qi, j]] + 1e-4


def test_rwmd_tier_response_tagged_not_exact(engine, queries):
    rt = ServingRuntime(engine, _cfg())
    tiers = rt.tiers

    async def go():
        await rt.start()
        f = rt.submit(queries[0], k=5, deadline_s=0.0)  # -> cheapest
        out = await f
        await rt.stop()
        return out

    r = asyncio.run(go())
    assert r.tier == tiers[-1].name and not r.exact
    j = r.to_json()
    assert j["tier"] == "rwmd" and j["exact"] is False
    assert "caveat" in j


# --------------------------------------------------------- observability
def test_iter_stats_ring_drop_counter(small_corpus, queries):
    """A saturated iteration-stats ring counts what it discards instead
    of silently windowing (the satellite-(c) observable)."""
    index = build_index(small_corpus.docs, small_corpus.vecs)
    eng = WmdEngine(index, lam=LAM, n_iter=5, impl="sparse",
                    iter_stats_maxlen=2)
    assert eng.iter_stats_dropped == 0
    eng.query_batch(queries)        # 4 doc groups -> > 2 records
    assert eng.iter_stats_dropped > 0
    eng.reset_iter_stats()
    assert eng.iter_stats_dropped == 0


def test_responses_carry_observability(engine, queries):
    resps, rt = _serve(engine, [queries[0], queries[0]],
                       _cfg(max_batch=2))
    r = resps[0]
    assert r.ok and r.service_ms > 0 and r.batch_size == 2
    assert r.solve_iters            # per-stage realized iterations
    stats = rt.stats()
    for key in ("dispatches", "retries", "watchdog_trips",
                "iter_stats_dropped", "degraded_frac", "tier_ema_s"):
        assert key in stats
    assert stats["dispatches"] >= 1
    assert stats["tier_ema_s"]      # EMA recorded for the served tier


# -------------------------------------------- admission validation (ISSUE 10)
def test_nan_query_rejected_batchmates_unaffected(engine, queries):
    """A NaN-weight histogram resolves to a structured ``invalid_query``
    at ADMISSION — it never reaches the worker thread, never burns a
    dispatch, and its batchmate (same coalescer window) is served
    normally."""
    bad = queries[0].copy()
    bad[np.flatnonzero(bad)[0]] = np.nan
    resps, rt = _serve(engine, [bad, queries[1]], _cfg(max_batch=2))
    assert not resps[0].ok
    assert resps[0].error["code"] == "invalid_query"
    assert "finite" in resps[0].error["message"]
    assert resps[1].ok and len(resps[1].indices) == 5
    assert resps[1].batch_size == 1            # bad one never coalesced
    assert rt.counters["invalid_query"] == 1
    assert rt.counters["isolations"] == 0      # not the poison path


def test_2d_query_rejected_before_dispatch(engine, queries):
    resps, rt = _serve(engine, [np.stack([queries[0], queries[0]])])
    assert not resps[0].ok
    assert resps[0].error["code"] == "invalid_query"
    assert "1-D" in resps[0].error["message"]
    assert rt.counters["dispatches"] == 0      # nothing reached the worker
    assert rt.counters["invalid_query"] == 1


def test_nonnumeric_and_ragged_queries_rejected(engine, queries):
    """Object-dtype and not-even-array-like inputs both land in the same
    structured code instead of exploding inside the worker."""
    obj = np.asarray([None] * queries[0].size, dtype=object)
    ragged = [[1.0, 2.0], [3.0]]               # np.asarray raises on this
    resps, rt = _serve(engine, [obj, ragged])
    for r in resps:
        assert not r.ok and r.error["code"] == "invalid_query"
    assert rt.counters["invalid_query"] == 2
    assert rt.counters["dispatches"] == 0


def test_inf_query_rejected(engine, queries):
    bad = queries[0].copy()
    bad[np.flatnonzero(bad)[0]] = np.inf
    resps, _ = _serve(engine, [bad])
    assert not resps[0].ok
    assert resps[0].error["code"] == "invalid_query"


# ---------------------------------------- backpressure hint (ISSUE 10 fix)
def test_retry_after_uses_currently_degraded_tiers_ema(engine, queries):
    """The ``rejected_overload`` hint must quote the service-time EMA of
    the tier the watermarks would serve at the CURRENT depth — under
    sustained overload that is a degraded tier; quoting tier 0's stale
    EMA (the old bug) tells callers to back off ~100x too long."""
    cfg = _cfg(max_queue=10, degrade_depth=(0.5, 0.8))
    rt = ServingRuntime(engine, cfg)
    rt._ema.record(0, 5.0)                     # stale exact-tier EMA
    rt._ema.record(2, 0.05)                    # fresh degraded-tier EMA
    rt._depth = cfg.max_queue                  # saturated -> watermark tier 2
    assert rt._depth_tier() == 2
    assert abs(rt._retry_after() - 0.05) < 1e-12

    async def go():
        await rt.start()
        r = await rt.submit(queries[0], k=5)
        rt._depth = 0                          # undo the forced saturation
        await rt.stop()
        return r

    r = asyncio.run(go())
    assert not r.ok and r.error["code"] == "rejected_overload"
    assert r.error["retry_after_s"] == round(0.05 + cfg.window_s, 4)


def test_retry_after_falls_back_across_tiers(engine):
    """No EMA at the watermark tier: the hint walks cheaper tiers first
    (those are the ones overload actually exercises), then back up
    toward exact; with no measurements at all it reports 0."""
    cfg = _cfg(max_queue=10, degrade_depth=(0.5, 0.8))
    rt = ServingRuntime(engine, cfg)
    rt._depth = cfg.max_queue
    assert rt._retry_after() == 0.0
    rt._ema.record(0, 5.0)                     # only exact measured
    assert rt._retry_after() == pytest.approx(5.0)
    rt._ema.record(3, 0.01)                    # cheaper tier measured
    assert rt._retry_after() == pytest.approx(0.01)  # beats tier 0


# -------------------------------------------- kcache observability (ISSUE 10)
def test_runtime_enables_kcache_by_default(small_corpus, queries):
    """Serving is where Zipfian reuse lives, so the runtime switches the
    engine's cross-request cache on by default; stats and per-response
    deltas expose it."""
    index = build_index(small_corpus.docs, small_corpus.vecs)
    eng = WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse")
    assert eng.kcache_stats() is None
    resps, rt = _serve(eng, [queries[0], queries[0]], _cfg(max_batch=2))
    assert eng.kcache_stats() is not None      # enabled by the runtime
    assert all(r.ok for r in resps)
    for r in resps:
        assert r.kcache is not None            # per-dispatch delta
        assert set(r.kcache) == {"hits", "misses", "hit_rate"}
        assert r.to_json()["kcache"] == r.kcache
    stats = rt.stats()
    assert stats["kcache"]["lookups"] > 0
    assert "invalid_query" in stats


def test_runtime_kcache_opt_out_and_respects_existing(small_corpus,
                                                      queries):
    index = build_index(small_corpus.docs, small_corpus.vecs)
    eng = WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse")
    _serve(eng, [queries[0]], _cfg(kcache_slots=0))
    assert eng.kcache_stats() is None          # 0 disables the default
    pre = WmdEngine(index, lam=LAM, n_iter=N_ITER, impl="sparse",
                    kcache_slots=64)
    cache_obj = pre._kcache
    _serve(pre, [queries[0]], _cfg(kcache_slots=512))
    assert pre._kcache is cache_obj            # existing cache kept
    assert pre.kcache_stats()["slots"] == 64


# ----------------------------------------------------- graceful shutdown
def test_graceful_shutdown_drains_and_rejects(engine, queries):
    """``request_shutdown()`` (the SIGTERM/SIGINT path): already-admitted
    requests drain to real answers; requests arriving after the flag get
    a structured ``shutting_down`` rejection — nothing hangs, nothing is
    silently dropped (ISSUE 9 satellite)."""
    rt = ServingRuntime(engine, _cfg(max_batch=2, window_s=0.01))

    async def go():
        await rt.start()
        before = [rt.submit(q, k=5) for q in [queries[0], queries[0]]]
        rt.request_shutdown()
        assert rt.closing
        rt.request_shutdown()               # idempotent
        after = rt.submit(queries[1], k=5)
        out = await asyncio.gather(*before, after)
        await rt.stop()
        return list(out)

    resps = asyncio.run(go())
    assert all(r.ok for r in resps[:2])     # admitted work still answered
    late = resps[2]
    assert not late.ok and late.error["code"] == "shutting_down"
    stats = rt.stats()
    assert stats["shutdown_rejected"] == 1
