"""Dry-run machinery tests (subprocess: needs >1 fake device).

The full 512-device sweep runs via ``python -m repro.launch.dryrun --all``
(results under experiments/dryrun/); here we verify the machinery end to
end on a small mesh quickly + the analysis utilities on CPU."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.runtime import sharding as SH
    from repro.runtime.analysis import hlo_collective_bytes, jaxpr_cost

    mesh = jax.make_mesh((2, 16), ("data", "model"))
    SH.set_axis_sizes(mesh)
    cfg = get_config("granite_3_2b")
    ap = M.abstract_params(cfg, tp=16, dtype=jnp.bfloat16)
    pspecs = SH.param_specs(ap)
    batch = M.train_input_specs(cfg, 4, 512)
    step = M.make_train_step(cfg, tp=16)
    with mesh:
        jstep = jax.jit(step, in_shardings=(
            SH.shardings(mesh, pspecs),
            SH.shardings(mesh, SH.opt_state_specs(pspecs)),
            {k: NamedSharding(mesh, SH.batch_spec(mesh)) for k in batch}))
        compiled = jstep.lower(ap, M.abstract_opt_state(ap), batch).compile()

    cost = jaxpr_cost(step, ap, M.abstract_opt_state(ap), batch)
    coll = hlo_collective_bytes(compiled.as_text())
    # model flops lower-bound: 6*N*D must be <= counted flops (remat adds)
    model_flops = 6 * cfg.n_params() * 4 * 512
    assert cost["flops"] > model_flops * 0.8, (cost["flops"], model_flops)
    assert cost["flops"] < model_flops * 4.0
    # TP activation psums must appear, scaled by the 40-layer scan
    assert coll["total_bytes"] > 0
    assert coll["counts"].get("all-reduce", 0) >= 40
    print("DRYRUN_UNIT_OK", json.dumps({k: coll["counts"][k] for k in coll["counts"]}))
""")


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
                         capture_output=True, text=True, timeout=900)
    assert "DRYRUN_UNIT_OK" in res.stdout, res.stdout + res.stderr


def test_cell_applicability_rules():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.configs.base import ARCH_IDS, get_config
    subquad = {a for a in ARCH_IDS if get_config(a).sub_quadratic}
    assert subquad == {"zamba2_7b", "rwkv6_3b"}


def test_sweep_results_if_present():
    """Validate whatever the full sweep has produced so far: every non-skip
    JSON must have compile_s, roofline terms, and collective accounting.

    The skip condition is the actual capability probe — the presence of
    sweep artifacts on disk — so a box that HAS run the sweep validates
    them instead of silently skipping, and the reason names the exact
    command that makes this test run (ISSUE 5 hygiene fix)."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    produced = ([name for name in os.listdir(d) if name.endswith(".json")]
                if os.path.isdir(d) else [])
    if not produced:
        pytest.skip(f"no sweep artifacts under {d} — run "
                    f"`python -m repro.launch.dryrun --all` to produce "
                    f"them, then this test validates every cell")
    n = 0
    for name in produced:
        with open(os.path.join(d, name)) as f:
            cell = json.load(f)
        if cell.get("skipped"):
            assert "sub-quadratic" in cell["skipped"]
            continue
        assert cell["compile_s"] > 0, name
        assert cell["roofline"]["dominant"] in ("compute", "memory",
                                                "collective"), name
        assert cell["jaxpr_cost"]["flops"] > 0, name
        n += 1
    assert n > 0
