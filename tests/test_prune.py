"""Staged retrieval pipeline (ISSUE 2): bound admissibility, pruned-vs-
exhaustive top-k equality, candidate-subset solves, streaming appends, and
the lam-underflow guard.

The admissibility chain (Kusner et al. §4.3, corrected for what our solver
actually returns): WCD <= RWMD <= exact EMD (the LP oracle) <= the
truncated-Sinkhorn score ``<P, M>`` — the Sinkhorn plan is (column-)
feasible, so its transport cost can only exceed the LP optimum; the
entropic term is not part of the returned distance.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (LamUnderflowError, MaxPruner, RwmdPruner,
                        SearchResult, WcdPruner, WmdEngine, append_docs,
                        build_index, one_to_many, resolve_pruner,
                        select_support)
from repro.core.exact_ot import exact_emd
from repro.core.prune import _min_cdist_xla
from repro.core.sinkhorn import cdist
from repro.core.sparse import PaddedDocs
from repro.data.corpus import make_corpus
from repro.kernels import ops
from repro.kernels.ref import rwmd_min_cdist_ref


@pytest.fixture(scope="module")
def corpus():
    # mixed v_r across buckets; embed/lam chosen so lam*dist stays < 87
    return make_corpus(vocab_size=512, embed_dim=16, n_docs=96, n_queries=8,
                       words_per_doc=(3, 60), seed=11)


@pytest.fixture(scope="module")
def engine(corpus):
    return WmdEngine(build_index(corpus.docs, corpus.vecs), lam=8.0,
                     n_iter=15)


def _bounds(engine, queries):
    """(wcd, rwmd) lower bounds via the engine's own staging, mapped from
    the index's cluster-major STORAGE doc order back to caller order (the
    order engine.query_batch scores are in)."""
    _, chunks = engine._plan(queries)
    n = engine.index.n_docs
    ext = engine.index.ext_ids
    wcd = np.zeros((len(queries), n))
    rwmd = np.zeros((len(queries), n))
    for chunk, width in chunks:
        sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk],
                                          width)
        w = np.asarray(WcdPruner().lower_bounds(engine.index, sup, r, mask))
        rw = np.asarray(RwmdPruner().lower_bounds(engine.index, sup, r,
                                                  mask))
        wcd[np.ix_(chunk, ext)] = w[:len(chunk)]
        rwmd[np.ix_(chunk, ext)] = rw[:len(chunk)]
    return wcd, rwmd


# ------------------------------------------------------------- admissibility
def test_bounds_below_engine_scores(corpus, engine):
    """WCD and doc-side RWMD lower-bound the engine's computed Sinkhorn
    score for every (query, doc) pair — the property exact top-k rests on."""
    queries = list(corpus.queries)
    scores = np.asarray(engine.query_batch(queries))
    wcd, rwmd = _bounds(engine, queries)
    assert (rwmd <= scores + 1e-4).all(), float((rwmd - scores).max())
    assert (wcd <= scores + 1e-4).all(), float((wcd - scores).max())


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_bound_chain_vs_exact_lp(seed):
    """{WCD, RWMD(sym)} <= exact LP ~<= converged Sinkhorn, per doc.

    The first inequalities are exact (WCD by Jensen, RWMD as a constraint
    relaxation of the LP). Two deliberate deviations from the naive chain:
    (a) WCD <= RWMD is NOT asserted — it is empirically typical (Kusner et
    al.) but not a theorem, and random corpora do produce counterexamples;
    both bounds are individually admissible, which is all MaxPruner needs.
    (b) LP <= Sinkhorn holds only up to the truncated iteration's
    query-marginal residual — the Sinkhorn plan satisfies the doc marginal
    exactly but the query marginal approximately, so its cost can undercut
    the LP optimum by O(residual * distance scale); hence the looser
    tolerance (and hence the engine prunes with the doc-side RWMD, which
    bounds the computed score itself — see
    test_bounds_below_engine_scores)."""
    corp = make_corpus(vocab_size=128, embed_dim=8, n_docs=6, n_queries=1,
                       words_per_doc=(4, 12), seed=seed)
    q = corp.queries[0]
    r, vecs_sel, _ = select_support(q, corp.vecs)
    r = np.asarray(r, np.float64)
    sink = np.asarray(one_to_many(q, corp.docs, corp.vecs, lam=12.0,
                                  n_iter=400, impl="sparse"), np.float64)
    idx = np.asarray(corp.docs.idx)
    val = np.asarray(corp.docs.val)
    vecs = np.asarray(corp.vecs)
    qc = r @ np.asarray(vecs_sel)
    for j in range(6):
        live = val[j] > 0
        c = val[j][live].astype(np.float64)
        c = c / c.sum()
        m = np.asarray(cdist(vecs_sel, jnp.asarray(vecs[idx[j][live]])),
                       np.float64)
        lp = exact_emd(r, c, m)
        wcd = float(np.linalg.norm(qc - c @ vecs[idx[j][live]]))
        rwmd = max(float(r @ m.min(axis=1)), float(c @ m.min(axis=0)))
        assert wcd <= lp + 1e-5, (wcd, lp)
        assert rwmd <= lp + 1e-5, (rwmd, lp)
        assert lp <= sink[j] * 1.05 + 0.05, (lp, sink[j])


# ----------------------------------------------------------- rwmd min-cdist
def test_rwmd_min_cdist_kernel_matches_ref(rng):
    a = jnp.asarray(rng.standard_normal((3, 12, 40)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((300, 40)).astype(np.float32))
    mask = jnp.asarray((rng.random((3, 12)) > 0.3).astype(np.float32))
    mask = mask.at[:, 0].set(1.0)             # every query has support
    want = rwmd_min_cdist_ref(a, mask, b)
    got = ops.rwmd_min_cdist(a, mask, b, block_v=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got_xla = _min_cdist_xla(a, mask, b)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------- search
@pytest.mark.parametrize("prune", ["wcd", "rwmd", "wcd+rwmd"])
@pytest.mark.parametrize("k", [1, 5])
def test_pruned_topk_equals_exhaustive(corpus, engine, prune, k):
    queries = list(corpus.queries)
    ex = engine.search(queries, k, prune=None)
    pr = engine.search(queries, k, prune=prune)
    for qi in range(len(queries)):
        assert set(ex.indices[qi]) == set(pr.indices[qi]), (prune, k, qi)
        np.testing.assert_allclose(np.sort(pr.distances[qi]),
                                   np.sort(ex.distances[qi]),
                                   rtol=1e-4, atol=1e-5)


def test_search_prune_none_is_query_batch_argsort(corpus, engine):
    """The prune=None path must reproduce exhaustive scoring bit-for-bit."""
    queries = list(corpus.queries[:4])
    d = np.asarray(engine.query_batch(queries))
    res = engine.search(queries, 7, prune=None)
    order = np.argsort(d, axis=1, kind="stable")[:, :7]
    np.testing.assert_array_equal(res.indices, order.astype(np.int32))
    np.testing.assert_array_equal(res.distances,
                                  np.take_along_axis(d, order, 1))
    assert (res.solved == corpus.docs.n_docs).all()


def test_search_solves_strict_subset_on_separable_corpus():
    """On a corpus with genuine near-duplicates the prune stage must
    exclude most docs (the fig8 contract), not just stay correct."""
    from benchmarks.fig8_topk_prune import dedup_corpus
    corpus = dedup_corpus(256, vocab=1024, embed_dim=32, seed=5)
    eng = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=2.0,
                    n_iter=15)
    queries = list(corpus.queries)
    ex = eng.search(queries, 8, prune=None)
    pr = eng.search(queries, 8, prune="rwmd")
    for qi in range(len(queries)):
        assert set(ex.indices[qi]) == set(pr.indices[qi])
    assert (pr.solved < 128).all(), pr.solved     # < half the corpus

def test_search_kernel_impl_matches_sparse(corpus):
    qs = list(corpus.queries[:3])
    es = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=6.0, n_iter=8,
                   impl="sparse")
    ek = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=6.0, n_iter=8,
                   impl="kernel")
    rs = es.search(qs, 4, prune="rwmd")
    rk = ek.search(qs, 4, prune="rwmd")
    np.testing.assert_array_equal(rs.indices, rk.indices)
    np.testing.assert_allclose(rs.distances, rk.distances,
                               rtol=5e-4, atol=5e-4)


def test_search_empty_and_edge_queries(corpus, engine):
    n = corpus.docs.n_docs
    empty = np.zeros(corpus.vecs.shape[0], np.float32)
    res = engine.search([corpus.queries[0], empty], 3)
    assert (res.indices[1] == -1).all() and np.isnan(res.distances[1]).all()
    assert res.solved[1] == 0
    ex = engine.search([corpus.queries[0], empty], 3, prune=None)
    np.testing.assert_array_equal(res.indices, ex.indices)
    # k >= n degrades to a full (sorted) scoring
    big = engine.search([corpus.queries[0]], n + 10)
    assert big.indices.shape == (1, n)
    with pytest.raises(ValueError):
        engine.search([corpus.queries[0]], 0)
    empty_res = engine.search([], 3)
    assert isinstance(empty_res, SearchResult)
    assert empty_res.indices.shape == (0, 3)


def test_resolve_pruner_specs():
    assert isinstance(resolve_pruner("wcd"), WcdPruner)
    assert isinstance(resolve_pruner("rwmd"), RwmdPruner)
    comp = resolve_pruner("wcd+rwmd")
    assert isinstance(comp, MaxPruner) and comp.name == "wcd+rwmd"
    assert isinstance(resolve_pruner("wcd,rwmd"), MaxPruner)
    assert resolve_pruner(comp) is comp
    with pytest.raises(ValueError):
        resolve_pruner("nope")
    with pytest.raises(TypeError):
        resolve_pruner(42)


# ------------------------------------------------------------ subset solves
def test_subset_solve_matches_full_columns(corpus, engine):
    """Candidate-subset solve == the same columns of the exhaustive solve
    (per-doc independence is what makes staged pruning exact)."""
    queries = list(corpus.queries[:3])
    full = np.asarray(engine.query_batch(queries))
    doc_ids = np.asarray([5, 17, 3, 90, 41], np.int32)
    _, chunks = engine._plan(queries)
    for chunk, width in chunks:
        sup, r, mask = engine._prep_chunk([queries[qi] for qi in chunk],
                                          width)
        grp = engine.index.subset(doc_ids)
        # shape-bucketed: doc count padded to pow2 (inert all-zero docs),
        # cols keeps only the real ids
        assert grp.cols.shape[0] == doc_ids.size
        assert grp.docs.idx.shape[0] == 8
        w = np.asarray(engine._solve_group(engine._kq(sup, mask), r, mask,
                                           grp))[:len(chunk), :doc_ids.size]
        np.testing.assert_allclose(w, full[np.ix_(chunk, doc_ids)],
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------- streaming index
def test_append_docs_matches_rebuild(corpus):
    full = make_corpus(vocab_size=512, embed_dim=16, n_docs=128, n_queries=6,
                       words_per_doc=(3, 60), seed=23)
    head = PaddedDocs(idx=full.docs.idx[:96], val=full.docs.val[:96])
    tail = PaddedDocs(idx=full.docs.idx[96:], val=full.docs.val[96:])
    base = build_index(head, full.vecs)
    appended = append_docs(base, tail)
    rebuilt = build_index(full.docs, full.vecs)
    assert appended.n_docs == rebuilt.n_docs == 128
    # only the smallest group grew; the others' arrays are reused as-is
    grown = [ga.cols.shape[0] != gb.cols.shape[0]
             for ga, gb in zip(appended.groups, base.groups)]
    assert sum(grown) == 1
    for ga, gb in zip(appended.groups, base.groups):
        if ga.cols.shape[0] == gb.cols.shape[0]:
            assert ga.docs.idx is gb.docs.idx
    # centroids live in cluster-major STORAGE order, which differs between
    # the appended and rebuilt indexes — compare in caller doc order
    def by_caller(index):
        out = np.empty_like(np.asarray(index.centroids))
        out[index.ext_ids] = np.asarray(index.centroids)
        return out

    np.testing.assert_allclose(by_caller(appended), by_caller(rebuilt),
                               rtol=1e-5, atol=1e-6)
    queries = list(full.queries)
    ea = WmdEngine(appended, lam=8.0, n_iter=12)
    er = WmdEngine(rebuilt, lam=8.0, n_iter=12)
    np.testing.assert_allclose(np.asarray(ea.query_batch(queries)),
                               np.asarray(er.query_batch(queries)),
                               rtol=1e-5, atol=1e-6)
    sa = ea.search(queries, 5, prune="rwmd")
    sr = er.search(queries, 5, prune="rwmd")
    for qi in range(len(queries)):
        assert set(sa.indices[qi]) == set(sr.indices[qi])


def test_append_docs_validates_vocab(corpus):
    index = build_index(corpus.docs, corpus.vecs)
    bad = PaddedDocs(idx=jnp.asarray([[9999]], jnp.int32),
                     val=jnp.asarray([[1.0]], jnp.float32))
    with pytest.raises(ValueError):
        append_docs(index, bad)
    assert append_docs(index, PaddedDocs(
        idx=jnp.zeros((0, 4), jnp.int32),
        val=jnp.zeros((0, 4), jnp.float32))) is index


# ---------------------------------------------------------- underflow guard
def test_lam_underflow_raises(corpus):
    hot = WmdEngine(build_index(corpus.docs, corpus.vecs), lam=80.0,
                    n_iter=5)
    with pytest.raises(LamUnderflowError, match="underflowed"):
        hot.query_batch(list(corpus.queries[:2]))
    with pytest.raises(LamUnderflowError, match="lam"):
        one_to_many(corpus.queries[0], corpus.docs, corpus.vecs, lam=80.0,
                    n_iter=5, impl="sparse")
    # the log-domain impl is the documented escape hatch: finite, no raise
    d = one_to_many(corpus.queries[0], corpus.docs, corpus.vecs, lam=80.0,
                    n_iter=5, impl="dense_stabilized")
    assert np.isfinite(np.asarray(d)).all()
    # and check_underflow=False preserves the raw-NaN escape hatch
    d = one_to_many(corpus.queries[0], corpus.docs, corpus.vecs, lam=80.0,
                    n_iter=5, impl="sparse", check_underflow=False)
    assert np.isnan(np.asarray(d)).any()
