"""Property-based oracle layer over the END-TO-END retrieval pipeline.

Where ``test_properties.py`` checks the solver's mathematical invariants
(symmetry, triangle inequality, scale equivariance) on ``one_to_many``,
this suite drives the full ``WmdEngine.search`` stack — staging, bucketing,
pruning cascade, cluster-major storage, subset solves, rank — and asserts
invariants any retrieval system must satisfy regardless of implementation:

- permutation invariance: reordering the query batch or the corpus must
  not change what is retrieved (exercises the v_r bucketing, chunk
  composition, and the ext_ids/remap storage translation);
- duplicate-doc tie consistency: byte-identical documents get equal
  distances and are retrieved together;
- weight-scale invariance: scaling every document's word counts by one
  constant leaves the ranking unchanged;
- recall↑nprobe: the IVF cascade's recall is monotone in the probe
  budget and exact at the full budget;
- exact-EMD agreement: as lam grows (the log-domain path — fp32
  ``exp(-lam*M)`` would underflow first), converged Sinkhorn distances
  approach the LP optimum (Cuturi'13), checked against the scipy oracle.

Runs under real ``hypothesis`` when installed (the CI ``tests-hypothesis``
job); falls back to the deterministic ``tests/_hypothesis_compat.py`` shim
in the tier-1 suite. Shapes are held constant across examples (only seeds
vary) so each property compiles its engine once.
"""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import WmdEngine, build_index
from repro.core.exact_ot import exact_emd
from repro.core.sinkhorn import cdist
from repro.core.sparse import PaddedDocs, padded_docs_from_lists
from repro.data.corpus import make_corpus


def _doc_as_query(docs: PaddedDocs, j: int, vocab: int) -> np.ndarray:
    q = np.zeros(vocab, np.float32)
    idx = np.asarray(docs.idx[j])
    val = np.asarray(docs.val[j])
    q[idx[val > 0]] = val[val > 0]
    return q


def _mk(seed, n_docs=48, n_queries=4, vocab=256):
    return make_corpus(vocab_size=vocab, embed_dim=16, n_docs=n_docs,
                       n_queries=n_queries, words_per_doc=(4, 24), seed=seed)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_query_permutation_invariance(seed):
    """Reordering the query batch permutes result rows and nothing else —
    bucketing sorts queries by v_r internally, so this exercises the whole
    staging/chunking path under a different composition."""
    corp = _mk(seed)
    eng = WmdEngine(build_index(corp.docs, corp.vecs), lam=2.0, n_iter=12)
    qs = list(corp.queries)
    perm = np.random.default_rng(seed).permutation(len(qs))
    res = eng.search(qs, 5, prune="rwmd")
    res_p = eng.search([qs[i] for i in perm], 5, prune="rwmd")
    for row, qi in enumerate(perm):
        assert set(res_p.indices[row].tolist()) == \
            set(res.indices[qi].tolist())
        np.testing.assert_allclose(np.sort(res_p.distances[row]),
                                   np.sort(res.distances[qi]),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_doc_permutation_invariance(seed):
    """Permuting the corpus before the index build maps retrieved ids
    through the permutation — distances unchanged. Exercises the
    cluster-major storage permutation and the ext_ids/remap translation
    (a bug there returns the right distances for the wrong documents)."""
    corp = _mk(seed)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(corp.docs.idx.shape[0])
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    shuffled = PaddedDocs(idx=corp.docs.idx[perm], val=corp.docs.val[perm])
    eng = WmdEngine(build_index(corp.docs, corp.vecs), lam=2.0, n_iter=12)
    eng_p = WmdEngine(build_index(shuffled, corp.vecs), lam=2.0, n_iter=12)
    qs = list(corp.queries)
    res = eng.search(qs, 5, prune="rwmd")
    res_p = eng_p.search(qs, 5, prune="rwmd")
    for qi in range(len(qs)):
        # shuffled-corpus id j is original id perm[j]
        assert set(perm[res_p.indices[qi]].tolist()) == \
            set(res.indices[qi].tolist())
        np.testing.assert_allclose(np.sort(res_p.distances[qi]),
                                   np.sort(res.distances[qi]),
                                   rtol=1e-3, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_duplicate_doc_tie_consistency(seed):
    """Byte-identical documents are indistinguishable to the engine:
    both enter the top-k together and their distances agree to fp."""
    corp = _mk(seed, n_docs=32, n_queries=0)
    idx = np.asarray(corp.docs.idx)
    val = np.asarray(corp.docs.val)
    dup_of = int(np.random.default_rng(seed).integers(0, 32))
    docs = PaddedDocs(idx=jnp.asarray(np.vstack([idx, idx[dup_of:dup_of + 1]])),
                      val=jnp.asarray(np.vstack([val, val[dup_of:dup_of + 1]])))
    eng = WmdEngine(build_index(docs, corp.vecs), lam=2.0, n_iter=12)
    q = _doc_as_query(docs, dup_of, 256)
    res = eng.search([q], 4, prune="rwmd")
    got = res.indices[0].tolist()
    assert dup_of in got and 32 in got, got  # the dup pair retrieved together
    d = {i: float(res.distances[0][p]) for p, i in enumerate(got)}
    assert abs(d[dup_of] - d[32]) <= 1e-5 * (1.0 + abs(d[dup_of]))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.sampled_from([0.25, 3.0, 17.0]))
def test_weight_scale_invariance(seed, scale):
    """Scaling every doc's word counts by one constant rescales distances
    uniformly (the solve's doc marginal is the raw counts) and therefore
    leaves the retrieved set and its order unchanged."""
    corp = _mk(seed)
    docs_s = PaddedDocs(idx=corp.docs.idx, val=corp.docs.val * scale)
    eng = WmdEngine(build_index(corp.docs, corp.vecs), lam=2.0, n_iter=12)
    eng_s = WmdEngine(build_index(docs_s, corp.vecs), lam=2.0, n_iter=12)
    qs = list(corp.queries)
    res = eng.search(qs, 5, prune="rwmd")
    res_s = eng_s.search(qs, 5, prune="rwmd")
    for qi in range(len(qs)):
        assert set(res_s.indices[qi].tolist()) == \
            set(res.indices[qi].tolist())
        np.testing.assert_allclose(res_s.distances[qi],
                                   res.distances[qi] * scale,
                                   rtol=1e-3, atol=1e-4)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_recall_monotone_in_nprobe(seed):
    """IVF cascade recall against the exhaustive reference is monotone in
    ``nprobe`` (probe sets are nested) and exactly 1 at the full budget."""
    from benchmarks.fig8_topk_prune import dedup_corpus
    corp = dedup_corpus(64, vocab=512, embed_dim=16, seed=seed)
    index = build_index(corp.docs, corp.vecs, n_clusters=8)
    eng = WmdEngine(index, lam=1.0, n_iter=12)
    qs = list(corp.queries)
    truth = [set(r.tolist())
             for r in eng.search(qs, 5, prune=None).indices]
    recalls = []
    for nprobe in (1, 2, 4, 8):
        res = eng.search(qs, 5, prune="ivf+wcd+rwmd", nprobe=nprobe)
        hit = sum(len(set(res.indices[qi].tolist()) & truth[qi])
                  for qi in range(len(qs)))
        recalls.append(hit / (5 * len(qs)))
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0, recalls


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_search_distances_approach_exact_emd(seed):
    """End-to-end distances converge to the LP optimum as lam grows, in
    the regime PR 4 unlocked: the linear fp32 path ALREADY raises
    ``LamUnderflowError`` at the large lam (asserted), while the
    log-domain path completes and its distance for the query's source
    document tightens onto the scipy ``exact_emd`` oracle (5% at lam=40
    vs the entropy-gap-sized 25% at lam=10).

    Scoped to the source-document pair on purpose: fp32 log-domain drops
    fully-underflowed query-word ROWS for far (query, doc) pairs (their
    plan mass is beyond the fp32 exp horizon — the documented dropout
    semantics), so only numerically representable pairs can be held to
    the LP. The near-duplicate pair retrieval actually ranks on is
    exactly such a pair."""
    from repro.core import LamUnderflowError
    rng = np.random.default_rng(seed)
    base = make_corpus(vocab_size=128, embed_dim=8, n_docs=6, n_queries=0,
                       words_per_doc=(4, 10), seed=seed)
    idx = np.asarray(base.docs.idx)
    val = np.asarray(base.docs.val)
    # normalize doc marginals so the LP and the engine agree on mass
    norm = [(idx[j][val[j] > 0], val[j][val[j] > 0] / val[j][val[j] > 0].sum())
            for j in range(6)]
    docs = padded_docs_from_lists([i for i, _ in norm], [c for _, c in norm])
    src = int(rng.integers(0, 6))
    q = np.zeros(128, np.float32)
    ids, cts = norm[src]
    q[ids] = cts
    index = build_index(docs, base.vecs)
    vecs = np.asarray(base.vecs)
    r = (q[q > 0] / q[q > 0].sum()).astype(np.float64)
    vecs_sel = vecs[np.nonzero(q > 0)[0]]
    m_src = np.asarray(cdist(jnp.asarray(vecs_sel),
                             jnp.asarray(vecs[ids])), np.float64)
    lp = exact_emd(r, np.asarray(norm[src][1], np.float64), m_src)

    def src_dist(lam, n_iter):
        eng = WmdEngine(index, lam=lam, n_iter=n_iter, precision="log")
        res = eng.search([q], 6, prune=None)
        pos = res.indices[0].tolist().index(src)
        return float(res.distances[0][pos])

    # lam=40 is past the linear fp32 horizon on this corpus scale...
    try:
        WmdEngine(index, lam=40.0, n_iter=5).query_batch([q])
        raise AssertionError("expected LamUnderflowError on the linear "
                             "path at lam=40")
    except LamUnderflowError:
        pass
    # ...while the log path completes and tightens onto the LP
    assert abs(src_dist(10.0, 200) - lp) <= 0.25 * lp + 0.05
    assert abs(src_dist(40.0, 600) - lp) <= 0.05 * lp + 0.02
