"""Docs gate (CI ``docs`` job): runnable examples + intra-repo links.

Two checks, both fail-loud:

1. **Doctests** — the module-level examples on the documented public API
   surface (``repro.core.index``, ``repro.core.prune``,
   ``repro.core.shard_index``, ``repro.runtime.serving``) are executed
   with :mod:`doctest`. A documented example that no longer runs is docs
   drift, the exact failure mode this job exists to catch.
2. **Intra-repo links** — every relative markdown link (and anchor) in
   ``docs/*.md`` and ``README.md`` must resolve to a real file; anchors
   (``file.md#section``) must match a heading in the target. External
   ``http(s)://`` links are not fetched (CI offline-safety), only
   well-formedness is required.

Run it the way CI does::

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOCTEST_MODULES = (
    "repro.core.index",
    "repro.core.prune",
    "repro.core.shard_index",
    "repro.runtime.serving",
)

MD_FILES = ("README.md", "docs/architecture.md", "docs/tuning.md")

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, spaces -> dashes, drop
    punctuation (the subset our headings actually use)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    out = set()
    in_fence = False
    for line in md_path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
        elif not in_fence and line.startswith("#"):
            out.add(_slug(line.lstrip("#")))
    return out


def check_links() -> list[str]:
    errors = []
    for rel in MD_FILES:
        md = REPO / rel
        if not md.is_file():
            errors.append(f"{rel}: file missing")
            continue
        text = md.read_text()
        # strip fenced code blocks — diagram/shell content is not links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part else \
                (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if _slug(anchor) not in _anchors(dest):
                    errors.append(
                        f"{rel}: broken anchor -> {target} "
                        f"(no heading '#{anchor}' in {dest.name})")
    return errors


def check_doctests() -> list[str]:
    errors = []
    for name in DOCTEST_MODULES:
        mod = importlib.import_module(name)
        res = doctest.testmod(mod, verbose=False,
                              optionflags=doctest.ELLIPSIS)
        print(f"doctest {name}: {res.attempted} examples, "
              f"{res.failed} failed")
        if res.failed:
            errors.append(f"{name}: {res.failed} doctest failure(s)")
        elif res.attempted == 0:
            errors.append(f"{name}: no doctest examples found "
                          "(documented example removed?)")
    return errors


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"LINK  {e}")
    doc_errors = check_doctests()
    for e in doc_errors:
        print(f"DOCTEST  {e}")
    errors += doc_errors
    if errors:
        print(f"\ndocs gate FAILED: {len(errors)} error(s)")
        return 1
    print("docs gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
